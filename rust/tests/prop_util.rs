//! Tiny property-testing helper shared by the prop_* integration tests
//! (the vendored crate set has no proptest): runs a closure over many
//! deterministically-seeded random cases and reports the failing seed.

#![allow(dead_code)]

use occamy_offload::rng::Rng64;

/// Run `f` over `cases` seeded RNGs; panics with the failing case index.
pub fn prop(cases: u64, mut f: impl FnMut(&mut Rng64)) {
    for case in 0..cases {
        let mut rng = Rng64::seed_from_u64(0xDEAD_0000 + case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {})", 0xDEAD_0000u64 + case);
            std::panic::resume_unwind(e);
        }
    }
}

/// Pick one element of a slice.
pub fn choose<'a, T>(rng: &mut Rng64, xs: &'a [T]) -> &'a T {
    &xs[rng.gen_range_usize(0, xs.len())]
}
