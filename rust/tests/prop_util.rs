//! Tiny property-testing helper shared by the prop_* integration tests
//! (the vendored crate set has no proptest): runs a closure over many
//! deterministically-seeded random cases and reports the failing seed.

#![allow(dead_code)]

use occamy_offload::kernels::JobSpec;
use occamy_offload::rng::Rng64;

/// Run `f` over `cases` seeded RNGs; panics with the failing case index.
pub fn prop(cases: u64, mut f: impl FnMut(&mut Rng64)) {
    for case in 0..cases {
        let mut rng = Rng64::seed_from_u64(0xDEAD_0000 + case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {})", 0xDEAD_0000u64 + case);
            std::panic::resume_unwind(e);
        }
    }
}

/// Pick one element of a slice.
pub fn choose<'a, T>(rng: &mut Rng64, xs: &'a [T]) -> &'a T {
    &xs[rng.gen_range_usize(0, xs.len())]
}

/// A random job over all six kernel families and a spread of sizes —
/// the shared generator of every offload/sweep property test (keep it
/// in one place so new `JobSpec` variants widen every suite at once).
pub fn random_spec(rng: &mut Rng64) -> JobSpec {
    match rng.gen_range_usize(0, 6) {
        0 => JobSpec::Axpy {
            n: *choose(rng, &[1, 7, 64, 255, 1024, 4096]),
        },
        1 => JobSpec::MonteCarlo {
            samples: *choose(rng, &[8, 100, 4096, 65536]),
        },
        2 => {
            let s = *choose(rng, &[4u64, 16, 33, 64]);
            JobSpec::Matmul { m: s, n: s, k: s }
        }
        3 => {
            let s = *choose(rng, &[4u64, 16, 63, 128]);
            JobSpec::Atax { m: s, n: s }
        }
        4 => JobSpec::Covariance {
            m: *choose(rng, &[2u64, 8, 32]),
            n: *choose(rng, &[4u64, 64, 128]),
        },
        _ => JobSpec::Bfs {
            nodes: *choose(rng, &[4u64, 16, 64, 100]),
            levels: *choose(rng, &[1u64, 2, 5, 9]),
        },
    }
}
