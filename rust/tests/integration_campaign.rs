//! Integration and property tests of the `campaign` subsystem: sharded
//! execution merging bit-identical to a single process, resume after a
//! kill, persistent-store reuse across (simulated) processes, corruption
//! tolerance, and figure reconstruction from merged output.

mod prop_util;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use occamy_offload::campaign::{self, CampaignSpec, Shard, TraceStore};
use occamy_offload::config::Config;
use occamy_offload::exp::fig7;
use occamy_offload::sweep::cache;
use prop_util::{choose, prop};

/// Unique scratch directory per call (tests run in parallel).
fn temp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "occamy-campaign-it-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small campaign spec with a per-test timing override, so the
/// process-wide trace cache and the store fingerprints of parallel
/// tests never alias.
fn small_spec(name: &str, gap: u64, kernels: &str, clusters: &str) -> CampaignSpec {
    CampaignSpec::parse(&format!(
        "[campaign]\nname = \"{name}\"\n\n[grid]\nkernels = [{kernels}]\nclusters = [{clusters}]\n\
         routines = [\"baseline\", \"ideal\", \"multicast\"]\n\n[timing]\nhost_ipi_issue_gap = {gap}\n"
    ))
    .unwrap()
}

#[test]
fn prop_shard_merge_is_bit_identical_to_single_process() {
    // The tentpole claim: for any campaign and any shard count, running
    // the shards independently and merging their streamed output equals
    // single-process execution bit-for-bit (every phase span of every
    // trace, in expansion order).
    const KERNELS: [&str; 5] = [
        "\"axpy:64\"",
        "\"atax:16\"",
        "\"montecarlo:256\"",
        "\"bfs:16x2\"",
        "\"covariance:8x16\"",
    ];
    prop(5, |rng| {
        let n_kernels = rng.gen_range_usize(1, 4);
        let kernels: Vec<&str> = (0..n_kernels).map(|_| *choose(rng, &KERNELS)).collect();
        let clusters = ["1", "1, 4", "2, 8"][rng.gen_range_usize(0, 3)];
        // Unique gap per case: disjoint cache/store namespaces.
        let gap = 1000 + rng.gen_range_usize(0, 10_000) as u64;
        let spec = small_spec("prop", gap, &kernels.join(", "), clusters);
        let shard_count = rng.gen_range_usize(2, 5);
        let out = temp_dir("prop");
        for i in 0..shard_count {
            let report =
                campaign::run_shard(&spec, Shard::new(i, shard_count).unwrap(), &out, None)
                    .unwrap();
            assert_eq!(report.executed + report.resumed, report.owned);
        }
        let merged = campaign::merge(&spec, shard_count, &out).unwrap();
        let single = campaign::run_single(&spec);
        assert_eq!(merged, single, "shard count {shard_count}");
        let _ = std::fs::remove_dir_all(&out);
    });
}

#[test]
fn resume_after_kill_skips_completed_points() {
    let spec = small_spec("resume-kill", 41, "\"axpy:96\", \"atax:16\"", "1, 4");
    let out = temp_dir("resume-kill");
    let shard = Shard::new(0, 2).unwrap();
    let full = campaign::run_shard(&spec, shard, &out, None).unwrap();
    assert!(full.owned >= 3);
    assert_eq!(full.executed, full.owned);

    // Simulate a kill mid-write: keep two complete lines plus a torn
    // third line.
    let text = std::fs::read_to_string(&full.output).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let torn = format!("{}\n{}\n{}", lines[0], lines[1], &lines[2][..lines[2].len() / 2]);
    std::fs::write(&full.output, torn).unwrap();

    let resumed = campaign::run_shard(&spec, shard, &out, None).unwrap();
    assert_eq!(resumed.resumed, 2, "both intact lines are reused");
    assert_eq!(resumed.dropped, 1, "the torn tail is dropped");
    assert_eq!(resumed.executed, full.owned - 2, "only the rest re-runs");

    // The other shard plus a merge still reproduces the single-process
    // results exactly.
    campaign::run_shard(&spec, Shard::new(1, 2).unwrap(), &out, None).unwrap();
    let merged = campaign::merge(&spec, 2, &out).unwrap();
    assert_eq!(merged, campaign::run_single(&spec));
}

#[test]
fn warm_store_performs_zero_new_simulations() {
    // Acceptance criterion: a second campaign run against a warm on-disk
    // trace store simulates nothing, even from a cold process (emulated
    // by clearing the process-wide cache; this test's config is unique
    // to it, so parallel tests are unaffected).
    let spec = small_spec("warm-store", 42, "\"axpy:80\", \"bfs:16x2\"", "1, 2");
    let store = TraceStore::open(temp_dir("warm-store-root")).unwrap();
    let total = spec.expand().len();

    let cold_out = temp_dir("warm-store-cold");
    for i in 0..2 {
        campaign::run_shard(&spec, Shard::new(i, 2).unwrap(), &cold_out, Some(&store)).unwrap();
    }
    let cold = store.stats();
    assert_eq!(cold.simulations as usize, total, "cold run simulates everything");
    assert_eq!(cold.disk_hits, 0);

    // "New process": cold memory cache, warm disk store, fresh handle
    // (fresh counters), fresh output dir.
    cache::clear();
    let store = TraceStore::open(store.root()).unwrap();
    let warm_out = temp_dir("warm-store-warm");
    for i in 0..2 {
        campaign::run_shard(&spec, Shard::new(i, 2).unwrap(), &warm_out, Some(&store)).unwrap();
    }
    let warm = store.stats();
    assert_eq!(warm.simulations, 0, "warm store: zero new simulations ({warm:?})");
    assert_eq!(warm.disk_hits as usize, total, "every point served from disk");

    let merged = campaign::merge(&spec, 2, &warm_out).unwrap();
    assert_eq!(merged, campaign::run_single(&spec));
}

#[test]
fn store_tolerates_corrupted_files_by_resimulating() {
    let spec = small_spec("corrupt-store", 43, "\"axpy:72\"", "1");
    let store = TraceStore::open(temp_dir("corrupt-store-root")).unwrap();
    let out = temp_dir("corrupt-store-cold");
    campaign::run_shard(&spec, Shard::SINGLE, &out, Some(&store)).unwrap();
    let fp = campaign::store::fingerprint(&spec.config);
    let n_traces = store.traces_on_disk(&fp);
    assert_eq!(n_traces, spec.expand().len());

    // Corrupt every stored trace (truncation and garbage).
    let dir = store.config_dir(&fp);
    for (i, entry) in std::fs::read_dir(&dir).unwrap().enumerate() {
        let path = entry.unwrap().path();
        if path.extension().is_some_and(|x| x == "json") {
            if i % 2 == 0 {
                std::fs::write(&path, "{\"tot").unwrap();
            } else {
                std::fs::write(&path, "not json at all").unwrap();
            }
        }
    }

    cache::clear();
    let store = TraceStore::open(store.root()).unwrap();
    let out2 = temp_dir("corrupt-store-warm");
    campaign::run_shard(&spec, Shard::SINGLE, &out2, Some(&store)).unwrap();
    let stats = store.stats();
    assert_eq!(stats.disk_hits, 0, "all corrupt files rejected");
    assert_eq!(stats.simulations as usize, n_traces, "everything re-simulated");
    // The store healed: the files parse again.
    cache::clear();
    let store = TraceStore::open(store.root()).unwrap();
    let out3 = temp_dir("corrupt-store-healed");
    campaign::run_shard(&spec, Shard::SINGLE, &out3, Some(&store)).unwrap();
    assert_eq!(store.stats().simulations, 0);
    assert_eq!(campaign::merge(&spec, 1, &out3).unwrap(), campaign::run_single(&spec));
}

#[test]
fn figures_render_from_precomputed_results() {
    // `from_results` on the figure's own sweep output must match `run`.
    let cfg = Config::default();
    let direct = fig7::run(&cfg);
    let rebuilt = fig7::from_results(&fig7::sweep().run(&cfg));
    assert_eq!(direct.points.len(), rebuilt.points.len());
    for (a, b) in direct.points.iter().zip(&rebuilt.points) {
        assert_eq!((a.kernel, a.n_clusters, a.overhead), (b.kernel, b.n_clusters, b.overhead));
    }
}

#[test]
fn campaign_covers_non_default_geometries() {
    // Non-default SoC geometry as a first-class campaign axis: the whole
    // shard/merge path works on a 2-quadrant SoC.
    let spec = CampaignSpec::parse(
        "[campaign]\nname = \"small-soc\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [1, 8]\n\
         [soc]\nn_quadrants = 2\n[timing]\nhost_ipi_issue_gap = 44\n",
    )
    .unwrap();
    assert_eq!(spec.config.soc.n_clusters(), 8);
    let out = temp_dir("small-soc");
    for i in 0..2 {
        campaign::run_shard(&spec, Shard::new(i, 2).unwrap(), &out, None).unwrap();
    }
    let merged = campaign::merge(&spec, 2, &out).unwrap();
    assert_eq!(merged, campaign::run_single(&spec));
    // The geometry override reached the DES: at 8 clusters, every
    // cluster recorded spans.
    let rec = &merged.records()[3];
    assert_eq!(rec.req().n_clusters, 8);
    assert_eq!(rec.trace.n_clusters(), 8);
}

#[test]
fn validate_reports_the_grid_shape() {
    let spec = small_spec("report", 45, "\"axpy:64\", \"axpy:128\", \"atax:16\"", "1, 2");
    let report = spec.report();
    assert_eq!(report.points, 3 * 2 * 3);
    assert_eq!(report.unique_traces, 3 * 2 * 3);
    assert_eq!(report.kernels.len(), 3);
    assert_eq!(report.config_fingerprint.len(), 16);
    let text = report.to_string();
    assert!(text.contains("18"), "{text}");
    assert!(text.contains("axpy_n128"), "{text}");
}
