//! Integration tests of the coordinator service: queueing, planning, JCU
//! bookkeeping, metrics — in timing-only mode and (when artifacts exist)
//! against the real PJRT runtime.

use std::path::PathBuf;

use occamy_offload::config::Config;
use occamy_offload::coordinator::{
    Coordinator, CoordinatorConfig, JobRequest, Placement,
};
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;

fn artifacts() -> Option<PathBuf> {
    let dir = std::env::var("OCCAMY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

fn timing_coordinator() -> Coordinator {
    Coordinator::start(
        CoordinatorConfig {
            cfg: Config::default(),
            queue_depth: 8,
            timing_only: true,
            ..Default::default()
        },
        None,
    )
    .unwrap()
}

#[test]
fn hundred_mixed_jobs_timing_only() {
    let c = timing_coordinator();
    let mix = [
        JobSpec::Axpy { n: 1024 },
        JobSpec::Atax { m: 64, n: 64 },
        JobSpec::MonteCarlo { samples: 8192 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
    ];
    let submitter = c.submitter();
    let h = std::thread::spawn(move || {
        for i in 0..100u64 {
            submitter
                .submit(JobRequest::new(i, mix[i as usize % mix.len()]))
                .unwrap();
        }
    });
    let mut seen = std::collections::HashSet::new();
    for _ in 0..100 {
        let r = c.recv().unwrap();
        assert!(seen.insert(r.id), "duplicate result id {}", r.id);
        assert!(r.cycles > 0);
    }
    h.join().unwrap();
    let m = c.shutdown();
    assert_eq!(m.completed, 100);
    assert_eq!(m.latency.count(), 100);
    assert!(m.jobs_per_sim_second() > 0.0);
}

#[test]
fn planner_places_mixed_sizes_sensibly() {
    let c = timing_coordinator();
    c.submit(JobRequest::new(0, JobSpec::Axpy { n: 8 })).unwrap();
    c.submit(JobRequest::new(1, JobSpec::MonteCarlo { samples: 1 << 16 }))
        .unwrap();
    let mut host = 0;
    let mut accel_wide = 0;
    for _ in 0..2 {
        let r = c.recv().unwrap();
        match r.placement {
            Placement::Host => host += 1,
            Placement::Accelerator { n_clusters } => {
                assert!(n_clusters >= 16);
                accel_wide += 1;
            }
        }
    }
    c.shutdown();
    assert_eq!((host, accel_wide), (1, 1));
}

#[test]
fn routine_comparison_through_coordinator() {
    // Baseline vs multicast through the service: multicast never slower.
    let c = timing_coordinator();
    let spec = JobSpec::Axpy { n: 1024 };
    c.submit(
        JobRequest::new(0, spec)
            .with_clusters(16)
            .with_routine(RoutineKind::Baseline),
    )
    .unwrap();
    c.submit(
        JobRequest::new(1, spec)
            .with_clusters(16)
            .with_routine(RoutineKind::Multicast),
    )
    .unwrap();
    let a = c.recv().unwrap();
    let b = c.recv().unwrap();
    let (base, mcast) = if a.routine == RoutineKind::Baseline {
        (a, b)
    } else {
        (b, a)
    };
    assert!(mcast.cycles < base.cycles);
    c.shutdown();
}

#[test]
fn model_estimates_accompany_results() {
    let c = timing_coordinator();
    c.submit(JobRequest::new(0, JobSpec::Axpy { n: 1024 }).with_clusters(8))
        .unwrap();
    let r = c.recv().unwrap();
    // Estimate within the paper's 15% of the simulated cycles.
    let err = (r.estimated_cycles as f64 - r.cycles as f64).abs() / r.cycles as f64;
    assert!(err < 0.15, "estimate {} vs sim {}", r.estimated_cycles, r.cycles);
    c.shutdown();
}

#[test]
fn full_stack_with_pjrt_verification() {
    let Some(dir) = artifacts() else { return };
    let c = Coordinator::start(
        CoordinatorConfig {
            cfg: Config::default(),
            queue_depth: 8,
            timing_only: false,
            ..Default::default()
        },
        Some(&dir),
    )
    .unwrap();
    let mix = [
        JobSpec::Axpy { n: 1024 },
        JobSpec::Matmul { m: 32, n: 32, k: 32 },
        JobSpec::Covariance { m: 32, n: 64 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
    ];
    for i in 0..12u64 {
        c.submit(JobRequest::new(i, mix[i as usize % mix.len()]))
            .unwrap();
    }
    for _ in 0..12 {
        let r = c.recv().unwrap();
        assert!(r.verified, "job {} {:?} failed verification", r.id, r.spec);
    }
    let m = c.shutdown();
    assert_eq!(m.verified, 12);
    assert_eq!(m.verification_failures, 0);
    assert!(m.pjrt_micros.mean() > 0.0);
}

#[test]
fn shutdown_with_queued_jobs_drains() {
    let c = timing_coordinator();
    for i in 0..4u64 {
        c.submit(JobRequest::new(i, JobSpec::Axpy { n: 256 })).unwrap();
    }
    // Shut down immediately: queued jobs still complete (close-then-drain).
    let m = c.shutdown();
    assert_eq!(m.completed, 4);
}
