//! Integration tests of overlapped dispatch and interference sweeps:
//! the `inflight = 1` serial reference must be bit-identical to the
//! isolated DES (what the pre-overlap serial coordinator reported),
//! contention must surface as a nonnegative, monotone queueing delay on
//! top of it, and `[interference]` campaigns must shard/merge like any
//! other.

use std::path::PathBuf;

use occamy_offload::campaign::{self, CampaignSpec, Shard};
use occamy_offload::config::Config;
use occamy_offload::coordinator::{Coordinator, CoordinatorConfig, JobRequest, JobResult};
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::{self, InterferenceRequest, OffloadRequest, Sweep};

fn coordinator(inflight: usize) -> Coordinator {
    Coordinator::start(
        CoordinatorConfig {
            cfg: Config::default(),
            timing_only: true,
            inflight,
            ..Default::default()
        },
        None,
    )
    .unwrap()
}

/// The mixed workload used across these tests: forced cluster counts so
/// the isolated reference is directly computable.
fn workload() -> Vec<JobRequest> {
    let mix = [
        (JobSpec::Axpy { n: 1024 }, 16),
        (JobSpec::Atax { m: 64, n: 64 }, 8),
        (JobSpec::MonteCarlo { samples: 8192 }, 16),
        (JobSpec::Matmul { m: 16, n: 16, k: 16 }, 4),
    ];
    (0..24u64)
        .map(|i| {
            let (spec, n) = mix[i as usize % mix.len()];
            JobRequest::new(i, spec).with_clusters(n)
        })
        .collect()
}

fn run_workload(inflight: usize) -> Vec<JobResult> {
    let c = coordinator(inflight);
    let jobs = workload();
    let n = jobs.len();
    for req in jobs {
        c.submit(req).unwrap();
    }
    let mut results: Vec<JobResult> = (0..n).map(|_| c.recv().unwrap()).collect();
    c.shutdown();
    results.sort_by_key(|r| r.id);
    results
}

#[test]
fn inflight_one_is_bit_identical_to_the_serial_coordinator() {
    // The serial coordinator reported, per job, exactly the isolated DES
    // cycles with no queueing. inflight = 1 must reproduce that
    // bit-for-bit against the DES reference.
    let cfg = Config::default();
    let results = run_workload(1);
    assert_eq!(results.len(), workload().len());
    for (r, req) in results.iter().zip(workload()) {
        assert_eq!(r.id, req.id);
        let isolated = sweep::run_one(
            &cfg,
            OffloadRequest::new(req.spec, req.n_clusters.unwrap(), RoutineKind::Multicast),
        )
        .total;
        assert_eq!(r.cycles, isolated, "job {}: serial cycles must be the DES's", r.id);
        assert_eq!(r.queue_delay, 0, "job {}: serial dispatch never queues", r.id);
        assert_eq!(r.latency(), isolated);
        assert!(r.error.is_none());
    }
    // And the whole schedule is deterministic: a second run agrees.
    let again = run_workload(1);
    for (a, b) in results.iter().zip(&again) {
        assert_eq!((a.cycles, a.queue_delay, a.start, a.completion), (b.cycles, b.queue_delay, b.start, b.completion));
    }
}

#[test]
fn overlapped_runs_decompose_and_stay_deterministic() {
    let serial = run_workload(1);
    let overlapped = run_workload(4);
    for (s, o) in serial.iter().zip(&overlapped) {
        // Service time is contention-independent (the isolated DES run).
        assert_eq!(s.cycles, o.cycles, "job {}", s.id);
        // Latency = isolated cycles + nonnegative queueing delay.
        assert_eq!(o.latency(), o.cycles + o.queue_delay);
        assert_eq!(o.completion, o.start + o.cycles);
    }
    // Contention exists: this mix cannot fully overlap on 32 clusters.
    assert!(
        overlapped.iter().map(|r| r.queue_delay).sum::<u64>() > 0,
        "a window of 4 over 16+8+16+4 cluster jobs must queue"
    );
    // Determinism under overlap, submission timing notwithstanding.
    let again = run_workload(4);
    for (a, b) in overlapped.iter().zip(&again) {
        assert_eq!(
            (a.queue_delay, a.start, a.completion),
            (b.queue_delay, b.start, b.completion),
            "job {}",
            a.id
        );
    }
}

#[test]
fn queueing_delay_is_monotone_in_the_window() {
    // Uniform 16-wide jobs: 1 and 2 fit the fabric (zero delay), wider
    // windows queue ever deeper.
    let cfg = Config::default();
    let req = OffloadRequest::new(JobSpec::Axpy { n: 1024 }, 16, RoutineKind::Multicast);
    let totals: Vec<u64> = [1usize, 2, 4, 8]
        .iter()
        .map(|&w| {
            InterferenceRequest::new(req, w, 16, 0)
                .run(&cfg)
                .total_queue_delay()
        })
        .collect();
    assert_eq!(totals[0], 0, "inflight = 1 is the serial reference");
    assert_eq!(totals[1], 0, "two 16-wide jobs fit 32 clusters");
    assert!(totals[2] > 0, "a window of 4 contends: {totals:?}");
    for pair in totals.windows(2) {
        assert!(pair[1] >= pair[0], "monotone in the window: {totals:?}");
    }
}

#[test]
fn coordinator_metrics_split_service_and_queueing() {
    let c = coordinator(4);
    for i in 0..8u64 {
        c.submit(JobRequest::new(i, JobSpec::Axpy { n: 1024 }).with_clusters(16))
            .unwrap();
    }
    for _ in 0..8 {
        c.recv().unwrap();
    }
    let m = c.shutdown();
    assert_eq!(m.completed, 8);
    assert_eq!(m.service.count(), 8);
    assert_eq!(m.queueing.count(), 8);
    assert!(m.queueing.sum() > 0, "16-wide jobs at window 4 must queue");
    assert_eq!(m.latency.sum(), m.service.sum() + m.queueing.sum());
    assert!(m.summary().contains("queueing"));
}

#[test]
fn bad_jobs_do_not_take_down_good_jobs_under_overlap() {
    let c = coordinator(4);
    // Submit-time rejection for the zero-cluster underflow case...
    assert!(c
        .submit(JobRequest::new(0, JobSpec::Axpy { n: 1024 }).with_clusters(0))
        .is_err());
    // ...and an in-loop error result for a geometry violation,
    // interleaved with good jobs.
    c.submit(JobRequest::new(1, JobSpec::Axpy { n: 1024 }).with_clusters(16)).unwrap();
    c.submit(JobRequest::new(2, JobSpec::Axpy { n: 1024 }).with_clusters(999)).unwrap();
    c.submit(JobRequest::new(3, JobSpec::Axpy { n: 1024 }).with_clusters(16)).unwrap();
    let mut results: Vec<JobResult> = (0..3).map(|_| c.recv().unwrap()).collect();
    results.sort_by_key(|r| r.id);
    assert!(results[0].error.is_none());
    assert!(results[1].is_rejected());
    assert!(results[2].error.is_none());
    assert_eq!(results[0].cycles, results[2].cycles);
    let m = c.shutdown();
    assert_eq!(m.completed, 2);
    assert_eq!(m.rejected, 1);
}

#[test]
fn interference_campaign_runs_merges_and_verifies_end_to_end() {
    // A two-shard [interference] campaign through run -> merge, checked
    // against the in-process reference, with the serial row equal to
    // the isolated trace and the contended rows queueing.
    let spec = CampaignSpec::parse(
        "[campaign]\nname = \"it-interference\"\n[grid]\n\
         kernels = [\"axpy:1024\", \"atax:64x64\"]\nclusters = [16]\n\
         routines = [\"multicast\"]\n[timing]\nhost_ipi_issue_gap = 47\n\
         [interference]\njobs_in_flight = [1, 4]\njobs = 12\n",
    )
    .unwrap();
    let out: PathBuf = std::env::temp_dir().join(format!(
        "occamy-it-interference-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&out);
    for i in 0..2 {
        campaign::run_shard(&spec, Shard::new(i, 2).unwrap(), &out, None).unwrap();
    }
    let merged = campaign::merge(&spec, 2, &out).unwrap();
    assert_eq!(merged, campaign::run_single(&spec));
    let records = campaign::interference_records(&spec, &merged).unwrap();
    assert_eq!(records.len(), 4, "2 kernels x 2 windows");
    for (point, outcome) in &records {
        let isolated = merged
            .records()
            .iter()
            .find(|r| r.req() == point.ireq.req)
            .unwrap()
            .total();
        assert_eq!(outcome.isolated, isolated);
        match point.ireq.inflight {
            1 => assert_eq!(outcome.total_queue_delay(), 0, "{}", point.label),
            _ => assert!(outcome.mean_latency() >= isolated as f64),
        }
    }
    // The file merge wrote round-trips to the same records.
    let read = campaign::stream::read_interference(
        &out.join(campaign::stream::interference_file_name(&spec.name)),
        &campaign::store::fingerprint(&spec.config),
    )
    .unwrap();
    assert_eq!(read, records);
}

#[test]
fn explicit_interference_sweep_matches_the_request_api() {
    // The grid path (Sweep::inflight + run_interference) and the direct
    // InterferenceRequest path must agree exactly.
    let cfg = Config::default();
    let samples = Sweep::new()
        .kernel("axpy", JobSpec::Axpy { n: 1024 })
        .clusters([16])
        .routines([RoutineKind::Multicast])
        .inflight([1, 4])
        .run_interference(&cfg, 12, 10);
    for s in &samples {
        assert_eq!(s.outcome, s.point.ireq.run(&cfg));
        assert_eq!(s.point.ireq.n_jobs, 12);
        assert_eq!(s.point.ireq.arrival_gap, 10);
    }
}
