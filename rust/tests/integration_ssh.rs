//! End-to-end tests of [`SshLauncher`] driving the fleet scheduler —
//! hermetically, via an `ssh` shim script that drops the host argument
//! and executes the remote command locally, so no real remote host (or
//! sshd) is needed. The transport is byte-for-byte what production ssh
//! sees: `<shim> <host> '<command>'`, pid banner on stdout, kill via a
//! second `<shim> <host> kill <pid>` invocation.

#![cfg(unix)]

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use occamy_offload::campaign::{self, CampaignSpec, HostSpec, Shard};
use occamy_offload::fleet::{
    self, FleetOptions, Launcher, LeaseState, SshLauncher, WorkerState, WorkerTask,
};

/// The occamy binary built for this test run.
fn occamy_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_occamy"))
}

/// Unique scratch directory per call (tests run in parallel).
fn temp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "occamy-ssh-it-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_executable(path: &Path, text: &str) {
    use std::os::unix::fs::PermissionsExt;
    std::fs::write(path, text).unwrap();
    std::fs::set_permissions(path, std::fs::Permissions::from_mode(0o755)).unwrap();
}

/// The hermetic ssh: `shim [-o opt].. <host> <command>` becomes
/// `sh -c <command>` locally, exactly how sshd hands the command to the
/// remote shell.
fn write_shim(dir: &Path) -> PathBuf {
    let path = dir.join("ssh");
    write_executable(
        &path,
        "#!/bin/sh\n# Hermetic ssh stand-in: skip options, drop the host, run locally.\n\
         while [ \"$1\" = \"-o\" ]; do shift 2; done\nshift\nexec /bin/sh -c \"$*\"\n",
    );
    path
}

/// A 12-point campaign spec on disk, with a per-test timing override so
/// parallel tests never share cache/store namespaces.
fn write_spec(tag: &str, gap: u64) -> (PathBuf, CampaignSpec) {
    let dir = temp_dir(&format!("spec-{tag}"));
    let path = dir.join("campaign.toml");
    let text = format!(
        "[campaign]\nname = \"ssh-it-{tag}\"\n\n[grid]\nkernels = [\"axpy:96\", \"atax:16\"]\n\
         clusters = [1, 4]\nroutines = [\"baseline\", \"ideal\", \"multicast\"]\n\n\
         [timing]\nhost_ipi_issue_gap = {gap}\n\n\
         [fleet]\nworkers = 2\nlease_ttl = 10\nmax_restarts = 2\n"
    );
    std::fs::write(&path, &text).unwrap();
    (path, CampaignSpec::parse(&text).unwrap())
}

fn shim_launcher(shim: PathBuf) -> SshLauncher {
    SshLauncher {
        hosts: vec![HostSpec::named("shim-a"), HostSpec::named("shim-b")],
        remote_bin: occamy_exe().to_string_lossy().into_owned(),
        local_root: None,
        ssh: shim,
        quiet: true,
    }
}

#[test]
fn a_two_shard_ssh_fleet_survives_a_chaos_kill_and_merges_bit_identically() {
    // The acceptance criterion: a 2-shard fleet fanned out over the ssh
    // shim, one worker chaos-killed mid-shard, recovers automatically
    // and merges bit-identical to single-process execution.
    let (spec_path, spec) = write_spec("chaos", 8301);
    let out = temp_dir("chaos-out");
    let shim = write_shim(&out);
    let mut opts = FleetOptions::new(&spec, out);
    opts.poll = Duration::from_millis(20);
    opts.chaos_kill = Some(1);
    let launcher = shim_launcher(shim);
    launcher.validate().unwrap();
    let report = fleet::run(&spec, &spec_path, &launcher, &opts).unwrap();

    assert_eq!(report.results, campaign::run_single(&spec), "bit-identical merge");
    assert_eq!(report.shards.len(), 2);
    assert_eq!(report.shards[0].restarts, 0);
    assert_eq!(report.shards[1].restarts, 1, "the chaos-killed shard was relaunched once");
    assert!(report.merged.exists());
    // Every point was simulated exactly once across the fleet,
    // including the one the killed worker streamed before dying.
    assert_eq!(report.sims, spec.expand().len());
    assert_eq!(report.hits, 0);

    // Workers heartbeated their leases over the "shared" filesystem and
    // marked them done; the relaunched worker's lease records attempt 1.
    let view = fleet::status(&spec, 2, &opts.out_dir, opts.store.as_deref(), &opts.run_id).unwrap();
    assert!(view.is_complete());
    assert_eq!(view.stale_shards(), 0);
    for sl in &view.leases {
        assert_eq!(sl.lease.as_ref().expect("every worker wrote a lease").state, LeaseState::Done);
    }
    assert_eq!(view.leases[1].lease.as_ref().unwrap().attempt, 1);
}

#[test]
fn ssh_worker_pid_banner_arrives_and_kill_terminates_the_remote_process() {
    let dir = temp_dir("pid");
    let shim = write_shim(&dir);
    // A "remote occamy" that just sleeps: exec keeps the banner pid and
    // the long-running process identical, like the real worker.
    let fake = dir.join("fake-occamy");
    write_executable(&fake, "#!/bin/sh\nexec sleep 30\n");
    let launcher = SshLauncher {
        hosts: vec![HostSpec::named("shim-a")],
        remote_bin: fake.to_string_lossy().into_owned(),
        local_root: None,
        ssh: shim,
        quiet: true,
    };
    let task = WorkerTask {
        spec_path: dir.join("unused.toml"),
        shard: Shard::SINGLE,
        out_dir: dir.clone(),
        store: None,
        lease_path: dir.join("shard-0-of-1.lease"),
        lease_ttl_secs: 5,
        run_id: "pid-test".into(),
        attempt: 0,
        max_points: None,
        trace_parent: None,
    };
    let mut handle = launcher.launch(&task).unwrap();
    // The pid banner is the first stdout line; wait for the reader
    // thread to parse it.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while handle.describe().contains("pending") && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    let who = handle.describe();
    assert!(who.contains("ssh shim-a, remote pid "), "{who}");
    assert!(!who.contains("pending"), "banner never arrived: {who}");
    assert_eq!(handle.poll().unwrap(), WorkerState::Running);
    // Kill goes through `ssh <host> kill <pid>` (the shim runs it
    // locally); idempotent, and the worker is observably gone.
    handle.kill();
    handle.kill();
    assert_eq!(handle.poll().unwrap(), WorkerState::Exited { success: false });
}

#[test]
fn cli_ssh_fleet_runs_merges_and_gc_sweeps_orphans_but_not_live_state() {
    let (spec_path, spec) = write_spec("cli", 8302);
    let out = temp_dir("cli-out");
    let shim = write_shim(&out);
    let exe = occamy_exe();
    let run = Command::new(&exe)
        .args(["fleet", "run", "--spec"])
        .arg(&spec_path)
        .args(["--workers", "2", "--poll-ms", "20", "--chaos-kill", "0", "--out"])
        .arg(&out)
        .args(["--hosts", "shim-a,shim-b", "--ssh"])
        .arg(&shim)
        .arg("--remote-bin")
        .arg(&exe)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&run.stdout);
    let stderr = String::from_utf8_lossy(&run.stderr);
    assert!(run.status.success(), "fleet run failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("ssh fan-out over 2 host(s): shim-a, shim-b"), "{stdout}");
    assert!(stdout.contains("1 restart(s)"), "{stdout}");

    // The merged output verifies bit-identical against a single-process
    // reference through the CLI as well.
    let merge = Command::new(&exe)
        .args(["campaign", "merge", "--spec"])
        .arg(&spec_path)
        .args(["--shards", "2", "--verify", "--out"])
        .arg(&out)
        .output()
        .unwrap();
    assert!(
        merge.status.success(),
        "merge --verify failed: {}",
        String::from_utf8_lossy(&merge.stderr)
    );

    // Plant orphans a killed writer would leave, next to live state.
    let store_root = out.join("store");
    let fp = campaign::store::fingerprint(&spec.config);
    let live_traces = campaign::store::traces_in(&store_root, &fp);
    assert!(live_traces > 0, "the fleet run persisted traces");
    let orphan_trace = store_root.join(&fp).join(".axpy_n96-c1-baseline.tmp-424242-0");
    std::fs::write(&orphan_trace, "torn").unwrap();
    let lease_dir = store_root.join("fleet").join(&spec.name);
    let orphan_lease = lease_dir.join(".lease-tmp-424242-0");
    std::fs::write(&orphan_lease, "torn").unwrap();

    // Dry run reports both orphans and touches nothing.
    let gc_args = |extra: &[&str]| {
        let mut c = Command::new(&exe);
        c.args(["fleet", "gc", "--store"]).arg(&store_root).args(["--tmp-grace-secs", "0"]);
        c.args(extra);
        c
    };
    let dry = gc_args(&["--dry-run"]).output().unwrap();
    let dry_out = String::from_utf8_lossy(&dry.stdout);
    assert!(dry.status.success(), "{}", String::from_utf8_lossy(&dry.stderr));
    assert!(dry_out.contains("orphaned temp file(s): 2 would remove"), "{dry_out}");
    assert!(orphan_trace.exists() && orphan_lease.exists(), "dry run must not delete");

    // The real pass sweeps the orphans and keeps live leases and traces
    // (the completed run's lease dir is younger than retention).
    let gc = gc_args(&[]).output().unwrap();
    let gc_out = String::from_utf8_lossy(&gc.stdout);
    assert!(gc.status.success(), "{}", String::from_utf8_lossy(&gc.stderr));
    assert!(gc_out.contains("orphaned temp file(s): 2 removed"), "{gc_out}");
    assert!(!orphan_trace.exists() && !orphan_lease.exists());
    assert!(lease_dir.exists(), "the recent run's lease dir survives retention");
    assert_eq!(
        campaign::store::traces_in(&store_root, &fp),
        live_traces,
        "gc must not touch valid traces"
    );
}
