//! Integration tests of the analytical model against the simulator and
//! the planner built on top of it (§5.6).

use occamy_offload::config::Config;
use occamy_offload::coordinator::{Placement, Planner};
use occamy_offload::kernels::JobSpec;
use occamy_offload::model::{max_rel_error, validate_grid, OffloadModel};
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::{self, OffloadRequest};

#[test]
fn model_error_below_15_percent_full_grid() {
    // The paper's Fig. 12 claim over all six kernels at small sizes.
    let cfg = Config::default();
    let specs = [
        JobSpec::Axpy { n: 256 },
        JobSpec::Axpy { n: 1024 },
        JobSpec::MonteCarlo { samples: 1024 },
        JobSpec::MonteCarlo { samples: 16384 },
        JobSpec::Matmul { m: 16, n: 16, k: 16 },
        JobSpec::Matmul { m: 64, n: 64, k: 64 },
        JobSpec::Atax { m: 32, n: 32 },
        JobSpec::Atax { m: 128, n: 128 },
        JobSpec::Covariance { m: 32, n: 64 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
    ];
    let pts = validate_grid(&cfg, &specs, &[1, 2, 4, 8, 16, 32]);
    let max = max_rel_error(&pts);
    assert!(max < 0.15, "max error {max:.3}");
}

#[test]
fn model_is_calibration_aware() {
    // Scaling a timing constant moves both model and simulation together:
    // the error bound survives a +50% DMA latency ablation.
    let mut cfg = Config::default();
    cfg.timing.dma_roundtrip += 28;
    let specs = [JobSpec::Axpy { n: 512 }, JobSpec::Atax { m: 64, n: 64 }];
    let pts = validate_grid(&cfg, &specs, &[1, 4, 16, 32]);
    assert!(max_rel_error(&pts) < 0.15);
}

#[test]
fn model_upper_phases_match_trace() {
    // Phase-level agreement, not just totals: B/C/H estimates must be
    // within a few cycles of the simulated multicast phases.
    let cfg = Config::default();
    let spec = JobSpec::Axpy { n: 1024 };
    let model = OffloadModel::new(&cfg);
    let est = model.phases(&spec, 8);
    let trace = sweep::run_one(&cfg, OffloadRequest::new(spec, 8, RoutineKind::Multicast));
    use occamy_offload::sim::Phase;
    let b_sim = trace.stats(Phase::Wakeup).unwrap().max;
    let b_est = est.get(Phase::Wakeup);
    assert!((b_sim as i64 - b_est as i64).abs() <= 3, "B: sim {b_sim} est {b_est}");
    let c_sim = trace.stats(Phase::RetrievePtr).unwrap().max;
    let c_est = est.get(Phase::RetrievePtr);
    assert!((c_sim as i64 - c_est as i64).abs() <= 3, "C: sim {c_sim} est {c_est}");
}

#[test]
fn planner_beats_naive_all_clusters_policy() {
    // The paper's motivation: the offload decision is non-trivial. For a
    // broadcast-class kernel the model-driven cluster count must beat
    // always-use-32.
    let cfg = Config::default();
    let planner = Planner::new(&cfg);
    let spec = JobSpec::Atax { m: 64, n: 64 };
    let plan = planner.plan(&spec);
    let mcast =
        |n: usize| sweep::run_one(&cfg, OffloadRequest::new(spec, n, RoutineKind::Multicast)).total;
    let naive = mcast(32);
    match plan.placement {
        Placement::Accelerator { n_clusters } => {
            let chosen = mcast(n_clusters);
            assert!(
                chosen < naive,
                "planner's {n_clusters} clusters ({chosen}) should beat 32 ({naive})"
            );
        }
        Placement::Host => {
            assert!(plan.host_estimate < naive);
        }
    }
}

#[test]
fn planner_monotone_in_problem_size() {
    // Larger AXPYs never get *fewer* clusters.
    let cfg = Config::default();
    let planner = Planner::new(&cfg);
    let mut prev = 0usize;
    for n in [64u64, 256, 1024, 4096, 16384, 65536] {
        let plan = planner.plan(&JobSpec::Axpy { n });
        let c = match plan.placement {
            Placement::Host => 0,
            Placement::Accelerator { n_clusters } => n_clusters,
        };
        assert!(c >= prev, "axpy {n}: {prev} -> {c} clusters");
        prev = c;
    }
    assert!(prev >= 16, "largest AXPY should use many clusters");
}

#[test]
fn model_estimate_is_fast() {
    // The model exists to make offload decisions cheap: three orders of
    // magnitude faster than simulating (sanity check, not a benchmark).
    let cfg = Config::default();
    let model = OffloadModel::new(&cfg);
    let spec = JobSpec::Axpy { n: 4096 };
    let t0 = std::time::Instant::now();
    for _ in 0..1000 {
        std::hint::black_box(model.estimate(&spec, 32));
    }
    let model_time = t0.elapsed();
    let t1 = std::time::Instant::now();
    // Uncached direct runs: the sweep cache would reduce this loop to
    // ten hash lookups and invalidate the comparison.
    let req = OffloadRequest::new(spec, 32, RoutineKind::Multicast);
    for _ in 0..10 {
        std::hint::black_box(req.run(&cfg));
    }
    let sim_time = t1.elapsed() * 100; // scale to 1000 runs
    assert!(
        model_time * 20 < sim_time,
        "model {model_time:?} vs sim {sim_time:?}"
    );
}
