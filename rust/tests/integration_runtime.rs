//! Integration tests of the PJRT runtime against the real AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a loud
//! message) when the manifest is absent so `cargo test` works in a fresh
//! checkout, and the Makefile's `test` target guarantees the full path.

use std::path::PathBuf;

use occamy_offload::kernels::datagen::{self, JobInputs};
use occamy_offload::kernels::JobSpec;
use occamy_offload::runtime::{
    execute_job, run_and_verify, values_for, verify_job, PjrtRuntime, Value,
};

fn artifacts() -> Option<PathBuf> {
    let dir = std::env::var("OCCAMY_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

#[test]
fn every_manifest_artifact_loads_and_verifies() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    assert_eq!(rt.platform(), "cpu");
    let entries = rt.manifest().entries.clone();
    assert!(entries.len() >= 12, "expected the full variant set");
    for e in &entries {
        let spec = match e.kernel.as_str() {
            "axpy" => JobSpec::Axpy { n: e.params["n"] },
            "montecarlo" => JobSpec::MonteCarlo {
                samples: e.params["n"],
            },
            "matmul" => JobSpec::Matmul {
                m: e.params["m"],
                n: e.params["n"],
                k: e.params["k"],
            },
            "atax" => JobSpec::Atax {
                m: e.params["m"],
                n: e.params["n"],
            },
            "covariance" => JobSpec::Covariance {
                m: e.params["m"],
                n: e.params["n"],
            },
            "bfs" => JobSpec::Bfs {
                nodes: e.params["n"],
                levels: 4,
            },
            other => panic!("unknown kernel {other}"),
        };
        run_and_verify(&rt, &spec, 1234).unwrap_or_else(|err| {
            panic!("{} failed: {err:#}", e.id);
        });
    }
}

#[test]
fn executables_are_cached_across_calls() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let spec = JobSpec::Axpy { n: 256 };
    run_and_verify(&rt, &spec, 1).unwrap();
    let cached = rt.cached();
    run_and_verify(&rt, &spec, 2).unwrap();
    assert_eq!(rt.cached(), cached, "second run must reuse the executable");
}

#[test]
fn shape_mismatch_is_rejected_before_execution() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    // axpy_n256 expects [256] vectors; feed [128].
    let bad = vec![
        Value::scalar_f64(1.0),
        Value::vec_f64(vec![0.0; 128]),
        Value::vec_f64(vec![0.0; 128]),
    ];
    let err = rt.execute("axpy_n256", &bad).unwrap_err();
    assert!(format!("{err:#}").contains("shape"), "{err:#}");
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    assert!(rt.execute("axpy_n31337", &[]).is_err());
}

#[test]
fn pjrt_results_match_reference_bitwise_shapes() {
    // Cross-check a matmul end to end and inspect the output tensor.
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let spec = JobSpec::Matmul { m: 32, n: 32, k: 32 };
    let (inputs, expected) = datagen::generate(&spec, 99);
    let out = execute_job(&rt, &spec, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[32, 32]);
    verify_job(&spec, &expected, &out).unwrap();
}

#[test]
fn tampered_result_fails_verification() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let spec = JobSpec::Axpy { n: 256 };
    let (inputs, expected) = datagen::generate(&spec, 5);
    let mut out = execute_job(&rt, &spec, &inputs).unwrap();
    if let Value::F64 { data, .. } = &mut out[0] {
        data[0] += 1.0;
    }
    assert!(verify_job(&spec, &expected, &out).is_err());
}

#[test]
fn montecarlo_artifact_estimates_pi() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let spec = JobSpec::MonteCarlo { samples: 4096 };
    let (inputs, _) = datagen::generate(&spec, 7);
    let out = execute_job(&rt, &spec, &inputs).unwrap();
    let pi = out[0].as_f64().unwrap()[0];
    assert!((pi - std::f64::consts::PI).abs() < 0.2, "pi estimate {pi}");
    // Deterministic per seed.
    let out2 = execute_job(&rt, &spec, &inputs).unwrap();
    assert_eq!(out[0], out2[0]);
}

#[test]
fn bfs_artifact_returns_exact_distances() {
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let spec = JobSpec::Bfs {
        nodes: 64,
        levels: 4,
    };
    let (inputs, expected) = datagen::generate(&spec, 21);
    let JobInputs::Bfs { .. } = &inputs else {
        panic!()
    };
    let out = execute_job(&rt, &spec, &inputs).unwrap();
    verify_job(&spec, &expected, &out).unwrap();
    let dist = out[0].as_i32().unwrap();
    assert_eq!(dist[0], 0, "source at distance 0");
    assert!(dist.iter().all(|&d| d >= 0), "layered graphs are connected");
}

#[test]
fn values_roundtrip_2d_layouts() {
    // Row-major layout preserved through the Literal reshape path: build
    // an asymmetric matmul and compare against the native reference.
    let Some(dir) = artifacts() else { return };
    let rt = PjrtRuntime::new(&dir).unwrap();
    let spec = JobSpec::Covariance { m: 32, n: 64 };
    let (inputs, expected) = datagen::generate(&spec, 3);
    let v = values_for(&spec, &inputs).unwrap();
    assert_eq!(v[0].shape(), &[32, 64]);
    let out = rt.execute(&spec.id(), &v).unwrap();
    verify_job(&spec, &expected, &out).unwrap();
}
