//! End-to-end tests of the `fleet` scheduler driving real `occamy`
//! worker subprocesses: automatic crash recovery merging bit-identical
//! to single-process execution, restart-budget exhaustion, warm-store
//! reuse, and a genuine mid-shard SIGKILL.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use occamy_offload::campaign::{self, CampaignSpec};
use occamy_offload::fleet::{
    self, FleetOptions, Launcher, LeaseState, LocalLauncher, WorkerHandle, WorkerTask,
};

/// The occamy binary built for this test run.
fn occamy_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_occamy"))
}

/// Unique scratch directory per call (tests run in parallel).
fn temp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "occamy-fleet-it-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Write a small campaign spec to disk (workers re-read it), with a
/// per-test timing override so parallel tests never share cache/store
/// namespaces. 12 points: 2 kernels x 2 cluster counts x 3 routines.
fn write_spec(tag: &str, gap: u64) -> (PathBuf, CampaignSpec) {
    let dir = temp_dir(&format!("spec-{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("campaign.toml");
    let text = format!(
        "[campaign]\nname = \"fleet-it-{tag}\"\n\n[grid]\nkernels = [\"axpy:96\", \"atax:16\"]\n\
         clusters = [1, 4]\nroutines = [\"baseline\", \"ideal\", \"multicast\"]\n\n\
         [timing]\nhost_ipi_issue_gap = {gap}\n\n\
         [fleet]\nworkers = 3\nlease_ttl = 10\nmax_restarts = 2\n"
    );
    std::fs::write(&path, &text).unwrap();
    (path, CampaignSpec::parse(&text).unwrap())
}

fn fast_opts(spec: &CampaignSpec, out: PathBuf) -> FleetOptions {
    let mut opts = FleetOptions::new(spec, out);
    opts.poll = Duration::from_millis(20);
    opts
}

#[test]
fn a_worker_killed_mid_shard_recovers_and_merges_bit_identically() {
    // The acceptance criterion: a 3-worker local fleet with one worker
    // dying mid-shard (chaos injection caps its first attempt at one
    // point and exits nonzero — byte-for-byte what a kill after one
    // streamed line looks like) recovers automatically and the merged
    // results equal single-process execution exactly.
    let (spec_path, spec) = write_spec("chaos", 8101);
    let out = temp_dir("chaos-out");
    let mut opts = fast_opts(&spec, out);
    opts.chaos_kill = Some(1);
    let launcher = LocalLauncher {
        exe: occamy_exe(),
        quiet: true,
    };
    let report = fleet::run(&spec, &spec_path, &launcher, &opts).unwrap();

    assert_eq!(report.shards.len(), 3);
    assert_eq!(report.shards[0].restarts, 0);
    assert_eq!(report.shards[1].restarts, 1, "the chaos-killed shard was relaunched once");
    assert_eq!(report.shards[2].restarts, 0);
    assert_eq!(report.results, campaign::run_single(&spec), "bit-identical merge");
    assert!(report.merged.exists());
    // Every point was simulated exactly once across the whole fleet
    // (including the one the killed worker streamed before dying).
    assert_eq!(report.sims, spec.expand().len());
    assert_eq!(report.hits, 0);

    // The shared status renderer agrees and shows the done leases.
    let view = fleet::status(&spec, 3, &opts.out_dir, opts.store.as_deref(), &opts.run_id).unwrap();
    assert!(view.is_complete());
    assert_eq!(view.stale_shards(), 0);
    for sl in &view.leases {
        let lease = sl.lease.as_ref().expect("every worker wrote a lease");
        assert_eq!(lease.state, LeaseState::Done);
    }
    assert_eq!(
        view.leases[1].lease.as_ref().unwrap().attempt,
        1,
        "the relaunched worker's final lease records attempt 1"
    );
    let text = view.to_string();
    assert!(text.contains("ready to merge"), "{text}");
    assert!(text.contains("store:"), "{text}");
}

/// Always re-injects the chaos cap, so the target shard can never
/// finish and the restart budget runs out.
struct AlwaysChaos {
    inner: LocalLauncher,
    shard: usize,
}

impl Launcher for AlwaysChaos {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
        let mut task = task.clone();
        if task.shard.index == self.shard {
            task.max_points = Some(1);
        }
        self.inner.launch(&task)
    }
}

#[test]
fn a_shard_that_keeps_dying_fails_the_run_after_max_restarts() {
    let (spec_path, spec) = write_spec("budget", 8102);
    let out = temp_dir("budget-out");
    let mut opts = fast_opts(&spec, out);
    opts.workers = 2;
    opts.max_restarts = 1;
    let launcher = AlwaysChaos {
        inner: LocalLauncher {
            exe: occamy_exe(),
            quiet: true,
        },
        shard: 0,
    };
    let err = fleet::run(&spec, &spec_path, &launcher, &opts).unwrap_err().to_string();
    assert!(err.contains("restart budget exhausted"), "{err}");
    assert!(err.contains("shard 0/2"), "{err}");
    // The two completed attempts each streamed one point; they resume
    // (not re-simulate) on the next run.
    let st = campaign::status(&spec, 2, &opts.out_dir).unwrap();
    assert_eq!(st.shards[0].done, 2, "one point per attempt survived");
}

#[test]
fn warm_store_fleet_rerun_simulates_nothing() {
    let (spec_path, spec) = write_spec("warm", 8103);
    let store_root = temp_dir("warm-store");
    let total = spec.expand().len();

    let cold_out = temp_dir("warm-cold-out");
    let mut cold = fast_opts(&spec, cold_out);
    cold.workers = 2;
    cold.store = Some(store_root.clone());
    let launcher = LocalLauncher {
        exe: occamy_exe(),
        quiet: true,
    };
    let report = fleet::run(&spec, &spec_path, &launcher, &cold).unwrap();
    assert_eq!(report.sims, total, "cold fleet simulates everything");
    assert_eq!(report.hits, 0);

    // Second fleet run: fresh output dir, same store — every point is
    // served from disk, zero new simulations.
    let warm_out = temp_dir("warm-warm-out");
    let mut warm = fast_opts(&spec, warm_out);
    warm.workers = 2;
    warm.store = Some(store_root);
    let rerun = fleet::run(&spec, &spec_path, &launcher, &warm).unwrap();
    assert_eq!(rerun.sims, 0, "warm store: zero new simulations");
    assert_eq!(rerun.hits, total);
    assert_eq!(rerun.results, report.results);
    assert_eq!(rerun.results, campaign::run_single(&spec));
}

/// SIGKILLs the target shard's first attempt as soon as its output file
/// has at least one streamed line — a genuine mid-shard kill, not an
/// orderly exit.
struct KillOnceStarted {
    inner: LocalLauncher,
    shard: usize,
    watch_file: PathBuf,
}

struct KillingHandle {
    inner: Box<dyn WorkerHandle>,
    watch: Option<PathBuf>,
}

impl WorkerHandle for KillingHandle {
    fn poll(&mut self) -> anyhow::Result<fleet::WorkerState> {
        if let Some(path) = &self.watch {
            if std::fs::metadata(path).map(|m| m.len() > 0).unwrap_or(false) {
                self.inner.kill();
                self.watch = None;
            }
        }
        self.inner.poll()
    }

    fn kill(&mut self) {
        self.inner.kill();
    }

    fn describe(&self) -> String {
        self.inner.describe()
    }
}

impl Launcher for KillOnceStarted {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
        let inner = self.inner.launch(task)?;
        Ok(Box::new(KillingHandle {
            inner,
            watch: (task.shard.index == self.shard && task.attempt == 0)
                .then(|| self.watch_file.clone()),
        }))
    }
}

#[test]
fn a_sigkilled_worker_is_reassigned_and_the_merge_stays_exact() {
    let (spec_path, spec) = write_spec("sigkill", 8104);
    let out = temp_dir("sigkill-out");
    let mut opts = fast_opts(&spec, out);
    opts.workers = 2;
    opts.poll = Duration::from_millis(5);
    let shard1 = campaign::Shard::new(1, 2).unwrap();
    let watch_file = opts.out_dir.join(campaign::stream::shard_file_name(&spec.name, shard1));
    let launcher = KillOnceStarted {
        inner: LocalLauncher {
            exe: occamy_exe(),
            quiet: true,
        },
        shard: 1,
        watch_file,
    };
    let report = fleet::run(&spec, &spec_path, &launcher, &opts).unwrap();
    // Whether the SIGKILL landed mid-shard or the worker won the race
    // and finished first, the merged results are exact; a landed kill
    // shows up as exactly one restart.
    assert!(report.shards[1].restarts <= 1);
    assert_eq!(report.results, campaign::run_single(&spec));
}
