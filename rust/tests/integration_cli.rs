//! Black-box tests of the `occamy` CLI surface: strict per-subcommand
//! flag rejection (a typo'd `--flag` must fail, not silently no-op) and
//! the fleet worker flags `campaign run` grew for the scheduler.

use std::process::{Command, Output};

fn occamy<S: AsRef<std::ffi::OsStr>>(args: &[S]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_occamy"))
        .args(args)
        .output()
        .expect("spawn occamy")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn unknown_flags_fail_fast_per_subcommand() {
    for args in [
        vec!["sim", "--warp", "9"],
        vec!["experiment", "fig7", "--wrap-speed", "1"],
        vec!["model", "--sizee", "64"],
        vec!["config-dump", "--verbose"],
        vec!["campaign", "run", "--maxpoints", "1"],
        vec!["campaign", "merge", "--shard", "0/2"], // merge takes --shards, not --shard
        vec!["fleet", "run", "--worker", "3"],       // fleet takes --workers
        vec!["fleet", "status", "--lease-ttl", "5"], // run-only flag
    ] {
        let out = occamy(&args);
        assert!(!out.status.success(), "{args:?} should fail");
        let err = stderr_of(&out);
        assert!(err.contains("unknown flag(s)"), "{args:?}: {err}");
        assert!(err.contains("allowed:"), "{args:?}: {err}");
    }
}

#[test]
fn extra_positionals_and_unknown_actions_are_rejected() {
    let out = occamy(&["config-dump", "stray"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unexpected argument"), "{}", stderr_of(&out));

    let out = occamy(&["campaign", "frobnicate"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown campaign action"), "{}", stderr_of(&out));

    let out = occamy(&["fleet", "frobnicate"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown fleet action"), "{}", stderr_of(&out));
}

#[test]
fn valid_invocations_still_work() {
    let out = occamy(&["config-dump"]);
    assert!(out.status.success());
    assert!(!out.stdout.is_empty());

    let out = occamy(&["help"]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("fleet run"));

    // --help inside a subcommand prints usage (as an error exit, so
    // scripts notice a half-formed command line).
    let out = occamy(&["sim", "--help"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("usage:"));
}

#[test]
fn campaign_run_max_points_stops_early_with_a_nonzero_exit() {
    // --max-points is the chaos seam the fleet smoke tests lean on: the
    // worker streams N points, then exits nonzero like a killed worker.
    let dir = std::env::temp_dir().join(format!("occamy-cli-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let spec = dir.join("spec.toml");
    std::fs::write(
        &spec,
        "[campaign]\nname = \"cli-maxpoints\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [1, 2]\n\
         routines = [\"baseline\", \"ideal\"]\n[timing]\nhost_ipi_issue_gap = 8201\n",
    )
    .unwrap();
    let out_dir = dir.join("out");
    let lease = dir.join("lease").join("shard-0-of-1.lease");

    let spec_s = spec.to_str().unwrap();
    let out_s = out_dir.to_str().unwrap();
    let lease_s = lease.to_str().unwrap();
    let worker_flags = |extra: &[&str]| -> Vec<String> {
        let mut args: Vec<String> = vec![
            "campaign".into(),
            "run".into(),
            "--spec".into(),
            spec_s.into(),
            "--out".into(),
            out_s.into(),
            "--no-store".into(),
            "--lease".into(),
            lease_s.into(),
            "--lease-ttl".into(),
            "5".into(),
            "--run-id".into(),
            "cli-test".into(),
        ];
        args.extend(extra.iter().map(|s| s.to_string()));
        args
    };
    let capped = worker_flags(&["--attempt", "0", "--max-points", "1"]);
    let run = occamy(&capped);
    assert!(!run.status.success(), "a capped run exits nonzero");
    assert!(stderr_of(&run).contains("--max-points"), "{}", stderr_of(&run));
    // It did stream its one point, and left the lease Running (stale to
    // any scheduler — exactly like a kill).
    let lease_text = std::fs::read_to_string(&lease).unwrap();
    assert!(lease_text.contains("\"running\""), "{lease_text}");
    assert!(lease_text.contains("\"cli-test\""), "{lease_text}");

    // Finishing the shard (no cap) succeeds and marks the lease done.
    let uncapped = worker_flags(&["--attempt", "1"]);
    let finish = occamy(&uncapped);
    assert!(finish.status.success(), "{}", stderr_of(&finish));
    let stdout = String::from_utf8_lossy(&finish.stdout);
    assert!(stdout.contains("1 resumed"), "{stdout}");
    let lease_text = std::fs::read_to_string(&lease).unwrap();
    assert!(lease_text.contains("\"done\""), "{lease_text}");

    // The shared status renderer shows per-shard sims and the merge
    // verifies bit-identity against a single-process reference.
    let status = occamy(&["campaign", "status", "--spec", spec_s, "--out", out_s, "--no-store"]);
    assert!(status.status.success(), "{}", stderr_of(&status));
    let stdout = String::from_utf8_lossy(&status.stdout);
    assert!(stdout.contains("4 of 4 points complete"), "{stdout}");
    assert!(stdout.contains("simulated"), "{stdout}");
    let merge = occamy(&["campaign", "merge", "--spec", spec_s, "--out", out_s, "--verify"]);
    assert!(merge.status.success(), "{}", stderr_of(&merge));
    assert!(String::from_utf8_lossy(&merge.stdout).contains("verified"), "{}", stderr_of(&merge));
}
