//! Integration tests of the offload framework across modules: the full
//! phase pipeline on the simulated SoC, config ablations, and the
//! paper's cross-cutting claims that involve more than one subsystem.
//! Runs go through the typed `sweep` API (cached where determinism is
//! not itself under test).

use std::sync::Arc;

use occamy_offload::config::Config;
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sim::{Phase, Trace};
use occamy_offload::sweep::{self, OffloadRequest, Sweep};

fn run_one(cfg: &Config, spec: JobSpec, n: usize, routine: RoutineKind) -> Arc<Trace> {
    sweep::run_one(cfg, OffloadRequest::new(spec, n, routine))
}

#[test]
fn full_sweep_all_kernels_all_routines() {
    // Smoke the entire configuration space end to end, as one campaign.
    let cfg = Config::default();
    let results = Sweep::new()
        .kernel("axpy", JobSpec::Axpy { n: 1024 })
        .kernel("montecarlo", JobSpec::MonteCarlo { samples: 4096 })
        .kernel("matmul", JobSpec::Matmul { m: 32, n: 32, k: 32 })
        .kernel("atax", JobSpec::Atax { m: 64, n: 64 })
        .kernel("covariance", JobSpec::Covariance { m: 32, n: 64 })
        .kernel("bfs", JobSpec::Bfs { nodes: 64, levels: 4 })
        .clusters([1, 2, 4, 8, 16, 32])
        .routines(RoutineKind::ALL)
        .run(&cfg);
    assert_eq!(results.len(), 6 * 6 * RoutineKind::ALL.len());
    for r in results.iter() {
        assert!(r.total() > 0, "{:?}", r.point);
        assert_eq!(r.trace.n_clusters(), r.req().n_clusters);
    }
}

#[test]
fn second_order_effect_atax_overhead_saturates() {
    // §5.2: for transfer-heavy kernels, part of the offload-phase time is
    // repaid as reduced contention stalls ("up to as much time as the
    // offset between Phase E on the first and last cluster"), so the
    // effective ATAX overhead flattens while e.g. Monte Carlo's (no
    // operand traffic to absorb anything) keeps growing.
    let cfg = Config::default();
    let atax = JobSpec::Atax { m: 64, n: 64 };
    let mc = JobSpec::MonteCarlo { samples: 16384 };
    let atax_8 = sweep::triple(&cfg, &atax, 8).overhead();
    let atax_32 = sweep::triple(&cfg, &atax, 32).overhead();
    let mc_8 = sweep::triple(&cfg, &mc, 8).overhead();
    let mc_32 = sweep::triple(&cfg, &mc, 32).overhead();
    assert!(
        (atax_32 - atax_8) < (mc_32 - mc_8) / 4,
        "ATAX grew {} vs MC {}",
        atax_32 - atax_8,
        mc_32 - mc_8
    );
}

#[test]
fn baseline_phase_e_start_skew_exceeds_multicast() {
    // The baseline's sequential wakeup staggers phase E starts; multicast
    // starts them (near-)simultaneously — the mechanism behind Fig. 11's
    // min/max bands.
    let cfg = Config::default();
    let spec = JobSpec::Axpy { n: 1024 };
    let base = run_one(&cfg, spec, 32, RoutineKind::Baseline);
    let mcast = run_one(&cfg, spec, 32, RoutineKind::Multicast);
    let skew_base = base.start_skew(Phase::RetrieveOperands).unwrap();
    let skew_mcast = mcast.start_skew(Phase::RetrieveOperands).unwrap();
    assert!(
        skew_base > 10 * skew_mcast.max(1),
        "baseline skew {skew_base} vs multicast {skew_mcast}"
    );
}

#[test]
fn wakeup_order_is_reversed_in_baseline() {
    // §5.5.H: clusters wake highest-index-first so cluster 0 arrives at
    // the barrier last.
    let cfg = Config::default();
    let spec = JobSpec::MonteCarlo { samples: 4096 };
    let t = run_one(&cfg, spec, 8, RoutineKind::Baseline);
    let wake_end = |c: usize| t.cluster_spans[c][&Phase::Wakeup].end;
    for c in 1..8 {
        assert!(
            wake_end(c) < wake_end(c - 1),
            "cluster {c} should wake before {}",
            c - 1
        );
    }
}

#[test]
fn config_ablation_smaller_soc() {
    // The simulator honors non-default geometries: a 2-quadrant SoC.
    let mut cfg = Config::default();
    cfg.soc.n_quadrants = 2;
    assert_eq!(cfg.soc.n_clusters(), 8);
    let spec = JobSpec::Axpy { n: 1024 };
    let t = sweep::triple(&cfg, &spec, 8);
    assert!(t.ideal <= t.improved && t.improved <= t.base);
}

#[test]
fn config_roundtrip_preserves_results() {
    // Serializing and re-parsing the config must not change timing.
    // Deliberately uncached direct runs: the cache would alias the two
    // configs (equal fingerprints) and make this tautological.
    let cfg = Config::default();
    let cfg2 = Config::from_toml(&cfg.to_toml()).unwrap();
    assert_eq!(cfg, cfg2);
    let spec = JobSpec::Atax { m: 64, n: 64 };
    let req = OffloadRequest::new(spec, 16, RoutineKind::Baseline);
    assert_eq!(req.run(&cfg).total, req.run(&cfg2).total);
}

#[test]
fn faster_noc_reduces_residual_overhead() {
    // Cutting the narrow-NoC hop latencies must reduce the multicast
    // routine's residual overhead (it is dominated by interrupt travel,
    // §5.4: "physical factors which cannot be trivially eliminated").
    let cfg = Config::default();
    let mut fast = cfg.clone();
    fast.timing.narrow_host_to_top = 1;
    fast.timing.narrow_top_to_quad = 1;
    fast.timing.narrow_quad_to_cluster = 1;
    fast.timing.cluster_wake = 8;
    let spec = JobSpec::Axpy { n: 1024 };
    let slow_res = sweep::triple(&cfg, &spec, 16).residual_overhead();
    let fast_res = sweep::triple(&fast, &spec, 16).residual_overhead();
    assert!(
        fast_res < slow_res,
        "residual should shrink: {slow_res} -> {fast_res}"
    );
}

#[test]
fn single_cluster_offload_has_no_remote_phases() {
    let cfg = Config::default();
    let spec = JobSpec::Axpy { n: 256 };
    let t = run_one(&cfg, spec, 1, RoutineKind::Baseline);
    // Phase C on cluster 0 is a local access: just a few cycles.
    let c = t.stats(Phase::RetrievePtr).unwrap();
    assert!(c.max <= 10, "local pointer load took {}", c.max);
}

#[test]
fn empty_workload_clusters_still_synchronize() {
    // AXPY with fewer elements than clusters: surplus clusters skip E/G
    // but still participate in wakeup and completion notification.
    let cfg = Config::default();
    let spec = JobSpec::Axpy { n: 4 };
    for r in [RoutineKind::Baseline, RoutineKind::Multicast] {
        let t = run_one(&cfg, spec, 32, r);
        assert!(t.total > 0);
        let e = t.stats(Phase::RetrieveOperands).unwrap();
        assert_eq!(e.n, 32, "every cluster records phase E (even zero-length)");
        assert_eq!(e.min, 0, "surplus clusters have empty phase E");
    }
}
