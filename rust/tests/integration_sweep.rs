//! Integration and property tests of the `sweep` subsystem: parallel ==
//! serial bit-identical results over randomized grids, equivalence of
//! the migrated figure modules with the legacy hand-rolled loops, and
//! trace-cache semantics.

mod prop_util;

use std::sync::Arc;

use occamy_offload::config::Config;
use occamy_offload::exp::{benchmark_set, fig7, CLUSTER_SWEEP};
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::{self, OffloadRequest, Sweep};
use prop_util::{choose, prop, random_spec};

#[test]
fn prop_parallel_matches_serial_bit_identical() {
    // The tentpole determinism claim: over a randomized grid, the
    // parallel executor returns results bit-identical (every phase span
    // of every trace, in the same order) to serial execution.
    let cfg = Config::default();
    const LABELS: [&str; 3] = ["k0", "k1", "k2"];
    prop(8, |rng| {
        let mut sweep = Sweep::new();
        for &label in LABELS.iter().take(rng.gen_range_usize(1, 4)) {
            sweep = sweep.kernel(label, random_spec(rng));
        }
        for _ in 0..rng.gen_range_usize(1, 3) {
            sweep = sweep.clusters([*choose(rng, &[1usize, 2, 5, 8, 16, 32])]);
        }
        let n_routines = rng.gen_range_usize(1, 4);
        for _ in 0..n_routines {
            sweep = sweep.routines([*choose(rng, &RoutineKind::ALL)]);
        }
        sweep = sweep.point(
            "extra",
            OffloadRequest::new(random_spec(rng), 3, RoutineKind::Multicast),
        );
        let serial = sweep.clone().serial().uncached().run(&cfg);
        let parallel = sweep.uncached().run(&cfg);
        assert_eq!(serial, parallel);
    });
}

#[test]
fn fig7_matches_legacy_per_loop_output() {
    // The migrated figure must reproduce the seed's hand-rolled loop
    // exactly (the raw uncached Executor is the legacy reference).
    let cfg = Config::default();
    let fig = fig7::run(&cfg);
    assert_eq!(fig.points.len(), benchmark_set().len() * CLUSTER_SWEEP.len());
    for (name, spec) in benchmark_set() {
        for &n in &CLUSTER_SWEEP {
            let run = |routine| {
                occamy_offload::offload::Executor::new(&cfg, &spec, n, routine)
                    .run()
                    .total as i64
            };
            let overhead = run(RoutineKind::Baseline) - run(RoutineKind::Ideal);
            assert_eq!(fig.overhead(name, n), Some(overhead), "{name}@{n}");
        }
    }
}

#[test]
fn cache_shares_traces_within_and_across_sweeps() {
    let cfg = Config::default();
    let req = OffloadRequest::new(JobSpec::Axpy { n: 48 }, 2, RoutineKind::Ideal);
    let a = sweep::run_one(&cfg, req);
    let b = sweep::run_one(&cfg, req);
    assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    // A sweep containing the same request shares the same trace.
    let results = Sweep::new()
        .point("p", req)
        .run(&cfg);
    assert!(Arc::ptr_eq(&a, &results.records()[0].trace));
    // A modified config must not alias.
    let mut other = cfg.clone();
    other.timing.host_ipi_issue_gap *= 2;
    let c = sweep::run_one(&other, req);
    assert!(!Arc::ptr_eq(&a, &c));
}

#[test]
fn uncached_results_equal_cached_results_by_value() {
    let cfg = Config::default();
    let sweep = Sweep::new()
        .kernel("axpy", JobSpec::Axpy { n: 96 })
        .clusters([1, 8])
        .triples();
    let cached = sweep.clone().run(&cfg);
    let uncached = sweep.uncached().run(&cfg);
    assert_eq!(cached, uncached);
}

#[test]
fn triple_helper_matches_grid_results() {
    let cfg = Config::default();
    let spec = JobSpec::Atax { m: 32, n: 32 };
    let t = sweep::triple(&cfg, &spec, 8);
    let results = Sweep::new()
        .kernel("atax", spec)
        .clusters([8])
        .triples()
        .run(&cfg);
    let grid_t = results.triple_of("atax", 8).expect("triple in grid");
    assert_eq!(t.base, grid_t.base);
    assert_eq!(t.ideal, grid_t.ideal);
    assert_eq!(t.improved, grid_t.improved);
    assert!(t.ideal <= t.improved && t.improved <= t.base);
}

#[test]
fn group_by_partitions_a_mixed_grid() {
    let cfg = Config::default();
    let results = Sweep::new()
        .kernel("axpy", JobSpec::Axpy { n: 64 })
        .kernel("atax", JobSpec::Atax { m: 16, n: 16 })
        .clusters([1, 4])
        .routines([RoutineKind::Multicast])
        .run(&cfg);
    let by_label = results.group_by(|r| r.label());
    assert_eq!(by_label.len(), 2);
    assert_eq!(by_label[0].0, "axpy");
    assert_eq!(by_label[0].1.len(), 2);
    let by_cluster = results.group_by(|r| r.req().n_clusters);
    assert_eq!(by_cluster.len(), 2);
    assert_eq!(by_cluster[0].0, 1);
}
