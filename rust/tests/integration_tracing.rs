//! End-to-end tests of the distributed-tracing surface: span-merged
//! Perfetto exports are byte-identical across runs, recorded span trees
//! stay well-formed across seeded serve bursts, and a worker subprocess
//! that panics (or bails mid-shard) leaves a flight-recorder dump that
//! `occamy trace flight` renders.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicU64, Ordering};

use occamy_offload::config::Config;
use occamy_offload::obs::{self, SpanRecord};
use occamy_offload::serve::{Engine, EngineOptions, Request, Submit};

/// The occamy binary built for this test run.
fn occamy_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_occamy"))
}

/// Unique scratch directory per call (tests run in parallel).
fn temp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "occamy-tracing-it-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Unique timing offset per test so the process-wide cache never
/// aliases across parallel tests (the campaign test idiom).
fn cfg_with_gap(gap: u64) -> Config {
    let mut cfg = Config::default();
    cfg.timing.host_ipi_issue_gap = gap;
    cfg
}

fn submit(id: u64, kernel: &str, clusters: usize, gap: u64) -> Submit {
    Submit {
        id,
        kernel: kernel.into(),
        clusters: Some(clusters),
        routine: None,
        gap: Some(gap),
        seed: None,
        traceparent: None,
    }
}

/// Run a seeded burst through an in-process engine and return the event
/// lines it logged, filtered by the burst's id prefix (other tests in
/// this binary share the process-wide in-memory ring).
fn burst_lines(cfg_gap: u64, inflight: usize, ids: std::ops::Range<u64>, kernel: &str) -> Vec<String> {
    obs::log::init(obs::log::EventLog::in_memory());
    let mut e = Engine::new(EngineOptions {
        cfg: cfg_with_gap(cfg_gap),
        inflight,
        ..EngineOptions::default()
    })
    .unwrap();
    let prefix = format!("\"id\":{}", ids.start / 1000);
    for (k, id) in ids.clone().enumerate() {
        e.handle(&Request::Submit(submit(id, kernel, 4, (k as u64) * 60)));
    }
    obs::log::recent().into_iter().filter(|l| l.contains(&prefix)).collect()
}

#[test]
fn span_merged_export_is_byte_identical_across_runs() {
    let lines = burst_lines(9401, 2, 991_000..991_004, "axpy:288");
    let spans: Vec<SpanRecord> =
        lines.iter().filter_map(|l| SpanRecord::parse(l)).collect();
    assert!(
        spans.iter().any(|s| s.name == "request"),
        "the burst recorded request spans: {lines:?}"
    );

    let dir = temp_dir("export");
    let log_path = dir.join("spans.jsonl");
    std::fs::write(&log_path, lines.join("\n") + "\n").unwrap();

    let export = |out: &Path| {
        let output = Command::new(occamy_exe())
            .args(["trace", "export", "--batch", "4", "--inflight", "2"])
            .arg("--out")
            .arg(out)
            .arg("--spans")
            .arg(&log_path)
            .output()
            .unwrap();
        assert!(
            output.status.success(),
            "trace export failed: {}",
            String::from_utf8_lossy(&output.stderr)
        );
        std::fs::read(out).unwrap()
    };
    let a = export(&dir.join("a.json"));
    let b = export(&dir.join("b.json"));
    assert_eq!(a, b, "span-merged export is byte-identical across runs");

    let text = String::from_utf8(a).unwrap();
    assert!(text.contains("request lane 0"), "recorded request lane present");
    assert!(text.contains("detail lane 0"), "queue/execute child lane present");
    assert!(text.contains("\"cat\":\"request\""), "request spans carry their category");
}

#[test]
fn recorded_span_trees_stay_well_formed_across_seeded_bursts() {
    let mut spans: Vec<SpanRecord> = Vec::new();
    for b in 0..3u64 {
        let kernel = format!("axpy:{}", 320 + 32 * b);
        // Distinct thousands per burst: the id prefix is the ring filter.
        let base = 992_000 + 1_000 * b;
        let lines = burst_lines(9411 + b, 1 + b as usize, base..(base + 5), &kernel);
        spans.extend(lines.iter().filter_map(|l| SpanRecord::parse(l)));
    }
    // Without a traceparent each admitted request roots its own trace,
    // so the whole recorded set must already form complete trees.
    obs::span::check_trees(&spans).unwrap();
    let requests: Vec<&SpanRecord> = spans.iter().filter(|s| s.name == "request").collect();
    assert!(requests.len() >= 3, "several bursts admitted requests: {}", requests.len());
    for req in requests {
        assert_eq!(req.parent, None, "self-rooted without a traceparent");
        let queue = spans
            .iter()
            .find(|s| s.name == "queue" && s.trace == req.trace && s.parent == Some(req.span))
            .expect("every request span has a queue child");
        let execute = spans
            .iter()
            .find(|s| s.name == "execute" && s.trace == req.trace && s.parent == Some(req.span))
            .expect("every request span has an execute child");
        // queue + execute tile the request exactly: arrival -> dispatch
        // -> completion on the virtual-cycle clock.
        assert_eq!(queue.cycle, req.cycle);
        assert_eq!(queue.end().map(|e| Some(e) == execute.cycle), Some(true));
        assert_eq!(execute.end(), req.end());
    }
}

/// Write a small campaign spec for the subprocess tests; two points so
/// `--max-points 1` always stops mid-shard.
fn write_spec(dir: &Path, tag: &str, gap: u64) -> PathBuf {
    let path = dir.join("campaign.toml");
    let text = format!(
        "[campaign]\nname = \"tracing-it-{tag}\"\n\n[grid]\nkernels = [\"axpy:96\"]\n\
         clusters = [1, 2]\nroutines = [\"baseline\"]\n\n\
         [timing]\nhost_ipi_issue_gap = {gap}\n"
    );
    std::fs::write(&path, text).unwrap();
    path
}

#[test]
fn a_panicking_worker_leaves_a_renderable_flight_dump() {
    let dir = temp_dir("panic");
    let spec = write_spec(&dir, "panic", 9421);
    let out = dir.join("out");
    let output = Command::new(occamy_exe())
        .args(["campaign", "run"])
        .arg("--spec")
        .arg(&spec)
        .arg("--out")
        .arg(&out)
        .env("OCCAMY_CHAOS_PANIC", "1")
        .output()
        .unwrap();
    assert!(!output.status.success(), "the chaos hook panics the worker");

    // The panic hook dumped the flight ring next to the store.
    let flight = out.join("store").join("flight");
    let dumps: Vec<PathBuf> = std::fs::read_dir(&flight)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with("panic-"))
        })
        .collect();
    assert_eq!(dumps.len(), 1, "exactly one panic dump: {dumps:?}");

    // `occamy trace flight` renders it, both directly and via --store.
    let rendered = Command::new(occamy_exe())
        .args(["trace", "flight"])
        .arg("--dump")
        .arg(&dumps[0])
        .output()
        .unwrap();
    assert!(rendered.status.success());
    let text = String::from_utf8(rendered.stdout).unwrap();
    assert!(text.contains("reason: panic"), "{text}");
    assert!(text.contains("chaos_panic"), "the noted event survived: {text}");

    let via_store = Command::new(occamy_exe())
        .args(["trace", "flight"])
        .arg("--store")
        .arg(out.join("store"))
        .output()
        .unwrap();
    assert!(via_store.status.success());
    assert!(String::from_utf8(via_store.stdout).unwrap().contains("reason: panic"));
}

#[test]
fn a_mid_shard_bail_leaves_an_incomplete_flight_dump() {
    let dir = temp_dir("bail");
    let spec = write_spec(&dir, "bail", 9423);
    let out = dir.join("out");
    let output = Command::new(occamy_exe())
        .args(["campaign", "run", "--max-points", "1"])
        .arg("--spec")
        .arg(&spec)
        .arg("--out")
        .arg(&out)
        .output()
        .unwrap();
    assert!(!output.status.success(), "--max-points stops the shard mid-way");
    assert!(
        String::from_utf8_lossy(&output.stderr).contains("incomplete"),
        "{}",
        String::from_utf8_lossy(&output.stderr)
    );

    let flight = out.join("store").join("flight");
    let dumps: Vec<PathBuf> = std::fs::read_dir(&flight)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with("incomplete-"))
        })
        .collect();
    assert_eq!(dumps.len(), 1, "exactly one incomplete dump: {dumps:?}");
    let text = obs::flight::render_dump(&dumps[0]).unwrap();
    assert!(text.contains("reason: incomplete"), "{text}");
    assert!(text.contains("shard_incomplete"), "the bail event was noted: {text}");
}
