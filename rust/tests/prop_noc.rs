//! Property tests of the multicast NoC (§4.2): the mask-encoded address
//! sets, the paper's one-line match rule, and two-level XBAR routing.

mod prop_util;

use occamy_offload::config::Config;
use occamy_offload::noc::{MaskedAddr, NarrowNoc};
use occamy_offload::rng::Rng64;
use prop_util::prop;

const STRIDE: u64 = 0x40000;

fn random_subcube(rng: &mut Rng64, max_bits: u32) -> Vec<usize> {
    // A subcube of the 5-bit cluster index space: pick don't-care bits
    // and a base agreeing on the fixed bits.
    let n_dc = rng.gen_range_usize(0, max_bits as usize + 1);
    let mut dc_bits: Vec<u32> = (0..5).collect();
    // shuffle-ish: pick n_dc distinct bits
    let mut mask = 0usize;
    for _ in 0..n_dc {
        loop {
            let b = dc_bits[rng.gen_range_usize(0, dc_bits.len())];
            if mask & (1 << b) == 0 {
                mask |= 1 << b;
                break;
            }
        }
    }
    let base = rng.gen_range_usize(0, 32) & !mask;
    let mut out = Vec::new();
    let bits: Vec<usize> = (0..5).filter(|b| mask >> b & 1 == 1).collect();
    for combo in 0..(1usize << bits.len()) {
        let mut v = base;
        for (i, b) in bits.iter().enumerate() {
            if combo >> i & 1 == 1 {
                v |= 1 << b;
            }
        }
        out.push(v);
    }
    out.sort_unstable();
    out
}

#[test]
fn prop_encode_decode_roundtrip() {
    // Any subcube of cluster indices encodes to a masked address that
    // expands back to exactly those clusters' addresses.
    prop(200, |rng| {
        let clusters = random_subcube(rng, 5);
        let offset = (rng.gen_range_usize(0, (STRIDE / 8) as usize) as u64) * 8;
        let m = MaskedAddr::for_clusters(0, STRIDE, offset, &clusters)
            .expect("subcube must encode");
        assert_eq!(m.cardinality() as usize, clusters.len());
        let got = m.expand();
        let want: Vec<u64> = clusters
            .iter()
            .map(|&c| c as u64 * STRIDE + offset)
            .collect();
        assert_eq!(got, want);
    });
}

#[test]
fn prop_match_rule_equals_set_intersection() {
    // The paper's single-line match condition is exactly non-empty
    // intersection of the two masked sets.
    prop(500, |rng| {
        let a = MaskedAddr {
            addr: rng.next_u64() & 0xFFFF,
            mask: rng.next_u64() & 0xFFF,
        };
        let b = MaskedAddr {
            addr: rng.next_u64() & 0xFFFF,
            mask: rng.next_u64() & 0xFFF,
        };
        let brute = a.expand().into_iter().any(|x| b.contains(x));
        assert_eq!(a.matches(&b), brute, "a={a:?} b={b:?}");
    });
}

#[test]
fn prop_match_is_symmetric() {
    prop(500, |rng| {
        let a = MaskedAddr {
            addr: rng.next_u64(),
            mask: rng.next_u64() & 0xFFFF_FFFF,
        };
        let b = MaskedAddr {
            addr: rng.next_u64(),
            mask: rng.next_u64() & 0xFFFF_FFFF,
        };
        assert_eq!(a.matches(&b), b.matches(&a));
    });
}

#[test]
fn prop_unicast_routes_to_owning_cluster() {
    // Every concrete address inside the cluster window routes to exactly
    // the cluster that owns it, on both baseline and multicast NoCs.
    let cfg = Config::default();
    let base = NarrowNoc::new(&cfg, false);
    let mcast = NarrowNoc::new(&cfg, true);
    prop(300, |rng| {
        let c = rng.gen_range_usize(0, 32);
        let offset = rng.next_u64() % STRIDE;
        let req = MaskedAddr::unicast(c as u64 * STRIDE + offset);
        assert_eq!(base.route_clusters(req).unwrap(), vec![c]);
        assert_eq!(mcast.route_clusters(req).unwrap(), vec![c]);
    });
}

#[test]
fn prop_two_level_decode_equals_expansion() {
    // Routing a masked request through the two-level XBAR tree reaches
    // exactly the clusters whose addresses the mask encodes.
    let cfg = Config::default();
    let noc = NarrowNoc::new(&cfg, true);
    prop(300, |rng| {
        let clusters = random_subcube(rng, 5);
        let offset = (rng.gen_range_usize(0, (STRIDE / 8) as usize) as u64) * 8;
        let m = MaskedAddr::for_clusters(0, STRIDE, offset, &clusters).unwrap();
        let got = noc.route_clusters(m).unwrap();
        assert_eq!(got, clusters);
    });
}

#[test]
fn prop_encode_first_n_minimal_and_exact() {
    // The greedy prefix decomposition uses exactly popcount(n) masked
    // writes and covers exactly [0, n) with no duplicates.
    let cfg = Config::default();
    let noc = NarrowNoc::new(&cfg, true);
    prop(100, |rng| {
        let n = rng.gen_range_usize(1, 33);
        let msgs = noc.encode_first_n(n, 0x10);
        assert_eq!(msgs.len() as u32, n.count_ones());
        let mut all = Vec::new();
        for m in &msgs {
            all.extend(noc.route_clusters(*m).unwrap());
        }
        let len_before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), len_before, "no cluster hit twice");
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    });
}
