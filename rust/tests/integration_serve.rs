//! Integration tests of the serve daemon over real TCP: concurrent
//! sessions against the serial DES reference, explicit overload
//! shedding, warm-store memoization through the load generator, input
//! robustness, and graceful drain — the PR's acceptance criteria.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use occamy_offload::config::Config;
use occamy_offload::offload::RoutineKind;
use occamy_offload::serve::{EngineOptions, LoadgenOptions, Reply, Request, Server, Submit};
use occamy_offload::sweep::OffloadRequest;

/// Unique timing offset per test so the process-wide trace cache and
/// store fingerprints never alias across parallel tests.
fn cfg_with_gap(gap: u64) -> Config {
    let mut cfg = Config::default();
    cfg.timing.host_ipi_issue_gap = gap;
    cfg
}

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone().unwrap();
        Client {
            writer,
            reader: BufReader::new(stream),
        }
    }

    /// One lockstep exchange with a well-formed request.
    fn exchange(&mut self, req: &Request) -> Reply {
        self.send_raw(&format!("{}\n", req.to_line()))
    }

    /// Write raw bytes (well-formed or not) and read one reply line.
    fn send_raw(&mut self, bytes: &str) -> Reply {
        self.writer.write_all(bytes.as_bytes()).unwrap();
        self.writer.flush().unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Reply::from_line(line.trim()).unwrap()
    }
}

fn submit(id: u64, kernel: &str, clusters: usize, gap: u64) -> Request {
    Request::Submit(Submit {
        id,
        kernel: kernel.to_string(),
        clusters: Some(clusters),
        routine: Some(RoutineKind::Multicast),
        gap: Some(gap),
        seed: None,
        traceparent: None,
    })
}

fn shut_down(addr: SocketAddr) {
    let mut c = Client::connect(addr);
    match c.exchange(&Request::Shutdown) {
        Reply::ShuttingDown { .. } => {}
        other => panic!("expected shutting-down, got {other:?}"),
    }
}

#[test]
fn concurrent_sessions_match_the_serial_des_reference() {
    let cfg = cfg_with_gap(9501);
    // The serial reference: each request shape's isolated DES total.
    // Contention may delay a job, but can never change its own cycles.
    let shapes = [
        ("axpy:1024", 8usize),
        ("matmul:16", 4),
        ("atax:64x64", 8),
        ("montecarlo:4096", 4),
    ];
    let reference: Vec<u64> = shapes
        .iter()
        .map(|(kernel, n)| {
            let spec = occamy_offload::campaign::spec::parse_kernel(kernel).unwrap();
            OffloadRequest::new(spec, *n, RoutineKind::Multicast).run(&cfg).total
        })
        .collect();

    let server = Server::start(
        EngineOptions {
            cfg,
            inflight: 4,
            ..EngineOptions::default()
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.addr();
    let mut handles = Vec::new();
    for (t, (kernel, clusters)) in shapes.iter().enumerate() {
        let kernel = kernel.to_string();
        let clusters = *clusters;
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            (0..8u64)
                .map(|i| {
                    // Wide gaps keep admission open; interleaving with
                    // the other sessions is still arbitrary.
                    match c.exchange(&submit(t as u64 * 100 + i, &kernel, clusters, 1_000_000)) {
                        Reply::Result(r) => r.cycles,
                        other => panic!("expected result, got {other:?}"),
                    }
                })
                .collect::<Vec<u64>>()
        }));
    }
    for (t, h) in handles.into_iter().enumerate() {
        let totals = h.join().unwrap();
        assert!(
            totals.iter().all(|&c| c == reference[t]),
            "session {t}: cycles {totals:?} diverge from the serial reference {}",
            reference[t]
        );
    }
    let mut c = Client::connect(addr);
    match c.exchange(&Request::Stats) {
        Reply::Stats(s) => {
            assert_eq!(s.completed, 32, "{s:?}");
            assert_eq!(s.rejected, 0, "{s:?}");
            assert_eq!(s.errors, 0, "{s:?}");
        }
        other => panic!("expected stats, got {other:?}"),
    }
    shut_down(addr);
    server.wait();
}

#[test]
fn overload_sheds_with_an_explicit_reply_and_never_hangs() {
    // inflight 1 x queue_factor 1: one job outstanding is the bound. A
    // gap-0 burst never advances the clock, so nothing retires and
    // every job after the first must be rejected — immediately.
    let server = Server::start(
        EngineOptions {
            cfg: cfg_with_gap(9503),
            inflight: 1,
            queue_factor: 1,
            ..EngineOptions::default()
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.addr();
    let mut c = Client::connect(addr);
    let first = c.exchange(&submit(0, "axpy:512", 4, 0));
    assert!(matches!(first, Reply::Result(_)), "{first:?}");
    for i in 1..6 {
        match c.exchange(&submit(i, "axpy:512", 4, 0)) {
            Reply::Rejected(r) => {
                assert_eq!(r.reason, "overloaded");
                assert_eq!((r.backlog, r.bound), (1, 1));
            }
            other => panic!("expected rejection, got {other:?}"),
        }
    }
    shut_down(addr);
    let (stats, _, _) = server.wait();
    assert_eq!((stats.completed, stats.rejected), (1, 5));
}

#[test]
fn a_warm_store_serves_bursts_with_zero_fresh_simulations() {
    let root = std::env::temp_dir().join(format!("occamy-serve-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let opts = || EngineOptions {
        cfg: cfg_with_gap(9505),
        store_root: Some(root.clone()),
        ..EngineOptions::default()
    };
    let burst = |addr: SocketAddr| LoadgenOptions {
        addr: addr.to_string(),
        requests: 24,
        seed: 7,
        shutdown: true,
        ..LoadgenOptions::default()
    };

    let cold = Server::start(opts(), "127.0.0.1:0").unwrap();
    let cold_report = occamy_offload::serve::loadgen::run(&burst(cold.addr())).unwrap();
    cold.wait();
    assert_eq!(cold_report.failures, 0, "{cold_report:?}");
    let cold_stats = cold_report.stats.as_ref().unwrap();
    assert!(cold_stats.fresh_sims > 0, "cold store must simulate: {cold_stats:?}");
    // The store actually persisted traces to disk.
    let fp = occamy_offload::campaign::store::fingerprint(&cfg_with_gap(9505));
    let traces = occamy_offload::campaign::store::traces_in(&root, &fp);
    assert!(traces > 0, "no traces persisted under {}", root.join(&fp).display());

    // Identical burst against a fresh daemon over the same store: every
    // request is answered from memoization, none simulate.
    let warm = Server::start(opts(), "127.0.0.1:0").unwrap();
    let warm_report = occamy_offload::serve::loadgen::run(&burst(warm.addr())).unwrap();
    warm.wait();
    assert_eq!(warm_report.failures, 0, "{warm_report:?}");
    let warm_stats = warm_report.stats.as_ref().unwrap();
    assert_eq!(warm_stats.fresh_sims, 0, "warm store must not simulate: {warm_stats:?}");
    assert!(warm_stats.hits > 0, "{warm_stats:?}");
    // The stats reply carries the latency percentiles...
    assert!(warm_stats.latency.count > 0, "{warm_stats:?}");
    assert!(
        warm_stats.latency.p50 <= warm_stats.latency.p95
            && warm_stats.latency.p95 <= warm_stats.latency.p99
            && warm_stats.latency.p99 <= warm_stats.latency.max,
        "{warm_stats:?}"
    );
    // ...and virtual time makes the runs reproducible: same seed, same
    // schedule, same latencies — warm or cold.
    assert_eq!(
        cold_report.latency.quantiles(&[0.50, 0.95, 0.99]),
        warm_report.latency.quantiles(&[0.50, 0.95, 0.99])
    );
    assert_eq!(cold_report.completed, warm_report.completed);
}

#[test]
fn garbage_and_torn_lines_never_kill_the_daemon() {
    let server = Server::start(
        EngineOptions {
            cfg: cfg_with_gap(9507),
            ..EngineOptions::default()
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.addr();
    let mut bad = Client::connect(addr);
    for junk in [
        "\u{1}\u{2}garbage bytes\u{3}\n",
        "{\"op\":\"sub\n",
        "{\"op\":\"frobnicate\"}\n",
        "[1,2,3]\n",
    ] {
        match bad.send_raw(junk) {
            Reply::Error(e) => assert_eq!(e.id, None, "{junk:?}"),
            other => panic!("expected error for {junk:?}, got {other:?}"),
        }
    }
    // The session that sent garbage still works.
    assert!(matches!(bad.exchange(&Request::Ping), Reply::Pong));

    // A torn trailing line (peer hangs up mid-request) is answered on
    // EOF, observably, without taking anything down.
    let mut torn = Client::connect(addr);
    torn.writer.write_all(b"{\"op\":\"ping\"").unwrap();
    torn.writer.flush().unwrap();
    torn.writer.shutdown(std::net::Shutdown::Write).unwrap();
    let mut line = String::new();
    torn.reader.read_line(&mut line).unwrap();
    match Reply::from_line(line.trim()).unwrap() {
        Reply::Error(e) => assert_eq!(e.id, None),
        other => panic!("expected error for the torn line, got {other:?}"),
    }

    // Fresh sessions are unaffected and the failures were all counted.
    let mut good = Client::connect(addr);
    match good.exchange(&Request::Stats) {
        Reply::Stats(s) => assert_eq!(s.errors, 5, "{s:?}"),
        other => panic!("expected stats, got {other:?}"),
    }
    assert!(matches!(
        good.exchange(&submit(1, "axpy:256", 4, 0)),
        Reply::Result(_)
    ));
    shut_down(addr);
    server.wait();
}

#[test]
fn shutdown_drains_in_flight_work_and_reports_it() {
    let server = Server::start(
        EngineOptions {
            cfg: cfg_with_gap(9509),
            inflight: 4,
            ..EngineOptions::default()
        },
        "127.0.0.1:0",
    )
    .unwrap();
    let addr = server.addr();
    let mut c = Client::connect(addr);
    for i in 0..3 {
        let reply = c.exchange(&submit(i, "axpy:512", 4, 0));
        assert!(matches!(reply, Reply::Result(_)), "{reply:?}");
    }
    // All three are still on the virtual timeline (gap 0 retired none);
    // shutdown drains them and says so.
    match c.exchange(&Request::Shutdown) {
        Reply::ShuttingDown { drained } => assert_eq!(drained, 3),
        other => panic!("expected shutting-down, got {other:?}"),
    }
    let (stats, _, summary) = server.wait();
    assert_eq!(stats.completed, 3);
    assert!(summary.contains("3 done"), "{summary}");
}
