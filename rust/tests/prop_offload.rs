//! Property tests of the offload executor: ordering, determinism and
//! conservation invariants over randomized (kernel, size, clusters)
//! configurations, exercised through the typed `sweep` API.

mod prop_util;

use occamy_offload::config::Config;
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::{self, OffloadRequest};
use prop_util::{choose, prop, random_spec};

#[test]
fn prop_runtime_ordering_ideal_improved_base() {
    // For every configuration: ideal <= improved <= base (the extensions
    // help, and nothing beats skipping the offload phases entirely).
    let cfg = Config::default();
    prop(60, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 2, 3, 4, 8, 12, 16, 32]);
        let t = sweep::triple(&cfg, &spec, n);
        assert!(t.ideal <= t.improved, "{spec:?}@{n}: {t:?}");
        assert!(t.improved <= t.base, "{spec:?}@{n}: {t:?}");
    });
}

#[test]
fn prop_deterministic_replay() {
    // Two *uncached* runs of the same request are bit-identical (the
    // cached path would make this trivially true).
    let cfg = Config::default();
    prop(30, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 5, 8, 32]);
        let routine = *choose(
            rng,
            &[
                RoutineKind::Baseline,
                RoutineKind::Multicast,
                RoutineKind::Ideal,
            ],
        );
        let req = OffloadRequest::new(spec, n, routine);
        let a = req.run(&cfg);
        let b = req.run(&cfg);
        assert_eq!(a.total, b.total);
        assert_eq!(a.events, b.events);
        for c in 0..n {
            assert_eq!(a.cluster_spans[c], b.cluster_spans[c]);
        }
    });
}

#[test]
fn prop_phase_pipeline_order_per_cluster() {
    // Per cluster, phases must not start before the previous one ended:
    // B.end <= C.start <= C.end <= D.start ... (pipeline order, Fig. 3).
    let cfg = Config::default();
    let order = [
        Phase::Wakeup,
        Phase::RetrievePtr,
        Phase::RetrieveArgs,
        Phase::RetrieveOperands,
        Phase::Execute,
        Phase::Writeback,
        Phase::Notify,
    ];
    prop(40, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 2, 8, 32]);
        let routine = *choose(rng, &[RoutineKind::Baseline, RoutineKind::Multicast]);
        let t = sweep::run_one(&cfg, OffloadRequest::new(spec, n, routine));
        for c in 0..n {
            let spans = &t.cluster_spans[c];
            let mut prev_end = 0;
            for p in order {
                if let Some(s) = spans.get(&p) {
                    assert!(
                        s.start >= prev_end,
                        "{spec:?}@{n} {} cluster {c}: {p:?} starts {} before {}",
                        routine.name(),
                        s.start,
                        prev_end
                    );
                    assert!(s.end >= s.start);
                    prev_end = s.end;
                }
            }
        }
    });
}

#[test]
fn prop_total_covers_all_spans() {
    // The reported total is >= the end of every recorded span.
    let cfg = Config::default();
    prop(40, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 4, 16, 32]);
        let routine = *choose(rng, &[RoutineKind::Baseline, RoutineKind::Multicast]);
        let t = sweep::run_one(&cfg, OffloadRequest::new(spec, n, routine));
        for c in 0..n {
            for (p, s) in &t.cluster_spans[c] {
                assert!(
                    s.end <= t.total,
                    "{spec:?}@{n}: {p:?} on {c} ends {} after total {}",
                    s.end,
                    t.total
                );
            }
        }
    });
}

#[test]
fn prop_overhead_positive_for_offloaded_runs() {
    // base - ideal > 0 always: offloading can never be free.
    let cfg = Config::default();
    prop(40, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 2, 8, 16, 32]);
        let t = sweep::triple(&cfg, &spec, n);
        assert!(t.overhead() > 0, "{spec:?}@{n}: overhead {}", t.overhead());
        assert!(t.residual_overhead() > 0);
    });
}

#[test]
fn prop_more_clusters_never_helps_broadcast_ideal() {
    // For the broadcast class (ATAX/Cov/BFS) the *ideal* runtime is
    // monotonically non-decreasing beyond the minimum, reflecting the
    // n-linear operand term (Eq. 6) — checked on ATAX.
    let cfg = Config::default();
    prop(20, |rng| {
        let s = *choose(rng, &[32u64, 64, 128]);
        let spec = JobSpec::Atax { m: s, n: s };
        let t8 = sweep::run_one(&cfg, OffloadRequest::new(spec, 8, RoutineKind::Ideal)).total;
        let t32 = sweep::run_one(&cfg, OffloadRequest::new(spec, 32, RoutineKind::Ideal)).total;
        assert!(t32 >= t8, "ATAX {s}: ideal {t8} -> {t32}");
    });
}

#[test]
fn prop_timing_config_scaling_sanity() {
    // Doubling the baseline IPI gap can only increase baseline runtime
    // and must not affect multicast runs.
    let cfg = Config::default();
    let mut slow = cfg.clone();
    slow.timing.host_ipi_issue_gap *= 2;
    prop(20, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[2usize, 8, 32]);
        let base = |c: &Config| {
            sweep::run_one(c, OffloadRequest::new(spec, n, RoutineKind::Baseline)).total
        };
        let b_fast = base(&cfg);
        let b_slow = base(&slow);
        // A few cycles of arbitration jitter are possible when shifted
        // arrivals happen to dodge a port conflict; anything more than
        // that would be a real inversion.
        assert!(
            b_slow + 8 >= b_fast,
            "{spec:?}@{n}: {b_fast} -> {b_slow}"
        );
        let mcast = |c: &Config| {
            sweep::run_one(c, OffloadRequest::new(spec, n, RoutineKind::Multicast)).total
        };
        assert_eq!(
            mcast(&cfg),
            mcast(&slow),
            "{spec:?}@{n}: multicast must not depend on the IPI gap"
        );
    });
}

#[test]
fn prop_fluid_port_ablation_preserves_ordering() {
    // With the fluid-PS ablation port, the ordering invariants still
    // hold (only the skew structure changes).
    let mut cfg = Config::default();
    cfg.soc.wide_port_fluid = true;
    prop(20, |rng| {
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 4, 16]);
        let t = sweep::triple(&cfg, &spec, n);
        assert!(t.ideal <= t.improved && t.improved <= t.base, "{spec:?}@{n}: {t:?}");
    });
}
