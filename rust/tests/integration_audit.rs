//! End-to-end tests of the determinism-domain auditor: planted
//! violations are found at exact `path:line`, pragmas suppress (and
//! malformed pragmas are themselves findings), unclassified modules are
//! rejected, the report renders byte-identically across runs, and —
//! the actual gate — the crate's own sources scan clean under the
//! built-in manifest.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use occamy_offload::analysis::{self, rules, Manifest};

fn occamy<S: AsRef<std::ffi::OsStr>>(args: &[S], cwd: Option<&Path>) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_occamy"));
    cmd.args(args);
    if let Some(dir) = cwd {
        cmd.current_dir(dir);
    }
    cmd.output().expect("spawn occamy")
}

/// A scratch tree with one planted fixture per rule plus an
/// unclassified module; returns (root, manifest text).
fn plant_fixtures(tag: &str) -> (PathBuf, String) {
    let dir = std::env::temp_dir().join(format!("occamy-audit-it-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    let src = dir.join("src");
    fs::create_dir_all(&src).unwrap();

    let sim_src = concat!(
        "use std::collections::HashMap;\n",
        "pub fn f(x: &std::sync::atomic::AtomicU64) -> f64 {\n",
        "    let _t = std::time::Instant::now();\n",
        "    let _e = std::env::var(\"SEED\");\n",
        "    let m: HashMap<u32, f64> = HashMap::new();\n",
        "    for (_k, _v) in m.iter() {}\n",
        "    x.store(1, Ordering::Relaxed);\n",
        "    m.values().sum::<f64>()\n",
        "}\n",
    );
    fs::write(src.join("simmod.rs"), sim_src).unwrap();

    let wall_src = concat!(
        "pub fn stop(f: &std::sync::atomic::AtomicBool) {\n",
        "    let _t = std::time::Instant::now();\n",
        "    f.store(true, Ordering::SeqCst);\n",
        "}\n",
    );
    fs::write(src.join("wallmod.rs"), wall_src).unwrap();

    let pragma_src = concat!(
        "pub fn f() {\n",
        "    // audit:allow(entropy-in-sim) -- fixture: seed comes from the env\n",
        "    let _a = std::env::var(\"A\");\n",
        "    // audit:allow(entropy-in-sim)\n",
        "    let _b = std::env::var(\"B\");\n",
        "}\n",
    );
    fs::write(src.join("pragmamod.rs"), pragma_src).unwrap();

    fs::write(src.join("mystery.rs"), "pub fn nothing() {}\n").unwrap();

    let manifest = concat!(
        "[modules]\n",
        "pragmamod = \"sim\"\n",
        "simmod = \"sim\"\n",
        "wallmod = \"wall\"\n",
    );
    (dir, manifest.to_string())
}

/// (file-name suffix, line, rule) triples of a report, for compact
/// comparison against the planted expectations.
fn triples(report: &analysis::Report) -> Vec<(String, usize, &'static str)> {
    let mut out = Vec::new();
    for f in &report.findings {
        let name = f.path.rsplit('/').next().unwrap_or(&f.path).to_string();
        out.push((name, f.line, f.rule));
    }
    out
}

#[test]
fn planted_violations_are_found_at_exact_lines() {
    let (dir, manifest) = plant_fixtures("planted");
    let m = Manifest::parse(&manifest).unwrap();
    let report = analysis::audit_paths(&m, &[dir.join("src")]).unwrap();

    let expected: Vec<(String, usize, &'static str)> = vec![
        ("mystery.rs".to_string(), 1, rules::UNKNOWN_MODULE),
        ("pragmamod.rs".to_string(), 4, rules::BAD_PRAGMA),
        ("pragmamod.rs".to_string(), 5, rules::ENTROPY_IN_SIM),
        ("simmod.rs".to_string(), 3, rules::WALL_CLOCK_IN_SIM),
        ("simmod.rs".to_string(), 4, rules::ENTROPY_IN_SIM),
        ("simmod.rs".to_string(), 6, rules::UNORDERED_ITERATION),
        ("simmod.rs".to_string(), 7, rules::RELAXED_ORDERING),
        ("simmod.rs".to_string(), 8, rules::FLOAT_REDUCTION_ORDER),
        ("simmod.rs".to_string(), 8, rules::UNORDERED_ITERATION),
        ("wallmod.rs".to_string(), 3, rules::RELAXED_ORDERING),
    ];
    assert_eq!(triples(&report), expected, "{}", analysis::render_text(&report));
    // The valid pragma silenced exactly one finding; 4 files scanned.
    assert_eq!(report.suppressed, 1);
    assert_eq!(report.files, 4);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn reports_are_byte_identical_across_runs() {
    let (dir, manifest) = plant_fixtures("stable");
    let m = Manifest::parse(&manifest).unwrap();
    let a = analysis::audit_paths(&m, &[dir.join("src")]).unwrap();
    let b = analysis::audit_paths(&m, &[dir.join("src")]).unwrap();
    assert_eq!(analysis::render_json(&a), analysis::render_json(&b));
    assert_eq!(analysis::render_text(&a), analysis::render_text(&b));
    let _ = fs::remove_dir_all(&dir);
}

/// The gate: this repository's own sources must scan clean under the
/// built-in manifest. A new finding here means either fix the code or
/// justify it with an `audit:allow(<rule>) -- reason` pragma.
#[test]
fn self_scan_of_crate_sources_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = analysis::audit_paths(&Manifest::builtin(), &[src]).unwrap();
    assert!(report.findings.is_empty(), "\n{}", analysis::render_text(&report));
    assert!(report.files > 40, "expected the whole tree, scanned {}", report.files);
}

#[test]
fn cli_deny_gates_on_findings() {
    let (dir, manifest) = plant_fixtures("cli-deny");
    let manifest_path = dir.join("analysis.toml");
    fs::write(&manifest_path, &manifest).unwrap();
    let src = dir.join("src");
    let margs = ["--manifest", manifest_path.to_str().unwrap()];

    // Without --deny: findings are reported but the exit is zero.
    let out = occamy(&["audit", margs[0], margs[1], src.to_str().unwrap()], None);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(stdout.contains("wall-clock-in-sim"), "{stdout}");
    assert!(stdout.contains("simmod.rs:3:"), "{stdout}");
    assert!(stdout.contains("file(s) scanned"), "{stdout}");

    // With --deny: same report, nonzero exit.
    let out = occamy(&["audit", "--deny", margs[0], margs[1], src.to_str().unwrap()], None);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("finding(s)"));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn cli_json_is_byte_identical_across_runs() {
    let (dir, manifest) = plant_fixtures("cli-json");
    let manifest_path = dir.join("analysis.toml");
    fs::write(&manifest_path, &manifest).unwrap();
    let src = dir.join("src");
    let args = [
        "audit",
        "--json",
        "--manifest",
        manifest_path.to_str().unwrap(),
        src.to_str().unwrap(),
    ];
    let a = occamy(&args, None);
    let b = occamy(&args, None);
    assert!(a.status.success() && b.status.success());
    assert_eq!(a.stdout, b.stdout, "JSON report must be byte-deterministic");
    let text = String::from_utf8_lossy(&a.stdout).into_owned();
    assert_eq!(text.lines().count(), 1, "single-line JSON: {text}");
    assert!(text.starts_with('{'), "{text}");
    assert!(text.contains("\"unordered-iteration\""), "{text}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn cli_self_scan_passes_under_deny() {
    // From the crate directory, `audit --deny` resolves `src` and must
    // exit zero — the same invocation CI runs from the repo root.
    let crate_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let out = occamy(&["audit", "--deny"], Some(crate_dir));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(out.status.success(), "{stdout}{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("audit: 0 finding(s)"), "{stdout}");
}

#[test]
fn cli_rejects_unknown_flags_and_bad_paths() {
    let out = occamy(&["audit", "--frobnicate"], None);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag(s)"));

    let out = occamy(&["audit", "definitely/not/a/dir"], None);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("does not exist"));
}
