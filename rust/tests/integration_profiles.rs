//! Differential bit-identity harness for the engine-profile seam: the
//! `fast` profile (heap elision, same-cycle batch drains, memoized
//! timelines; `sim::fast`) must be *byte-identical* to the `reference`
//! event-heap DES on every observable — full traces span-for-span,
//! event accounting, and the f64 phase statistics compared through
//! `to_bits`, so even a last-ulp drift fails.
//!
//! This is the gate that lets every caller (`sweep`, `campaign`,
//! `serve`, the CLI) treat `--profile fast` as a pure go-faster knob.

mod prop_util;

use occamy_offload::config::Config;
use occamy_offload::exp;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sim::{fast, Phase, SimProfile, Trace};
use occamy_offload::sweep::OffloadRequest;
use prop_util::{choose, prop, random_spec};

/// Assert the two traces are byte-identical: whole-struct equality
/// (`Trace` compares every span bit-for-bit) plus an explicit
/// `f64::to_bits` pass over the per-phase statistics, which is where a
/// reassociated floating-point average would hide from `==` on totals.
fn assert_bit_identical(reference: &Trace, fast_t: &Trace, what: &str) {
    assert_eq!(reference, fast_t, "{what}: trace mismatch");
    assert_eq!(reference.total, fast_t.total, "{what}: total");
    assert_eq!(reference.events, fast_t.events, "{what}: events");
    for p in Phase::ALL {
        match (reference.stats(p), fast_t.stats(p)) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.min, b.min, "{what}: {p:?} min");
                assert_eq!(a.max, b.max, "{what}: {p:?} max");
                assert_eq!(a.n, b.n, "{what}: {p:?} n");
                assert_eq!(
                    a.avg.to_bits(),
                    b.avg.to_bits(),
                    "{what}: {p:?} avg {} vs {}",
                    a.avg,
                    b.avg
                );
            }
            (a, b) => panic!("{what}: {p:?} present in one profile only ({a:?} vs {b:?})"),
        }
        assert_eq!(
            reference.host_duration(p),
            fast_t.host_duration(p),
            "{what}: {p:?} host duration"
        );
    }
}

fn run_both(cfg: &Config, req: OffloadRequest, what: &str) {
    let reference = req.run_with(cfg, SimProfile::Reference);
    let fast_t = req.run_with(cfg, SimProfile::Fast);
    assert_bit_identical(&reference, &fast_t, what);
}

#[test]
fn full_kernel_grid_is_bit_identical_across_profiles() {
    // Every kernel of the benchmark set x the geometry grid x the three
    // figure routines — the exact surface the experiments and the serve
    // engine run on.
    let cfg = Config::default();
    for (label, spec) in exp::benchmark_set() {
        for n in [1usize, 2, 8, 32] {
            for routine in [
                RoutineKind::Baseline,
                RoutineKind::Ideal,
                RoutineKind::Multicast,
            ] {
                run_both(
                    &cfg,
                    OffloadRequest::new(spec, n, routine),
                    &format!("{label}@{n} {}", routine.name()),
                );
            }
        }
    }
}

#[test]
fn ablation_routines_are_bit_identical_across_profiles() {
    // The mcast-only/jcu-only ablations take different event paths
    // (one extension enabled at a time) — cover all five routines.
    let cfg = Config::default();
    for (label, spec) in exp::benchmark_set() {
        for routine in RoutineKind::ALL {
            run_both(
                &cfg,
                OffloadRequest::new(spec, 8, routine),
                &format!("{label}@8 {}", routine.name()),
            );
        }
    }
}

#[test]
fn seeded_random_configs_are_bit_identical_across_profiles() {
    // Randomized (spec, geometry, routine, timing) points: perturbed
    // timing parameters shift every event's arrival cycle, and the
    // fluid-port ablation swaps the arbitration model — the fast
    // engine must track all of it exactly, not just the default config.
    prop(24, |rng| {
        let mut cfg = Config::default();
        cfg.timing.host_ipi_issue_gap = 1 + rng.gen_range_usize(0, 40) as u64;
        cfg.timing.cluster_wake = 1 + rng.gen_range_usize(0, 300) as u64;
        cfg.timing.dma_roundtrip = 1 + rng.gen_range_usize(0, 200) as u64;
        cfg.timing.tcdm_service = 1 + rng.gen_range_usize(0, 4) as u64;
        cfg.soc.wide_port_fluid = rng.gen_range_usize(0, 2) == 1;
        let spec = random_spec(rng);
        let n = *choose(rng, &[1usize, 2, 3, 8, 16, 32]);
        let routine = *choose(rng, &RoutineKind::ALL);
        run_both(
            &cfg,
            OffloadRequest::new(spec, n, routine),
            &format!("random {spec:?}@{n} {}", routine.name()),
        );
    });
}

#[test]
fn memoized_timeline_replays_are_bit_identical() {
    // A repeated fast-profile request is served from the specialized
    // timeline memo — the replay must equal both the first fast run and
    // the reference, and the memo must actually be exercised.
    let mut cfg = Config::default();
    cfg.timing.host_ipi_issue_gap = 9501; // unique memo key for this test
    let req = OffloadRequest::new(
        occamy_offload::kernels::JobSpec::Axpy { n: 704 },
        8,
        RoutineKind::Multicast,
    );
    let reference = req.run_with(&cfg, SimProfile::Reference);
    let before = fast::stats();
    let first = req.run_with(&cfg, SimProfile::Fast);
    let replay = req.run_with(&cfg, SimProfile::Fast);
    let after = fast::stats();
    assert_bit_identical(&reference, &first, "first fast run");
    assert_bit_identical(&reference, &replay, "memoized replay");
    assert!(
        after.timeline_hits > before.timeline_hits,
        "replay did not hit the timeline memo ({} -> {})",
        before.timeline_hits,
        after.timeline_hits
    );
    assert!(
        after.timeline_misses > before.timeline_misses,
        "first run did not miss the timeline memo"
    );
}

#[test]
fn fast_profile_elides_heap_work_without_changing_results() {
    // The point of the profile: identical answers for less heap work.
    // The elision counters are process-wide and strictly monotonic, so
    // with tests running in parallel only lower bounds on a delta are
    // race-free — per-run equality lives in the `sim::fast` unit tests.
    let mut cfg = Config::default();
    cfg.timing.host_ipi_issue_gap = 9502; // unique memo key for this test
    let req = OffloadRequest::new(
        occamy_offload::kernels::JobSpec::Atax { m: 64, n: 64 },
        32,
        RoutineKind::Baseline,
    );
    let reference = req.run_with(&cfg, SimProfile::Reference);
    let before = fast::stats();
    let fast_t = req.run_with(&cfg, SimProfile::Fast);
    let after = fast::stats();
    assert_bit_identical(&reference, &fast_t, "wide baseline atax");
    assert!(
        after.events_popped > before.events_popped,
        "fresh fast run dispatched no events at all"
    );
    // Replays simulate nothing; the *accounted* event total still
    // matches the reference, so downstream event metrics are stable.
    let replay = req.run_with(&cfg, SimProfile::Fast);
    assert_eq!(replay.events, reference.events, "replay event accounting");
}
