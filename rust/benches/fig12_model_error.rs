//! Bench: regenerate Fig. 12 (model validation grid) and time the
//! analytical model alone vs the simulation it is validated against.
use occamy_offload::bench::{black_box, Bench};
use occamy_offload::config::Config;
use occamy_offload::exp::fig12;
use occamy_offload::kernels::JobSpec;
use occamy_offload::model::OffloadModel;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    let model = OffloadModel::new(&cfg);
    let spec = JobSpec::Axpy { n: 1024 };
    b.run("fig12/model_estimate", 10, 100, || {
        model.estimate(black_box(&spec), 32)
    });
    b.run("fig12/validation_grid_cached", 1, 5, || fig12::run(&cfg));
    let fig = fig12::run(&cfg);
    println!("\n{}", fig12::render(&fig).render());
    println!("max relative error: {:.1}% (paper: <15%)", fig.max_error() * 100.0);
    b.finish("fig12_model_error");
}
