//! Bench: regenerate Fig. 10 (extension speedups across problem sizes).
//! The first run populates the sweep cache; the cached re-run shows the
//! memoization win.
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::fig10;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("fig10/full_sweep_cached", 1, 5, || fig10::run(&cfg));
    let fig = fig10::run(&cfg);
    println!("\n{}", fig10::render(&fig).render());
    println!("max speedup over baseline: {:.2} (paper: up to 2.3)", fig.max_speedup());
    b.finish("fig10_weak_scaling");
}
