//! Bench: the co-design ablation (multicast vs JCU contributions, port
//! arbitration) — regenerates the tables and times the sweep.
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::ablation;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("ablation/full_sweep", 1, 5, || ablation::run(&cfg));
    let a = ablation::run(&cfg);
    println!("\n{}", ablation::render(&a).render());
    println!("{}", ablation::render_port(&a).render());
    b.finish("ablation");
}
