//! Bench: the co-design ablation (multicast vs JCU contributions, port
//! arbitration) — regenerates the tables and times the five-routine
//! sweep uncached vs through the shared trace cache.
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::{ablation, benchmark_set, CLUSTER_SWEEP};
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::Sweep;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("ablation/grid_uncached", 1, 3, || {
        Sweep::over_kernels(benchmark_set())
            .clusters(CLUSTER_SWEEP)
            .routines(RoutineKind::ALL)
            .uncached()
            .run(&cfg)
    });
    b.run("ablation/full_sweep_cached", 1, 5, || ablation::run(&cfg));
    let a = ablation::run(&cfg);
    println!("\n{}", ablation::render(&a).render());
    println!("{}", ablation::render_port(&a).render());
    b.finish("ablation");
}
