//! Bench: regenerate Fig. 11 (per-phase breakdown of AXPY-1024) and time
//! single offload executions at both extremes of the sweep.
use occamy_offload::bench::{black_box, Bench};
use occamy_offload::config::Config;
use occamy_offload::exp::fig11;
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::RoutineKind;
use occamy_offload::sweep::OffloadRequest;

fn main() {
    let cfg = Config::default();
    let spec = JobSpec::Axpy { n: 1024 };
    let mut b = Bench::new();
    for routine in [RoutineKind::Baseline, RoutineKind::Multicast] {
        for n in [1usize, 32] {
            b.run(&format!("fig11/offload/{}/c{n}", routine.name()), 3, 20, || {
                OffloadRequest::new(black_box(spec), n, routine).run(&cfg)
            });
        }
    }
    b.run("fig11/full_breakdown_cached", 1, 5, || fig11::run(&cfg));
    println!("\n{}", fig11::render(&fig11::run(&cfg)).render());
    b.finish("fig11_phase_breakdown");
}
