//! Bench: regenerate Fig. 7 (offload overhead vs clusters, 6 kernels)
//! and time the grid through the sweep executor — parallel vs serial on
//! a cold cache (the tentpole claim: parallelism alone speeds up the
//! full grid), plus warm-cache re-runs and single triples.
use occamy_offload::bench::{black_box, Bench};
use occamy_offload::config::Config;
use occamy_offload::exp::{benchmark_set, fig7, CLUSTER_SWEEP};
use occamy_offload::kernels::JobSpec;
use occamy_offload::sweep::{OffloadRequest, Sweep};

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    let grid = || {
        Sweep::over_kernels(benchmark_set())
            .clusters(CLUSTER_SWEEP)
            .triples()
            .uncached()
    };
    b.run("fig7/grid_parallel_uncached", 1, 5, || grid().run(&cfg));
    b.run("fig7/grid_serial_uncached", 1, 5, || grid().serial().run(&cfg));
    // Warm path: fig7::run shares its traces process-wide.
    b.run("fig7/full_sweep_cached", 1, 5, || fig7::run(&cfg));
    for (name, spec) in [
        ("axpy1024", JobSpec::Axpy { n: 1024 }),
        ("atax64", JobSpec::Atax { m: 64, n: 64 }),
    ] {
        for n in [1usize, 32] {
            b.run(&format!("fig7/triple/{name}/c{n}"), 2, 10, || {
                OffloadRequest::triple(black_box(spec), n).map(|req| req.run(&cfg))
            });
        }
    }
    // Print the regenerated table once (the bench doubles as the harness).
    println!("\n{}", fig7::render(&fig7::run(&cfg)).render());
    b.finish("fig7_overheads");
}
