//! Bench: regenerate Fig. 7 (offload overhead vs clusters, 6 kernels)
//! and time the full sweep plus its per-kernel slices.
use occamy_offload::bench::{black_box, Bench};
use occamy_offload::config::Config;
use occamy_offload::exp::fig7;
use occamy_offload::kernels::JobSpec;
use occamy_offload::offload::run_triple;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("fig7/full_sweep", 1, 5, || fig7::run(&cfg));
    for (name, spec) in [
        ("axpy1024", JobSpec::Axpy { n: 1024 }),
        ("atax64", JobSpec::Atax { m: 64, n: 64 }),
    ] {
        for n in [1usize, 32] {
            b.run(&format!("fig7/triple/{name}/c{n}"), 2, 10, || {
                run_triple(&cfg, black_box(&spec), n)
            });
        }
    }
    // Print the regenerated table once (the bench doubles as the harness).
    println!("\n{}", fig7::render(&fig7::run(&cfg)).render());
    b.finish("fig7_overheads");
}
