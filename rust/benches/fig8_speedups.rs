//! Bench: regenerate Fig. 8 (ideal vs achieved speedups) and time it.
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::fig8;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("fig8/full_sweep", 1, 5, || fig8::run(&cfg));
    let fig = fig8::run(&cfg);
    println!("\n{}", fig8::render(&fig).render());
    println!("max ideal speedup: {:.2} (paper: 3.02)", fig.max_ideal_speedup());
    b.finish("fig8_speedups");
}
