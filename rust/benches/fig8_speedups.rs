//! Bench: regenerate Fig. 8 (ideal vs achieved speedups) and time its
//! grid uncached through the sweep executor.
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::{benchmark_set, fig8, CLUSTER_SWEEP};
use occamy_offload::sweep::Sweep;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("fig8/grid_uncached", 1, 5, || {
        Sweep::over_kernels(benchmark_set())
            .clusters(CLUSTER_SWEEP)
            .triples()
            .uncached()
            .run(&cfg)
    });
    b.run("fig8/full_sweep_cached", 1, 5, || fig8::run(&cfg));
    let fig = fig8::run(&cfg);
    println!("\n{}", fig8::render(&fig).render());
    println!("max ideal speedup: {:.2} (paper: 3.02)", fig.max_ideal_speedup());
    b.finish("fig8_speedups");
}
