//! Bench: regenerate Fig. 9 (base/ideal/improved curves, AXPY & ATAX).
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::{fig9, CLUSTER_SWEEP};
use occamy_offload::kernels::JobSpec;
use occamy_offload::sweep::Sweep;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("fig9/both_curves_uncached", 1, 10, || {
        Sweep::new()
            .kernel("axpy", JobSpec::Axpy { n: 1024 })
            .kernel("atax", JobSpec::Atax { m: 64, n: 64 })
            .clusters(CLUSTER_SWEEP)
            .triples()
            .uncached()
            .run(&cfg)
    });
    b.run("fig9/both_curves_cached", 1, 10, || fig9::run(&cfg));
    let fig = fig9::run(&cfg);
    println!("\n{}", fig9::render(&fig).render());
    println!(
        "baseline AXPY minimum at {} clusters; improved at {} (paper: improved has no interior minimum)",
        fig.axpy.argmin_base(),
        fig.axpy.argmin_improved()
    );
    b.finish("fig9_runtime_curves");
}
