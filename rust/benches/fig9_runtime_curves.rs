//! Bench: regenerate Fig. 9 (base/ideal/improved curves, AXPY & ATAX).
use occamy_offload::bench::Bench;
use occamy_offload::config::Config;
use occamy_offload::exp::fig9;

fn main() {
    let cfg = Config::default();
    let mut b = Bench::new();
    b.run("fig9/both_curves", 1, 10, || fig9::run(&cfg));
    let fig = fig9::run(&cfg);
    println!("\n{}", fig9::render(&fig).render());
    println!(
        "baseline AXPY minimum at {} clusters; improved at {} (paper: improved has no interior minimum)",
        fig.axpy.argmin_base(),
        fig.axpy.argmin_improved()
    );
    b.finish("fig9_runtime_curves");
}
