//! Microbenchmarks of the simulation substrate: event queue throughput,
//! RR/fluid port arbitration, XBAR multicast decode — the L3 hot paths
//! the §Perf pass optimizes.
use occamy_offload::bench::{black_box, Bench};
use occamy_offload::config::Config;
use occamy_offload::noc::{MaskedAddr, NarrowNoc};
use occamy_offload::sim::{EventQueue, PsPort, RrPort};

fn main() {
    let mut b = Bench::new();

    b.run("engine/queue_10k_events", 2, 20, || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule(i * 7 % 4096, i);
        }
        let mut sum = 0u64;
        while let Some((_, e)) = q.pop() {
            sum = sum.wrapping_add(e);
        }
        sum
    });

    b.run("engine/rr_port_1k_transfers", 2, 20, || {
        let mut p = RrPort::new(32);
        for i in 0..1000usize {
            p.submit(i % 32, 16);
        }
        let mut t = 0u64;
        while let Some((_, beats)) = p.try_grant() {
            t += beats;
            p.complete();
        }
        t
    });

    b.run("engine/fluid_port_256_joins", 2, 20, || {
        let mut p = PsPort::new();
        let mut now = 0;
        for i in 0..256u64 {
            p.join(now, 32);
            now += 1;
            if i % 8 == 7 {
                if let Some((t, _)) = p.next_completion(now) {
                    now = t;
                    black_box(p.collect_finished(now));
                }
            }
        }
        p.in_flight()
    });

    let cfg = Config::default();
    let noc = NarrowNoc::new(&cfg, true);
    let req = MaskedAddr { addr: 0x20, mask: 0b11111 << 18 };
    b.run("noc/two_level_multicast_decode", 10, 200, || {
        noc.route_clusters(black_box(req)).unwrap().len()
    });
    b.run("noc/encode_first_n_all", 10, 200, || {
        (1..=32usize).map(|n| noc.encode_first_n(n, 0).len()).sum::<usize>()
    });

    b.finish("engine_micro");
}
