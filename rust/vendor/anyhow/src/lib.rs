//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset of anyhow's API the workspace uses:
//! [`Error`], [`Result`], the [`Context`] trait, and the `anyhow!`,
//! `bail!` and `ensure!` macros. Like the real crate, `Error` does *not*
//! implement `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.
//!
//! Formatting matches anyhow's conventions: `{e}` prints the outermost
//! message, `{e:#}` the full `outer: inner: ...` context chain, and
//! `{e:?}` the message plus a `Caused by:` list.

use std::fmt;

/// A string-chain error type: an outermost message plus optional nested
/// context layers (outer → inner).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error in an outer context layer.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain().join(": "))
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the std source chain as context layers.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("at least the top-level message")
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and missing `Option` values).
pub trait Context<T> {
    /// Wrap the error with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")
            .context("reading the config")
            .map(|_| ())
    }

    #[test]
    fn display_and_alternate_forms() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading the config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading the config: "), "{full}");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_compose() {
        fn inner(x: u64) -> Result<u64> {
            ensure!(x < 10, "x too large: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(format!("{}", inner(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", inner(11).unwrap_err()), "x too large: 11");
        let e: Error = anyhow!("plain {}", "message");
        assert_eq!(e.to_string(), "plain message");
    }

    #[test]
    fn option_context() {
        let none: Option<u64> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn from_preserves_source_chain() {
        let parse: std::num::ParseIntError = "x".parse::<u64>().unwrap_err();
        let e = Error::from(parse);
        assert!(!e.chain().is_empty());
    }
}
