//! Fig. 7: offload overhead (base − ideal runtime) per application, for a
//! variable number of accelerator clusters (§5.2). Declarative sweep over
//! the benchmark set — the traces are shared with Figs. 8-10 through the
//! sweep cache.

use crate::config::Config;
use crate::sim::SimProfile;
use crate::sweep::{mean_std, Sweep, SweepResults};

use super::table::Table;
use super::{benchmark_set, CLUSTER_SWEEP};

/// One measured point.
#[derive(Debug, Clone)]
pub struct Point {
    pub kernel: &'static str,
    pub n_clusters: usize,
    pub overhead: i64,
}

/// The full figure's data.
#[derive(Debug, Clone)]
pub struct Fig7 {
    pub points: Vec<Point>,
}

impl Fig7 {
    pub fn overhead(&self, kernel: &str, n: usize) -> Option<i64> {
        self.points
            .iter()
            .find(|p| p.kernel == kernel && p.n_clusters == n)
            .map(|p| p.overhead)
    }

    /// Mean and population std-dev of the overhead across applications at
    /// a fixed cluster count (the paper reports 242±65 at one cluster and
    /// a 256-cycle std-dev at 32). `None` when no point matches — a
    /// cluster count outside the sweep must not surface as NaN.
    pub fn stats_at(&self, n: usize) -> Option<(f64, f64)> {
        mean_std(
            self.points
                .iter()
                .filter(|p| p.n_clusters == n)
                .map(|p| p.overhead as f64),
        )
    }

    /// Maximum overhead across the sweep (paper: 1146 cycles).
    pub fn max_overhead(&self) -> i64 {
        self.points.iter().map(|p| p.overhead).max().unwrap_or(0)
    }
}

/// The sweep this figure needs — also the grid a campaign spec must
/// cover to render it from merged output.
pub fn sweep() -> Sweep {
    Sweep::over_kernels(benchmark_set())
        .clusters(CLUSTER_SWEEP)
        .triples()
}

/// Build the figure from pre-computed results (e.g. merged campaign
/// output). Only triples on the figure's own grid (the benchmark set at
/// the cluster sweep) are taken — a superset campaign must not skew the
/// mean/std aggregates; triples absent from the results are simply
/// absent points.
pub fn from_results(results: &SweepResults) -> Fig7 {
    let set = benchmark_set();
    let points = results
        .triples()
        .into_iter()
        .filter(|t| {
            CLUSTER_SWEEP.contains(&t.n_clusters)
                && set.iter().any(|(l, s)| *l == t.label && *s == t.spec)
        })
        .map(|t| Point {
            kernel: t.label,
            n_clusters: t.n_clusters,
            overhead: t.runtimes.overhead(),
        })
        .collect();
    Fig7 { points }
}

pub fn run(cfg: &Config) -> Fig7 {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`); `fast` is bit-identical to `reference`.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Fig7 {
    from_results(&sweep().profile(profile).run(cfg))
}

pub fn render(fig: &Fig7) -> Table {
    let mut t = Table::new(
        "Fig. 7 — offload overhead (cycles) vs number of clusters",
        &["kernel", "1", "2", "4", "8", "16", "32"],
    );
    for (name, _) in benchmark_set() {
        let mut row = vec![name.to_string()];
        for &n in &CLUSTER_SWEEP {
            row.push(fig.overhead(name, n).unwrap().to_string());
        }
        t.row(row);
    }
    let (m1, s1) = fig.stats_at(1).expect("cluster count 1 in sweep");
    let (m32, s32) = fig.stats_at(32).expect("cluster count 32 in sweep");
    let mut stats = vec!["mean±std".to_string()];
    stats.push(format!("{m1:.0}±{s1:.0}"));
    for _ in 0..4 {
        stats.push(String::new());
    }
    stats.push(format!("{m32:.0}±{s32:.0}"));
    t.row(stats);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_aggregates() {
        let fig = run(&Config::default());
        // §5.2: single-cluster average 242 (σ=65); we accept the σ band.
        let (mean1, _) = fig.stats_at(1).unwrap();
        assert!(
            (242.0 - mean1).abs() < 65.0,
            "single-cluster mean {mean1} vs paper 242±65"
        );
        // §5.2: maximum overhead 1146 cycles; same order here.
        let max = fig.max_overhead();
        assert!(
            (800..=1500).contains(&max),
            "max overhead {max} vs paper 1146"
        );
        // Overhead grows from 1 to 32 clusters for every application.
        for (name, _) in benchmark_set() {
            let o1 = fig.overhead(name, 1).unwrap();
            let o32 = fig.overhead(name, 32).unwrap();
            assert!(o32 > o1, "{name}: {o1} -> {o32}");
        }
    }

    #[test]
    fn stats_at_unswept_cluster_count_is_none() {
        // Regression: this used to divide by zero and return NaN.
        let fig = run(&Config::default());
        assert_eq!(fig.stats_at(3), None);
        assert_eq!(Fig7 { points: vec![] }.stats_at(1), None);
    }

    #[test]
    fn renders_all_kernels() {
        let fig = run(&Config::default());
        let table = render(&fig);
        assert_eq!(table.rows.len(), 7); // 6 kernels + stats row
    }
}
