//! Fig. 10: speedup of the extensions over the baseline for various
//! problem sizes (weak scaling) and cluster counts (§5.4).

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::sim::SimProfile;
use crate::sweep::{Sweep, SweepResults};

use super::table::{f, Table};

/// Clusters used for the three curves of each kernel.
pub const CURVES: [usize; 3] = [8, 16, 32];
/// Problem sizes on the x-axis. The paper compares curves at shared
/// x-points ("at the 512 point ... 16 clusters vs 32"), so sizes are
/// absolute: N for AXPY, the matrix edge M=N for ATAX.
pub const AXPY_SIZES: [u64; 3] = [512, 1024, 4096];
pub const ATAX_SIZES: [u64; 3] = [64, 128, 512];

#[derive(Debug, Clone)]
pub struct Point {
    pub kernel: &'static str,
    pub n_clusters: usize,
    pub size: u64,
    /// base / improved runtime.
    pub speedup: f64,
}

#[derive(Debug, Clone)]
pub struct Fig10 {
    pub points: Vec<Point>,
}

impl Fig10 {
    pub fn get(&self, kernel: &str, n: usize, size: u64) -> Option<&Point> {
        self.points
            .iter()
            .find(|p| p.kernel == kernel && p.n_clusters == n && p.size == size)
    }

    pub fn max_speedup(&self) -> f64 {
        self.points.iter().map(|p| p.speedup).fold(0.0, f64::max)
    }
}

/// The sweep this figure needs. One label per kernel, several specs per
/// label: the problem size rides along in the spec and is recovered
/// from each triple.
pub fn sweep() -> Sweep {
    let mut sweep = Sweep::new().clusters(CURVES).triples();
    for &size in &AXPY_SIZES {
        sweep = sweep.kernel("axpy", JobSpec::Axpy { n: size });
    }
    for &size in &ATAX_SIZES {
        sweep = sweep.kernel("atax", JobSpec::Atax { m: size, n: size });
    }
    sweep
}

/// Build the figure from pre-computed results (e.g. merged campaign
/// output). Only triples on the figure's own grid (its sizes at the
/// curve cluster counts) are taken, so a superset campaign renders
/// correctly.
pub fn from_results(results: &SweepResults) -> Fig10 {
    let points = results
        .triples()
        .into_iter()
        .filter_map(|t| {
            if !CURVES.contains(&t.n_clusters) {
                return None;
            }
            let size = match t.spec {
                JobSpec::Axpy { n } if AXPY_SIZES.contains(&n) => n,
                JobSpec::Atax { m, n } if m == n && ATAX_SIZES.contains(&m) => m,
                _ => return None,
            };
            Some(Point {
                kernel: t.label,
                n_clusters: t.n_clusters,
                size,
                speedup: t.runtimes.achieved_speedup(),
            })
        })
        .collect();
    Fig10 { points }
}

pub fn run(cfg: &Config) -> Fig10 {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`); `fast` is bit-identical to `reference`.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Fig10 {
    from_results(&sweep().profile(profile).run(cfg))
}

pub fn render(fig: &Fig10) -> Table {
    let mut t = Table::new(
        "Fig. 10 — speedup of extensions over baseline vs problem size",
        &["kernel", "clusters", "size_lo", "size_mid", "size_hi"],
    );
    for (kernel, sizes) in [("axpy", AXPY_SIZES), ("atax", ATAX_SIZES)] {
        for &n in &CURVES {
            let mut row = vec![kernel.to_string(), n.to_string()];
            for &size in &sizes {
                row.push(f(fig.get(kernel, n, size).unwrap().speedup, 2));
            }
            t.row(row);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_always_greater_than_one() {
        // §5.4: "we observe a speedup greater than one in all
        // experiments" — the extensions never hurt.
        let fig = run(&Config::default());
        for p in &fig.points {
            assert!(
                p.speedup > 1.0,
                "{}@{}x{}: speedup {}",
                p.kernel,
                p.n_clusters,
                p.size,
                p.speedup
            );
        }
    }

    #[test]
    fn speedup_decreases_with_problem_size() {
        // §5.4: fine-grained jobs benefit the most.
        let fig = run(&Config::default());
        for (kernel, sizes) in [("axpy", AXPY_SIZES), ("atax", ATAX_SIZES)] {
            for &n in &CURVES {
                let lo = fig.get(kernel, n, sizes[0]).unwrap().speedup;
                let hi = fig.get(kernel, n, sizes[2]).unwrap().speedup;
                assert!(
                    lo > hi,
                    "{kernel}@{n}: speedup should fall with size ({lo} vs {hi})"
                );
            }
        }
    }

    #[test]
    fn axpy_speedup_grows_with_clusters_at_fixed_size() {
        // §5.4: "For any fixed problem size, the speedup of the AXPY
        // kernel ... increases as we offload to a larger number of
        // clusters".
        let fig = run(&Config::default());
        for &size in &AXPY_SIZES {
            let s8 = fig.get("axpy", 8, size).unwrap().speedup;
            let s32 = fig.get("axpy", 32, size).unwrap().speedup;
            if size <= 1024 {
                assert!(s32 > s8, "axpy size {size}: {s8} -> {s32}");
            } else {
                // At 4096 the baseline's wakeup stagger is fully absorbed
                // by the saturated SPM port (§5.2's second-order effect),
                // flattening the gain.
                assert!(s32 >= s8, "axpy size {size}: {s8} -> {s32}");
            }
        }
    }

    #[test]
    fn atax_trend_inverts_at_large_sizes() {
        // §5.4: "At the 512 point, we observe a higher speedup in the 16
        // clusters configuration than the 32 clusters."
        let fig = run(&Config::default());
        let s16 = fig.get("atax", 16, 512).unwrap().speedup;
        let s32 = fig.get("atax", 32, 512).unwrap().speedup;
        assert!(s16 >= s32, "atax@512: 16cl {s16} vs 32cl {s32}");
    }

    #[test]
    fn max_speedup_near_paper_claim() {
        // Paper headline: up to 2.3x. Accept the same order.
        let fig = run(&Config::default());
        let m = fig.max_speedup();
        assert!((1.8..=3.2).contains(&m), "max speedup {m} vs paper 2.3");
    }
}
