//! Fig. 8: ideal speedup (white bars) vs speedup achieved with the
//! extensions (colored fill), per application and offload configuration
//! (§5.3, §5.4).

use crate::config::Config;
use crate::sim::SimProfile;
use crate::sweep::{Sweep, SweepResults};

use super::table::{f, Table};
use super::{benchmark_set, CLUSTER_SWEEP};

#[derive(Debug, Clone)]
pub struct Point {
    pub kernel: &'static str,
    pub n_clusters: usize,
    pub ideal_speedup: f64,
    pub achieved_speedup: f64,
    pub restored: f64,
}

#[derive(Debug, Clone)]
pub struct Fig8 {
    pub points: Vec<Point>,
}

impl Fig8 {
    pub fn get(&self, kernel: &str, n: usize) -> Option<&Point> {
        self.points
            .iter()
            .find(|p| p.kernel == kernel && p.n_clusters == n)
    }

    pub fn max_ideal_speedup(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.ideal_speedup)
            .fold(0.0, f64::max)
    }
}

/// The sweep this figure needs.
pub fn sweep() -> Sweep {
    Sweep::over_kernels(benchmark_set())
        .clusters(CLUSTER_SWEEP)
        .triples()
}

/// Build the figure from pre-computed results (e.g. merged campaign
/// output). Only triples on the figure's own grid are taken, so a
/// superset campaign renders correctly.
pub fn from_results(results: &SweepResults) -> Fig8 {
    let set = benchmark_set();
    let points = results
        .triples()
        .into_iter()
        .filter(|t| {
            CLUSTER_SWEEP.contains(&t.n_clusters)
                && set.iter().any(|(l, s)| *l == t.label && *s == t.spec)
        })
        .map(|t| Point {
            kernel: t.label,
            n_clusters: t.n_clusters,
            ideal_speedup: t.runtimes.ideal_speedup(),
            achieved_speedup: t.runtimes.achieved_speedup(),
            restored: t.runtimes.restored_fraction(),
        })
        .collect();
    Fig8 { points }
}

pub fn run(cfg: &Config) -> Fig8 {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`); `fast` is bit-identical to `reference`.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Fig8 {
    from_results(&sweep().profile(profile).run(cfg))
}

pub fn render(fig: &Fig8) -> Table {
    let mut t = Table::new(
        "Fig. 8 — ideal vs achieved speedup (ideal/achieved/restored)",
        &["kernel", "1", "2", "4", "8", "16", "32"],
    );
    for (name, _) in benchmark_set() {
        let mut row = vec![name.to_string()];
        for &n in &CLUSTER_SWEEP {
            let p = fig.get(name, n).unwrap();
            row.push(format!(
                "{}/{}/{}",
                f(p.ideal_speedup, 2),
                f(p.achieved_speedup, 2),
                f(p.restored, 2)
            ));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_application_classes_emerge() {
        // §5.3: AXPY/MC/Matmul speedups grow with clusters; ATAX/Cov/BFS
        // stay near-constant.
        let fig = run(&Config::default());
        for k in ["axpy", "montecarlo", "matmul"] {
            let s1 = fig.get(k, 1).unwrap().ideal_speedup;
            let s32 = fig.get(k, 32).unwrap().ideal_speedup;
            assert!(s32 > s1 + 0.5, "{k}: {s1} -> {s32} should grow");
        }
        for k in ["atax", "covariance", "bfs"] {
            let s32 = fig.get(k, 32).unwrap().ideal_speedup;
            assert!(s32 < 1.4, "{k}: ideal speedup {s32} should be small");
        }
    }

    #[test]
    fn max_speedup_matches_paper_order() {
        // Paper: up to 3.02x on a 32-cluster Matmul. Same order here.
        let fig = run(&Config::default());
        let max = fig.max_ideal_speedup();
        assert!((2.0..=3.6).contains(&max), "max ideal speedup {max}");
    }

    #[test]
    fn amdahl_class_restores_70_to_90_percent() {
        // §5.4: "within 70% and 90% of the ideally attainable speedups"
        // for AXPY, Monte Carlo and Matmul.
        let fig = run(&Config::default());
        for k in ["axpy", "montecarlo", "matmul"] {
            for &n in &[8usize, 16, 32] {
                let r = fig.get(k, n).unwrap().restored;
                assert!((0.65..=1.0).contains(&r), "{k}@{n}: restored {r}");
            }
        }
        // §5.4: ATAX class within 85-96%.
        for k in ["atax", "covariance", "bfs"] {
            let r = fig.get(k, 32).unwrap().restored;
            assert!(r > 0.85, "{k}: restored {r}");
        }
    }
}
