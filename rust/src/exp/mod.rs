//! Experiment harness: one module per figure of the paper's evaluation
//! (§5, Figs. 7-12), plus the [`interference`] experiment measuring
//! offload latency under contention (latency vs. jobs in flight). Each
//! `run(cfg)` declares its grid as a [`crate::sweep::Sweep`] campaign
//! (parallel execution, shared trace cache) and renders the results as
//! a table; the benches under `rust/benches/` wrap these with
//! wall-clock measurement. Aggregations use `sweep::mean_std`, which
//! guards the empty case instead of emitting NaN. See DESIGN.md's
//! experiment index.

pub mod ablation;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod interference;
pub mod table;

pub use table::Table;

use crate::kernels::JobSpec;

/// The fixed benchmark set of §5.2/§5.3 (Figs. 7 and 8): one fine-grained
/// representative per kernel. The paper does not publish its exact sizes;
/// these are calibrated so the headline aggregates match (242-cycle
/// single-cluster overhead, ~1.1k max at 32 clusters, ideal speedups
/// topping out near 3x for the Amdahl class — see EXPERIMENTS.md).
pub fn benchmark_set() -> Vec<(&'static str, JobSpec)> {
    vec![
        ("axpy", JobSpec::Axpy { n: 1024 }),
        ("montecarlo", JobSpec::MonteCarlo { samples: 16384 }),
        ("matmul", JobSpec::Matmul { m: 16, n: 16, k: 16 }),
        ("atax", JobSpec::Atax { m: 64, n: 64 }),
        ("covariance", JobSpec::Covariance { m: 32, n: 64 }),
        ("bfs", JobSpec::Bfs { nodes: 64, levels: 4 }),
    ]
}

/// The cluster-count sweep used across all figures.
pub const CLUSTER_SWEEP: [usize; 6] = [1, 2, 4, 8, 16, 32];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_set_covers_all_kernels() {
        let set = benchmark_set();
        assert_eq!(set.len(), 6);
        let mut kinds: Vec<&str> = set.iter().map(|(_, s)| s.kind().name()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert_eq!(kinds.len(), 6);
    }
}
