//! Fig. 9: base, ideal and improved runtime curves of the AXPY and ATAX
//! jobs for a variable number of clusters (§5.3, §5.4).

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::RunTriple;
use crate::sim::SimProfile;
use crate::sweep::{Sweep, SweepResults};

use super::table::Table;
use super::CLUSTER_SWEEP;

#[derive(Debug, Clone)]
pub struct Curve {
    pub kernel: &'static str,
    pub triples: Vec<RunTriple>,
}

impl Curve {
    pub fn at(&self, n: usize) -> &RunTriple {
        self.triples
            .iter()
            .find(|t| t.n_clusters == n)
            .expect("cluster count in sweep")
    }

    /// Index (cluster count) of the curve's minimum base runtime — the
    /// baseline's "global minimum" the extensions eliminate (§5.4).
    pub fn argmin_base(&self) -> usize {
        self.triples
            .iter()
            .min_by_key(|t| t.base)
            .unwrap()
            .n_clusters
    }

    pub fn argmin_improved(&self) -> usize {
        self.triples
            .iter()
            .min_by_key(|t| t.improved)
            .unwrap()
            .n_clusters
    }
}

#[derive(Debug, Clone)]
pub struct Fig9 {
    pub axpy: Curve,
    pub atax: Curve,
}

/// The sweep this figure needs.
pub fn sweep() -> Sweep {
    Sweep::new()
        .kernel("axpy", JobSpec::Axpy { n: 1024 })
        .kernel("atax", JobSpec::Atax { m: 64, n: 64 })
        .clusters(CLUSTER_SWEEP)
        .triples()
}

/// Build the figure from pre-computed results (e.g. merged campaign
/// output). Each curve selects its exact spec (not just the kernel
/// label), so a campaign sweeping several problem sizes per family
/// still yields the figure's two curves; `triples()` preserves
/// expansion order, so points come back in cluster-sweep order.
pub fn from_results(results: &SweepResults) -> Fig9 {
    let curve = |kernel: &'static str, spec: JobSpec| Curve {
        kernel,
        triples: results
            .triples()
            .into_iter()
            .filter(|t| t.label == kernel && t.spec == spec)
            .map(|t| t.runtimes)
            .collect(),
    };
    Fig9 {
        axpy: curve("axpy", JobSpec::Axpy { n: 1024 }),
        atax: curve("atax", JobSpec::Atax { m: 64, n: 64 }),
    }
}

pub fn run(cfg: &Config) -> Fig9 {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`); `fast` is bit-identical to `reference`.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Fig9 {
    from_results(&sweep().profile(profile).run(cfg))
}

pub fn render(fig: &Fig9) -> Table {
    let mut t = Table::new(
        "Fig. 9 — base/ideal/improved runtimes (cycles) vs clusters",
        &["kernel", "n", "base", "ideal", "improved"],
    );
    for c in [&fig.axpy, &fig.atax] {
        for tr in &c.triples {
            t.row(vec![
                c.kernel.to_string(),
                tr.n_clusters.to_string(),
                tr.base.to_string(),
                tr.ideal.to_string(),
                tr.improved.to_string(),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_baseline_has_global_minimum_improved_does_not() {
        // §5.4: with the extensions the AXPY runtime keeps improving with
        // more clusters; the baseline curve turns back up.
        let fig = run(&Config::default());
        assert!(
            fig.axpy.argmin_base() < 32,
            "baseline min at {} should be interior",
            fig.axpy.argmin_base()
        );
        assert_eq!(fig.axpy.argmin_improved(), 32, "improved is monotone");
        // Monotone decrease of improved runtime across the sweep.
        let imp: Vec<u64> = fig.axpy.triples.iter().map(|t| t.improved).collect();
        for w in imp.windows(2) {
            assert!(w[1] <= w[0], "improved not monotone: {imp:?}");
        }
    }

    #[test]
    fn improved_tracks_ideal_with_near_constant_offset() {
        // §5.4: improved curves track ideal "offset only by a
        // near-constant overhead centered at 185 cycles ... std dev 18".
        let fig = run(&Config::default());
        let offsets: Vec<i64> = fig
            .axpy
            .triples
            .iter()
            .chain(fig.atax.triples.iter())
            .map(|t| t.residual_overhead())
            .collect();
        let (mean, sd) = crate::sweep::mean_std(offsets.iter().map(|&o| o as f64))
            .expect("both curves are non-empty");
        assert!(
            (140.0..=240.0).contains(&mean),
            "residual mean {mean} vs paper 185"
        );
        assert!(sd < 40.0, "residual std dev {sd} vs paper 18");
    }

    #[test]
    fn atax_runtime_grows_with_clusters() {
        // §5.3: ATAX's runtime still increases with clusters (broadcast).
        let fig = run(&Config::default());
        let t4 = fig.atax.at(4).ideal;
        let t32 = fig.atax.at(32).ideal;
        assert!(t32 > t4, "atax ideal {t4} -> {t32} should grow");
    }
}
