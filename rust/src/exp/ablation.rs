//! Ablation study — beyond the paper's evaluation.
//!
//! The paper evaluates its two hardware extensions (multicast
//! interconnect, §4.2; job completion unit, §4.3) only *together*. This
//! experiment decomposes their contributions (baseline → +multicast →
//! +JCU → both) and additionally ablates the wide-SPM port arbitration
//! (transfer-granular round-robin, the Occamy model, vs fluid processor
//! sharing) — the design choices DESIGN.md calls out.

use crate::config::Config;
use crate::offload::RoutineKind;
use crate::sim::SimProfile;
use crate::sweep::Sweep;

use super::table::{f, Table};
use super::{benchmark_set, CLUSTER_SWEEP};

#[derive(Debug, Clone)]
pub struct Row {
    pub kernel: &'static str,
    pub n_clusters: usize,
    pub base: u64,
    pub mcast_only: u64,
    pub jcu_only: u64,
    pub both: u64,
    pub ideal: u64,
}

impl Row {
    /// Share of the total (base − both) improvement attributable to the
    /// multicast interconnect alone.
    pub fn mcast_share(&self) -> f64 {
        let total = self.base.saturating_sub(self.both).max(1) as f64;
        self.base.saturating_sub(self.mcast_only) as f64 / total
    }

    pub fn jcu_share(&self) -> f64 {
        let total = self.base.saturating_sub(self.both).max(1) as f64;
        self.base.saturating_sub(self.jcu_only) as f64 / total
    }
}

#[derive(Debug, Clone)]
pub struct Ablation {
    pub rows: Vec<Row>,
    /// (kernel, n, rr_total, fluid_total) for the port-arbitration study.
    pub port_rows: Vec<(&'static str, usize, u64, u64)>,
}

impl Ablation {
    pub fn get(&self, kernel: &str, n: usize) -> Option<&Row> {
        self.rows
            .iter()
            .find(|r| r.kernel == kernel && r.n_clusters == n)
    }
}

pub fn run(cfg: &Config) -> Ablation {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`); both the routine and the port-arbitration sweeps
/// run profiled, and `fast` is bit-identical to `reference`.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Ablation {
    // All five routines over the full grid; the Baseline/Ideal/Multicast
    // traces are shared with Figs. 7-10 through the sweep cache.
    let results = Sweep::over_kernels(benchmark_set())
        .clusters(CLUSTER_SWEEP)
        .routines(RoutineKind::ALL)
        .profile(profile)
        .run(cfg);
    let mut rows = Vec::new();
    for (name, _) in benchmark_set() {
        for &n in &CLUSTER_SWEEP {
            let total =
                |r: RoutineKind| results.total(name, n, r).expect("point in ablation grid");
            rows.push(Row {
                kernel: name,
                n_clusters: n,
                base: total(RoutineKind::Baseline),
                mcast_only: total(RoutineKind::McastOnly),
                jcu_only: total(RoutineKind::JcuOnly),
                both: total(RoutineKind::Multicast),
                ideal: total(RoutineKind::Ideal),
            });
        }
    }
    // The port-arbitration study runs under a modified config — a second
    // campaign, cached under its own config fingerprint.
    let mut fluid_cfg = cfg.clone();
    fluid_cfg.soc.wide_port_fluid = true;
    let fluid = Sweep::over_kernels(benchmark_set())
        .clusters([8, 32])
        .routines([RoutineKind::Multicast])
        .profile(profile)
        .run(&fluid_cfg);
    let mut port_rows = Vec::new();
    for (name, _) in benchmark_set() {
        for &n in &[8usize, 32] {
            let rr = results
                .total(name, n, RoutineKind::Multicast)
                .expect("point in ablation grid");
            let fl = fluid
                .total(name, n, RoutineKind::Multicast)
                .expect("point in fluid grid");
            port_rows.push((name, n, rr, fl));
        }
    }
    Ablation { rows, port_rows }
}

pub fn render(a: &Ablation) -> Table {
    let mut t = Table::new(
        "Ablation — per-extension runtimes (cycles) and improvement shares",
        &[
            "kernel", "n", "base", "+mcast", "+jcu", "both", "ideal", "mcast%", "jcu%",
        ],
    );
    for r in &a.rows {
        t.row(vec![
            r.kernel.to_string(),
            r.n_clusters.to_string(),
            r.base.to_string(),
            r.mcast_only.to_string(),
            r.jcu_only.to_string(),
            r.both.to_string(),
            r.ideal.to_string(),
            f(r.mcast_share() * 100.0, 0),
            f(r.jcu_share() * 100.0, 0),
        ]);
    }
    t
}

pub fn render_port(a: &Ablation) -> Table {
    let mut t = Table::new(
        "Ablation — wide-SPM port arbitration (multicast routine, cycles)",
        &["kernel", "n", "round-robin", "fluid-PS", "delta%"],
    );
    for &(k, n, rr, fl) in &a.port_rows {
        t.row(vec![
            k.to_string(),
            n.to_string(),
            rr.to_string(),
            fl.to_string(),
            f((fl as f64 - rr as f64) / rr as f64 * 100.0, 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ab() -> Ablation {
        run(&Config::default())
    }

    #[test]
    fn partial_extensions_bracket_the_full_ones() {
        // base >= {mcast_only, jcu_only} >= both >= ideal for every
        // configuration: each extension helps, neither hurts.
        for r in &ab().rows {
            assert!(r.base >= r.mcast_only, "{r:?}");
            assert!(r.base >= r.jcu_only, "{r:?}");
            assert!(r.mcast_only >= r.both, "{r:?}");
            assert!(r.jcu_only >= r.both, "{r:?}");
            assert!(r.both >= r.ideal, "{r:?}");
        }
    }

    #[test]
    fn multicast_dominates_at_scale() {
        // At 32 clusters the sequential-IPI elimination dwarfs the
        // barrier improvement: multicast alone captures most of the win.
        let a = ab();
        for k in ["axpy", "montecarlo", "matmul"] {
            let r = a.get(k, 32).unwrap();
            assert!(
                r.mcast_share() > 0.7,
                "{k}: mcast share {:.2}",
                r.mcast_share()
            );
            assert!(
                r.mcast_share() > r.jcu_share(),
                "{k}: mcast {:.2} vs jcu {:.2}",
                r.mcast_share(),
                r.jcu_share()
            );
        }
    }

    #[test]
    fn jcu_contribution_is_positive_but_small() {
        let a = ab();
        let r = a.get("axpy", 32).unwrap();
        assert!(r.jcu_share() > 0.0);
        assert!(r.jcu_share() < 0.5);
    }

    #[test]
    fn port_arbitration_fluid_never_faster() {
        // Fluid PS removes the completion skew the RR port creates, so
        // phase G collides with the tail of phase E (§5.5.G's overlap):
        // the fluid ablation is never faster, and the gap stays bounded
        // (<25% on the benchmark set). This is exactly why the RR model
        // is the default — the paper's Eq. 3 relies on the skew.
        for &(k, n, rr, fl) in &ab().port_rows {
            assert!(fl + 4 >= rr, "{k}@{n}: fluid {fl} beat rr {rr}");
            let delta = (fl as f64 - rr as f64) / rr as f64;
            assert!(delta < 0.25, "{k}@{n}: rr {rr} vs fluid {fl}");
        }
    }
}
