//! Plain-text / CSV table rendering for the experiment harness.

/// A simple column-aligned table with an optional CSV form.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Column-aligned text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (no quoting needed: cells are numeric/identifiers).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed precision, trimming to integers cleanly.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["100".into(), "2000".into(), "3".into()]);
        let s = t.render();
        assert!(s.contains("# demo"));
        assert!(s.lines().count() >= 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "a,long_header,c");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
