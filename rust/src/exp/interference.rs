//! Interference sweeps: latency vs. jobs-in-flight per kernel.
//!
//! The paper measures offload overheads one job at a time; the JCU
//! (§4.3) exists so several can be outstanding. This experiment puts
//! every kernel of the benchmark set under contention: [`JOBS_PER_POINT`]
//! identical jobs at [`CLUSTERS_PER_JOB`] clusters each, with the
//! jobs-in-flight window swept over [`INFLIGHT_SWEEP`]. Two 16-wide jobs
//! fit the 32-cluster fabric, so windows of 4 and 8 queue on clusters
//! with progressively deeper backlogs (narrow jobs would instead queue
//! on the JCU's 4 slots — both waits land in the same queueing-delay
//! component). Reported latency decomposes as
//! isolated DES cycles + mean queueing delay; the `inflight = 1` row is
//! the serial coordinator and always shows zero delay.

use crate::config::Config;
use crate::sim::SimProfile;
use crate::sweep::{InterferenceSample, Sweep};

use super::benchmark_set;
use super::table::{f, Table};
use crate::offload::RoutineKind;

/// Jobs-in-flight sweep: serial, cluster-fitting, then two contended
/// window depths.
pub const INFLIGHT_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Jobs replayed per (kernel, inflight) point.
pub const JOBS_PER_POINT: usize = 16;

/// Clusters per job: half the fabric, so contention starts at a window
/// of 3.
pub const CLUSTERS_PER_JOB: usize = 16;

/// The sweep this experiment needs — also the grid a campaign spec must
/// cover to derive it from merged output.
pub fn sweep() -> Sweep {
    Sweep::over_kernels(benchmark_set())
        .clusters([CLUSTERS_PER_JOB])
        .routines([RoutineKind::Multicast])
        .inflight(INFLIGHT_SWEEP)
}

pub fn run(cfg: &Config) -> Vec<InterferenceSample> {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`): the isolated traces come from a profiled sweep;
/// the contention replay on top of them is analytic either way.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Vec<InterferenceSample> {
    sweep().profile(profile).run_interference(cfg, JOBS_PER_POINT, 0)
}

pub fn render(samples: &[InterferenceSample]) -> Table {
    // Jobs-per-point and arrival gap are uniform across one expansion;
    // title from the data, not from this module's defaults (the same
    // renderer serves `occamy interfere` and campaign --render).
    let title = match samples.first() {
        None => "Interference — latency vs jobs in flight (cycles)".to_string(),
        Some(s) => format!(
            "Interference — latency vs jobs in flight ({} jobs{}, cycles)",
            s.point.ireq.n_jobs,
            if s.point.ireq.arrival_gap > 0 {
                format!(", arrival gap {}", s.point.ireq.arrival_gap)
            } else {
                String::new()
            }
        ),
    };
    let mut t = Table::new(
        &title,
        &[
            "kernel", "clusters", "inflight", "service", "queue_mean", "queue_max", "latency",
            "makespan",
        ],
    );
    for s in samples {
        let o = &s.outcome;
        t.row(vec![
            s.point.label.to_string(),
            s.point.ireq.req.n_clusters.to_string(),
            s.point.ireq.inflight.to_string(),
            o.isolated.to_string(),
            f(o.mean_queue_delay(), 0),
            o.max_queue_delay().to_string(),
            f(o.mean_latency(), 0),
            o.makespan.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_rows_show_zero_delay_and_contended_rows_do_not() {
        let samples = run(&Config::default());
        assert_eq!(samples.len(), benchmark_set().len() * INFLIGHT_SWEEP.len());
        for s in &samples {
            let o = &s.outcome;
            assert_eq!(o.n_jobs(), JOBS_PER_POINT);
            // Decomposition: latency = isolated + nonnegative delay.
            assert!(o.mean_latency() >= o.isolated as f64);
            match s.point.ireq.inflight {
                1 => assert_eq!(o.total_queue_delay(), 0, "{}", s.point.label),
                4 | 8 => assert!(o.total_queue_delay() > 0, "{}", s.point.label),
                _ => {}
            }
        }
    }

    #[test]
    fn queueing_delay_is_monotone_in_the_window() {
        let samples = run(&Config::default());
        for (label, _) in benchmark_set() {
            let delays: Vec<u64> = INFLIGHT_SWEEP
                .iter()
                .map(|&w| {
                    samples
                        .iter()
                        .find(|s| s.point.label == label && s.point.ireq.inflight == w)
                        .unwrap()
                        .outcome
                        .total_queue_delay()
                })
                .collect();
            for pair in delays.windows(2) {
                assert!(pair[1] >= pair[0], "{label}: {delays:?}");
            }
        }
    }

    #[test]
    fn renders_every_row_with_the_actual_parameters() {
        let samples = run(&Config::default());
        let table = render(&samples);
        assert_eq!(table.rows.len(), samples.len());
        assert!(table.to_csv().contains("axpy,16,1,"));
        assert!(table.title.contains("16 jobs"), "{}", table.title);
        // The title reflects the samples, not this module's defaults.
        let small = sweep().run_interference(&Config::default(), 3, 7);
        let t = render(&small);
        assert!(t.title.contains("3 jobs"), "{}", t.title);
        assert!(t.title.contains("arrival gap 7"), "{}", t.title);
        assert!(render(&[]).title.contains("Interference"));
    }
}
