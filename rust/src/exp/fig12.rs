//! Fig. 12: relative error of the offloaded-application runtime models,
//! |t − t̂| / t, across problem sizes and cluster counts (§5.6). The
//! grids run through `model::validate_grid`, itself a `sweep` campaign.

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::model::{validate_grid, validate_results, ValidationPoint};
use crate::offload::RoutineKind;
use crate::sim::SimProfile;
use crate::sweep::{Sweep, SweepResults};

use super::table::{f, Table};
use super::CLUSTER_SWEEP;

/// Problem sizes of the validation sweep (N for AXPY, M=N for ATAX), as
/// in the paper's Fig. 12.
pub const AXPY_SIZES: [u64; 6] = [64, 128, 256, 512, 1024, 2048];
pub const ATAX_SIZES: [u64; 5] = [16, 32, 64, 128, 256];

#[derive(Debug, Clone)]
pub struct Fig12 {
    pub axpy: Vec<ValidationPoint>,
    pub atax: Vec<ValidationPoint>,
}

impl Fig12 {
    pub fn max_error(&self) -> f64 {
        self.axpy
            .iter()
            .chain(&self.atax)
            .map(|p| p.rel_error())
            .fold(0.0, f64::max)
    }
}

pub fn run(cfg: &Config) -> Fig12 {
    let axpy_specs: Vec<JobSpec> = AXPY_SIZES.iter().map(|&n| JobSpec::Axpy { n }).collect();
    let atax_specs: Vec<JobSpec> = ATAX_SIZES
        .iter()
        .map(|&m| JobSpec::Atax { m, n: m })
        .collect();
    Fig12 {
        axpy: validate_grid(cfg, &axpy_specs, &CLUSTER_SWEEP),
        atax: validate_grid(cfg, &atax_specs, &CLUSTER_SWEEP),
    }
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`): the simulated runtimes come from a profiled sweep
/// over this figure's grid, the model estimates are recomputed inline —
/// the same construction as rendering from merged campaign output.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Fig12 {
    from_results(cfg, &sweep().profile(profile).run(cfg))
}

/// The sweep covering this figure's validation grid (Multicast only —
/// the model estimates are closed-form, recomputed at render time, not
/// simulated).
pub fn sweep() -> Sweep {
    let mut sweep = Sweep::new()
        .clusters(CLUSTER_SWEEP)
        .routines([RoutineKind::Multicast]);
    for &n in &AXPY_SIZES {
        sweep = sweep.kernel("axpy", JobSpec::Axpy { n });
    }
    for &m in &ATAX_SIZES {
        sweep = sweep.kernel("atax", JobSpec::Atax { m, n: m });
    }
    sweep
}

/// Build the figure from pre-computed results (e.g. merged campaign
/// output): the simulated runtimes come from the results' Multicast
/// records, the model estimates are recomputed inline from `cfg` (they
/// are closed-form, not simulations). Only points on the figure's
/// validation grid are taken, so a superset campaign renders correctly.
pub fn from_results(cfg: &Config, results: &SweepResults) -> Fig12 {
    let points = validate_results(cfg, results);
    let on_grid = |p: &&ValidationPoint| CLUSTER_SWEEP.contains(&p.n_clusters);
    Fig12 {
        axpy: points
            .iter()
            .filter(on_grid)
            .filter(|p| matches!(p.spec, JobSpec::Axpy { n } if AXPY_SIZES.contains(&n)))
            .cloned()
            .collect(),
        atax: points
            .iter()
            .filter(on_grid)
            .filter(|p| matches!(p.spec, JobSpec::Atax { m, n } if m == n && ATAX_SIZES.contains(&m)))
            .cloned()
            .collect(),
    }
}

pub fn render(fig: &Fig12) -> Table {
    let mut t = Table::new(
        "Fig. 12 — model relative error |t - t̂|/t (percent)",
        &["kernel", "size", "1", "2", "4", "8", "16", "32"],
    );
    let mut rows = |points: &[ValidationPoint], kernel: &str, sizes: &[u64]| {
        for &size in sizes {
            let mut row = vec![kernel.to_string(), size.to_string()];
            for &n in &CLUSTER_SWEEP {
                let p = points
                    .iter()
                    .find(|p| {
                        p.n_clusters == n
                            && match p.spec {
                                JobSpec::Axpy { n: nn } => nn == size,
                                JobSpec::Atax { m, .. } => m == size,
                                _ => false,
                            }
                    })
                    .expect("point in grid");
                row.push(f(p.rel_error() * 100.0, 1));
            }
            t.row(row);
        }
    };
    rows(&fig.axpy, "axpy", &AXPY_SIZES);
    rows(&fig.atax, "atax", &ATAX_SIZES);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_consistently_below_15_percent() {
        // The paper's validation claim over the Fig. 12 sweep.
        let fig = run(&Config::default());
        assert!(
            fig.max_error() < 0.15,
            "max model error {:.3}",
            fig.max_error()
        );
    }

    #[test]
    fn grid_is_complete() {
        let fig = run(&Config::default());
        assert_eq!(fig.axpy.len(), AXPY_SIZES.len() * CLUSTER_SWEEP.len());
        assert_eq!(fig.atax.len(), ATAX_SIZES.len() * CLUSTER_SWEEP.len());
    }
}
