//! Fig. 11: per-phase (A-I) runtime breakdown of an AXPY-1024 offload,
//! baseline vs multicast, with min/avg/max across clusters (§5.5).

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::RoutineKind;
use crate::sim::{Phase, SimProfile, Trace};
use crate::sweep::{Sweep, SweepResults};

use super::table::{f, Table};
use super::CLUSTER_SWEEP;

/// min/avg/max of one phase at one configuration.
#[derive(Debug, Clone)]
pub struct Band {
    pub phase: Phase,
    pub routine: RoutineKind,
    pub n_clusters: usize,
    pub min: u64,
    pub avg: f64,
    pub max: u64,
}

#[derive(Debug, Clone)]
pub struct Fig11 {
    pub bands: Vec<Band>,
}

impl Fig11 {
    pub fn get(&self, phase: Phase, routine: RoutineKind, n: usize) -> Option<&Band> {
        self.bands
            .iter()
            .find(|b| b.phase == phase && b.routine == routine && b.n_clusters == n)
    }
}

/// Append one trace's per-phase bands (host phases as degenerate
/// min=avg=max bands, cluster phases via [`Trace::stats`]). Public so
/// `obs::report` derives the identical statistics from stored traces.
pub fn bands_of(trace: &Trace, routine: RoutineKind, n: usize, out: &mut Vec<Band>) {
    for p in Phase::ALL {
        if p.is_host_phase() {
            if let Some(d) = trace.host_duration(p) {
                out.push(Band {
                    phase: p,
                    routine,
                    n_clusters: n,
                    min: d,
                    avg: d as f64,
                    max: d,
                });
            }
        } else if let Some(s) = trace.stats(p) {
            out.push(Band {
                phase: p,
                routine,
                n_clusters: n,
                min: s.min,
                avg: s.avg,
                max: s.max,
            });
        }
    }
}

/// The sweep this figure needs. Unlike Figs. 7-10 it consumes full
/// traces, not just totals — campaign streams carry every phase span,
/// so merged output renders it just the same.
pub fn sweep() -> Sweep {
    Sweep::new()
        .kernel("axpy", JobSpec::Axpy { n: 1024 })
        .clusters(CLUSTER_SWEEP)
        .routines([RoutineKind::Baseline, RoutineKind::Multicast])
}

/// Build the figure from pre-computed results (e.g. merged campaign
/// output). Records outside the figure's grid — other specs, the
/// ideal/ablation routines — are ignored, so a superset campaign
/// renders correctly.
pub fn from_results(results: &SweepResults) -> Fig11 {
    let mut bands = Vec::new();
    for rec in results.records() {
        if rec.req().spec != (JobSpec::Axpy { n: 1024 })
            || !matches!(
                rec.req().routine,
                RoutineKind::Baseline | RoutineKind::Multicast
            )
        {
            continue;
        }
        bands_of(&rec.trace, rec.req().routine, rec.req().n_clusters, &mut bands);
    }
    Fig11 { bands }
}

pub fn run(cfg: &Config) -> Fig11 {
    run_with(cfg, SimProfile::default())
}

/// [`run`] under an explicit engine profile (`occamy experiment
/// --profile fast`); `fast` is bit-identical to `reference`.
pub fn run_with(cfg: &Config, profile: SimProfile) -> Fig11 {
    from_results(&sweep().profile(profile).run(cfg))
}

pub fn render(fig: &Fig11) -> Table {
    let mut t = Table::new(
        "Fig. 11 — AXPY-1024 phase breakdown, min/avg/max cycles",
        &["phase", "routine", "1", "2", "4", "8", "16", "32"],
    );
    for p in Phase::ALL {
        for routine in [RoutineKind::Baseline, RoutineKind::Multicast] {
            let mut row = vec![
                format!("{} ({})", p.letter(), p.name()),
                routine.name().to_string(),
            ];
            for &n in &CLUSTER_SWEEP {
                match fig.get(p, routine, n) {
                    Some(b) => row.push(format!("{}/{}/{}", b.min, f(b.avg, 0), b.max)),
                    None => row.push("-".into()),
                }
            }
            t.row(row);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Fig11 {
        run(&Config::default())
    }

    #[test]
    fn phase_a_same_for_both_implementations() {
        // §5.5.A: "multicast and baseline perform nearly the same".
        let f = fig();
        for &n in &CLUSTER_SWEEP {
            let b = f.get(Phase::SendInfo, RoutineKind::Baseline, n).unwrap();
            let m = f.get(Phase::SendInfo, RoutineKind::Multicast, n).unwrap();
            assert!(
                (m.max as i64 - b.max as i64).abs() <= 10,
                "n={n}: A baseline {} vs multicast {}",
                b.max,
                m.max
            );
        }
    }

    #[test]
    fn phase_b_multicast_constant_baseline_linear() {
        // §5.5.B.
        let f = fig();
        let m1 = f.get(Phase::Wakeup, RoutineKind::Multicast, 1).unwrap();
        let m32 = f.get(Phase::Wakeup, RoutineKind::Multicast, 32).unwrap();
        assert_eq!(m1.max, m32.max, "multicast wakeup is n-independent");
        let b2 = f.get(Phase::Wakeup, RoutineKind::Baseline, 2).unwrap();
        let b32 = f.get(Phase::Wakeup, RoutineKind::Baseline, 32).unwrap();
        assert!(b32.max > b2.max + 500, "baseline wakeup grows linearly");
        // Minimum (first cluster) barely differs between implementations.
        assert!(b32.min <= m32.max + 5);
    }

    #[test]
    fn phase_c_steps_at_quadrant_boundary() {
        // §5.5.C: increase "in two steps" — 1->2 clusters and 4->8.
        let f = fig();
        let c = |n| f.get(Phase::RetrievePtr, RoutineKind::Baseline, n).unwrap().max;
        assert!(c(2) > c(1));
        // Within a quadrant the latency step is flat up to mild FIFO
        // contention at cluster 0's TCDM port.
        assert!(c(4) >= c(2) && c(4) - c(2) <= 15, "c2={} c4={}", c(2), c(4));
        assert!(c(8) > c(4), "crossing quadrants steps up");
        assert!(c(32) >= c(8), "no step back beyond two quadrants");
        assert!(c(32) - c(8) <= 20, "only contention, no new step");
        // Multicast: constant local access.
        let m1 = f.get(Phase::RetrievePtr, RoutineKind::Multicast, 1).unwrap();
        let m32 = f.get(Phase::RetrievePtr, RoutineKind::Multicast, 32).unwrap();
        assert_eq!(m1.max, m32.max);
    }

    #[test]
    fn phase_e_max_constant_in_multicast() {
        // §5.5.E: single SPM read port => max runtime constant (Eq. 1).
        let f = fig();
        let e4 = f
            .get(Phase::RetrieveOperands, RoutineKind::Multicast, 4)
            .unwrap();
        let e32 = f
            .get(Phase::RetrieveOperands, RoutineKind::Multicast, 32)
            .unwrap();
        let delta = (e4.max as i64 - e32.max as i64).abs();
        assert!(delta <= 130, "E max should stay near-constant: {delta}");
    }

    #[test]
    fn phase_h_multicast_constant() {
        // §4.3/§5.5.H: the JCU gives a predictable, constant phase H.
        let f = fig();
        let h1 = f.get(Phase::Notify, RoutineKind::Multicast, 1).unwrap();
        let h32 = f.get(Phase::Notify, RoutineKind::Multicast, 32).unwrap();
        assert_eq!(h1.max, h32.max);
    }

    #[test]
    fn phase_d_eliminated_by_multicast() {
        let f = fig();
        let d32 = f.get(Phase::RetrieveArgs, RoutineKind::Multicast, 32).unwrap();
        assert_eq!(d32.max, 0);
        let b32 = f.get(Phase::RetrieveArgs, RoutineKind::Baseline, 32).unwrap();
        assert!(b32.max > 0);
    }
}
