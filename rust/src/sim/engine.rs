//! Discrete-event engine: a monotonic event queue with stable FIFO
//! tie-breaking, the substitute for the paper's QuestaSim RTL simulation
//! kernel (§5.1). Time is in cycles of the 1 GHz system clock (1 cy = 1 ns).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in clock cycles.
pub type Time = u64;

#[derive(Debug)]
struct Entry<E> {
    time: Time,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first, and
        // FIFO order among same-cycle events.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Priority event queue. Events of equal timestamp pop in insertion order,
/// which makes simulations deterministic and arbitration fair by
/// construction (the paper's interconnect is designed for fairness, §5.5.E).
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: Time,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
            popped: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events dispatched so far.
    pub fn dispatched(&self) -> u64 {
        self.popped
    }

    /// Schedule `event` at absolute time `at`. Scheduling in the past is a
    /// simulator bug and panics.
    pub fn schedule(&mut self, at: Time, event: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past: {} < {} ({} events pending)",
            at,
            self.now,
            self.heap.len()
        );
        self.heap.push(Entry {
            time: at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` `delay` cycles from now.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        self.schedule(self.now + delay, event);
    }

    /// Pop the next event, advancing time. Monotonicity is asserted in
    /// release builds too: the differential profile harness relies on the
    /// reference engine loudly rejecting ordering bugs rather than
    /// silently rewinding the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let e = self.heap.pop()?;
        assert!(
            e.time >= self.now,
            "event popped out of order: {} < {} ({} events pending)",
            e.time,
            self.now,
            self.heap.len()
        );
        self.now = e.time;
        self.popped += 1;
        Some((e.time, e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(10, "c");
        q.schedule(5, "a");
        q.schedule(7, "b");
        assert_eq!(q.pop(), Some((5, "a")));
        assert_eq!(q.pop(), Some((7, "b")));
        assert_eq!(q.pop(), Some((10, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_cycle_is_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(42, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((42, i)));
        }
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule(3, ());
        q.pop();
        assert_eq!(q.now(), 3);
        q.schedule_in(4, ());
        assert_eq!(q.pop(), Some((7, ())));
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule(5, ());
        q.pop();
        q.schedule(1, ());
    }
}
