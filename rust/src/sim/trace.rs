//! Trace instrumentation — the mcycle-CSR equivalent (§5.1).
//!
//! The paper instruments program segments with single-cycle `mcycle` reads
//! and reconstructs phase runtimes from simulation timestamps. Here the
//! executor records a [`PhaseSpan`] per (cluster, phase) plus the
//! host-side spans, and [`Trace`] computes the min/avg/max statistics that
//! Fig. 11 plots.

use std::collections::BTreeMap;


use super::engine::Time;

/// The nine phases of the offload process (§4.1, Fig. 3).
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash,
)]
pub enum Phase {
    /// A) Send job information (host).
    SendInfo,
    /// B) Wakeup.
    Wakeup,
    /// C) Retrieve job pointer.
    RetrievePtr,
    /// D) Retrieve job arguments.
    RetrieveArgs,
    /// E) Retrieve job operands.
    RetrieveOperands,
    /// F) Job execution.
    Execute,
    /// G) Writeback job outputs.
    Writeback,
    /// H) Notify job completion.
    Notify,
    /// I) Resume operation on host.
    Resume,
}

impl Phase {
    /// All phases in pipeline order.
    pub const ALL: [Phase; 9] = [
        Phase::SendInfo,
        Phase::Wakeup,
        Phase::RetrievePtr,
        Phase::RetrieveArgs,
        Phase::RetrieveOperands,
        Phase::Execute,
        Phase::Writeback,
        Phase::Notify,
        Phase::Resume,
    ];

    /// Paper letter (A..I).
    pub fn letter(&self) -> char {
        match self {
            Phase::SendInfo => 'A',
            Phase::Wakeup => 'B',
            Phase::RetrievePtr => 'C',
            Phase::RetrieveArgs => 'D',
            Phase::RetrieveOperands => 'E',
            Phase::Execute => 'F',
            Phase::Writeback => 'G',
            Phase::Notify => 'H',
            Phase::Resume => 'I',
        }
    }

    /// Inverse of [`Phase::letter`] — used by the campaign trace codec.
    pub fn from_letter(c: char) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.letter() == c)
    }

    /// Human-readable name as in Fig. 3.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::SendInfo => "Send job information",
            Phase::Wakeup => "Wakeup",
            Phase::RetrievePtr => "Retrieve job pointer",
            Phase::RetrieveArgs => "Retrieve job arguments",
            Phase::RetrieveOperands => "Retrieve job operands",
            Phase::Execute => "Job execution",
            Phase::Writeback => "Writeback job outputs",
            Phase::Notify => "Notify job completion",
            Phase::Resume => "Resume operation on host",
        }
    }

    /// True for the phases that run on CVA6 only.
    pub fn is_host_phase(&self) -> bool {
        matches!(self, Phase::SendInfo | Phase::Resume)
    }
}

/// A measured [start, end) interval, in cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseSpan {
    pub start: Time,
    pub end: Time,
}

impl PhaseSpan {
    pub fn new(start: Time, end: Time) -> Self {
        assert!(end >= start, "span ends before it starts: {start}..{end}");
        Self { start, end }
    }

    pub fn duration(&self) -> Time {
        self.end - self.start
    }
}

/// min/avg/max of a phase duration across clusters (Fig. 11's bands).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseStats {
    pub min: Time,
    pub max: Time,
    pub avg: f64,
    pub n: usize,
}

/// Full execution trace of one offloaded job. `PartialEq` compares every
/// span bit-for-bit — the sweep executor's determinism tests rely on it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Per-cluster spans: `cluster_spans[c][phase]`.
    pub cluster_spans: Vec<BTreeMap<Phase, PhaseSpan>>,
    /// Host-side spans (A and I; B's host part is folded into B).
    pub host_spans: BTreeMap<Phase, PhaseSpan>,
    /// End-to-end runtime: 0 to host-resume end (offloaded runs) or to the
    /// last cluster writeback (ideal runs).
    pub total: Time,
    /// Events the engine dispatched (perf accounting).
    pub events: u64,
}

impl Trace {
    pub fn new(n_clusters: usize) -> Self {
        Self {
            cluster_spans: vec![BTreeMap::new(); n_clusters],
            ..Default::default()
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.cluster_spans.len()
    }

    /// Record a per-cluster phase span.
    pub fn record(&mut self, cluster: usize, phase: Phase, span: PhaseSpan) {
        let prev = self.cluster_spans[cluster].insert(phase, span);
        debug_assert!(prev.is_none(), "phase {phase:?} recorded twice on {cluster}");
    }

    /// Record a host phase span.
    pub fn record_host(&mut self, phase: Phase, span: PhaseSpan) {
        self.host_spans.insert(phase, span);
    }

    /// min/avg/max duration of `phase` across clusters; `None` if no
    /// cluster ran it.
    pub fn stats(&self, phase: Phase) -> Option<PhaseStats> {
        let durs: Vec<Time> = self
            .cluster_spans
            .iter()
            .filter_map(|m| m.get(&phase))
            .map(|s| s.duration())
            .collect();
        if durs.is_empty() {
            return None;
        }
        Some(PhaseStats {
            min: *durs.iter().min().unwrap(),
            max: *durs.iter().max().unwrap(),
            avg: durs.iter().sum::<Time>() as f64 / durs.len() as f64,
            n: durs.len(),
        })
    }

    /// Duration of a host phase.
    pub fn host_duration(&self, phase: Phase) -> Option<Time> {
        self.host_spans.get(&phase).map(|s| s.duration())
    }

    /// Start-time skew of a phase: latest start − earliest start across
    /// clusters (the "offset" driving the paper's second-order effects).
    pub fn start_skew(&self, phase: Phase) -> Option<Time> {
        let starts: Vec<Time> = self
            .cluster_spans
            .iter()
            .filter_map(|m| m.get(&phase))
            .map(|s| s.start)
            .collect();
        if starts.is_empty() {
            return None;
        }
        Some(starts.iter().max().unwrap() - starts.iter().min().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_letters_cover_a_to_i() {
        let letters: Vec<char> = Phase::ALL.iter().map(|p| p.letter()).collect();
        assert_eq!(letters, vec!['A', 'B', 'C', 'D', 'E', 'F', 'G', 'H', 'I']);
    }

    #[test]
    fn from_letter_inverts_letter() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_letter(p.letter()), Some(p));
        }
        assert_eq!(Phase::from_letter('Z'), None);
    }

    #[test]
    fn stats_min_avg_max() {
        let mut t = Trace::new(3);
        t.record(0, Phase::Execute, PhaseSpan::new(10, 20)); // 10
        t.record(1, Phase::Execute, PhaseSpan::new(10, 40)); // 30
        t.record(2, Phase::Execute, PhaseSpan::new(12, 32)); // 20
        let s = t.stats(Phase::Execute).unwrap();
        assert_eq!((s.min, s.max), (10, 30));
        assert!((s.avg - 20.0).abs() < 1e-12);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn start_skew() {
        let mut t = Trace::new(2);
        t.record(0, Phase::RetrieveOperands, PhaseSpan::new(100, 150));
        t.record(1, Phase::RetrieveOperands, PhaseSpan::new(130, 180));
        assert_eq!(t.start_skew(Phase::RetrieveOperands), Some(30));
    }

    #[test]
    fn missing_phase_has_no_stats() {
        let t = Trace::new(2);
        assert!(t.stats(Phase::Wakeup).is_none());
        assert!(t.start_skew(Phase::Wakeup).is_none());
    }

    #[test]
    #[should_panic(expected = "ends before")]
    fn span_validates() {
        PhaseSpan::new(5, 4);
    }
}
