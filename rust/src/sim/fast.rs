//! The `fast` engine profile: an event queue that elides heap work the
//! reference [`EventQueue`](super::engine::EventQueue) would do, while
//! provably dispatching the *same events in the same order* — plus the
//! process-wide timeline memoizer that lets repeated grid points replay
//! a precomputed [`Trace`] skeleton without simulating at all.
//!
//! # Why this is bit-identical by construction
//!
//! The reference engine is a binary heap ordered by `(time, seq)` where
//! `seq` is a monotone counter incremented on *every* schedule call.
//! [`FastQueue`] keeps the same `(time, seq)` assignment but routes
//! events into three structures:
//!
//! * **Same-cycle FIFO** — an event scheduled at `at == now` can never
//!   be preceded by a later schedule (new entries always take the
//!   largest `seq`), so it goes into a plain `VecDeque` instead of the
//!   heap. Batch-draining a same-cycle run is then pointer-chasing a
//!   deque, not sifting a heap.
//! * **Replaceable slot** — at most one completion *poll* (the fluid
//!   port's `PortCheck`) is live at a time; scheduling a new one makes
//!   any pending one stale (its generation stamp no longer matches, so
//!   the reference handler pops it and immediately returns). The slot
//!   holds the single live poll; an overwrite counts the overwritten
//!   entry as dispatched-and-elided, exactly the no-op pop the
//!   reference performs.
//! * **Heap** — everything else, identical to the reference.
//!
//! [`FastQueue::pop`] takes the strict `(time, seq)` minimum across the
//! three sources, so the pop sequence — and therefore every handler
//! call, every schedule call, and every recorded span — is identical to
//! the reference's by induction. `dispatched()` counts elided slot
//! entries too, keeping `Trace::events` byte-identical. The analytic
//! fast-forward of contention-free segments is inherited from the
//! contention models themselves ([`FifoServer`](super::FifoServer)
//! watermarks, [`PsPort`](super::PsPort) closed-form completions): when
//! the pending set is sparse, a single pop jumps the clock over the
//! whole quiescent region, and [`FastStats::fast_forward_jumps`] counts
//! those jumps.
//!
//! The differential harness (`tests/integration_profiles.rs`) enforces
//! the identity over every kernel, geometry, and routine; the
//! [`Backend`] seam keeps the reference engine untouched as the
//! authority.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use super::engine::{EventQueue, Time};
use super::trace::Trace;

/// Which simulation engine runs an offload timeline.
///
/// `Reference` is the event-heap DES, unchanged and authoritative.
/// `Fast` elides heap work and memoizes whole timelines; it is gated by
/// a differential bit-identity harness and safe wherever that harness
/// covers the workload (all shipped kernels, routines and geometries).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SimProfile {
    #[default]
    Reference,
    Fast,
}

impl SimProfile {
    pub const ALL: [SimProfile; 2] = [SimProfile::Reference, SimProfile::Fast];

    pub fn name(&self) -> &'static str {
        match self {
            SimProfile::Reference => "reference",
            SimProfile::Fast => "fast",
        }
    }

    /// Inverse of [`SimProfile::name`]; `None` for unknown tokens.
    pub fn parse(name: &str) -> Option<SimProfile> {
        match name {
            "reference" => Some(SimProfile::Reference),
            "fast" => Some(SimProfile::Fast),
            _ => None,
        }
    }
}

#[derive(Debug)]
struct Entry<E> {
    time: Time,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed, exactly like the reference engine's heap entry.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The fast profile's event queue. Same scheduling contract as
/// [`EventQueue`] (monotonic time, FIFO among equal timestamps), plus
/// [`FastQueue::schedule_replaceable`] for single-live-poll events.
#[derive(Debug)]
pub struct FastQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    /// Events scheduled at the current instant, drained in seq order.
    fifo: VecDeque<(Time, u64, E)>,
    /// The single live completion poll, overwritten in place.
    slot: Option<(Time, u64, E)>,
    seq: u64,
    now: Time,
    popped: u64,
    /// Slot entries overwritten before popping: dispatched, not executed.
    elided: u64,
    /// Events that never entered the binary heap (FIFO + slot).
    heap_bypassed: u64,
    /// Pops that advanced the virtual clock (fast-forward jumps).
    ff_jumps: u64,
}

impl<E> Default for FastQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> FastQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            fifo: VecDeque::new(),
            slot: None,
            seq: 0,
            now: 0,
            popped: 0,
            elided: 0,
            heap_bypassed: 0,
            ff_jumps: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events dispatched: popped plus slot-elided, which equals
    /// the reference engine's pop count for the same schedule sequence.
    pub fn dispatched(&self) -> u64 {
        self.popped + self.elided
    }

    /// Events actually popped (the fast engine's real work).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Stale polls elided by slot overwrites.
    pub fn elided(&self) -> u64 {
        self.elided
    }

    /// Events that bypassed the binary heap entirely.
    pub fn heap_bypassed(&self) -> u64 {
        self.heap_bypassed
    }

    /// Pops that advanced the virtual clock.
    pub fn ff_jumps(&self) -> u64 {
        self.ff_jumps
    }

    /// Schedule `event` at absolute time `at`. Same-cycle events skip
    /// the heap: a new schedule always takes the largest `seq`, so
    /// appending to a FIFO preserves the reference pop order.
    pub fn schedule(&mut self, at: Time, event: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past: {} < {} ({} events pending)",
            at,
            self.now,
            self.len()
        );
        if at == self.now {
            self.fifo.push_back((at, self.seq, event));
            self.heap_bypassed += 1;
        } else {
            self.heap.push(Entry {
                time: at,
                seq: self.seq,
                event,
            });
        }
        self.seq += 1;
    }

    /// Schedule `event` `delay` cycles from now.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        self.schedule(self.now + delay, event);
    }

    /// Schedule an event of which at most one is ever *live*: scheduling
    /// a new one makes any pending one a guaranteed no-op when popped
    /// (the fluid port's generation-stamped completion poll). The
    /// overwritten entry is counted as dispatched — the reference
    /// engine pops it, observes the stale stamp, and returns.
    pub fn schedule_replaceable(&mut self, at: Time, event: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past: {} < {} ({} events pending)",
            at,
            self.now,
            self.len()
        );
        if self.slot.replace((at, self.seq, event)).is_some() {
            self.elided += 1;
        }
        self.heap_bypassed += 1;
        self.seq += 1;
    }

    /// Pop the next event: the strict `(time, seq)` minimum over the
    /// heap, the same-cycle FIFO, and the replaceable slot — the exact
    /// order the reference heap would produce.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let heap_key = self.heap.peek().map(|e| (e.time, e.seq));
        let fifo_key = self.fifo.front().map(|(t, s, _)| (*t, *s));
        let slot_key = self.slot.as_ref().map(|(t, s, _)| (*t, *s));
        let best = [heap_key, fifo_key, slot_key].into_iter().flatten().min()?;
        let (time, event) = if heap_key == Some(best) {
            let e = self.heap.pop().expect("peeked entry present");
            (e.time, e.event)
        } else if fifo_key == Some(best) {
            let (t, _, ev) = self.fifo.pop_front().expect("front entry present");
            (t, ev)
        } else {
            let (t, _, ev) = self.slot.take().expect("slot entry present");
            (t, ev)
        };
        assert!(
            time >= self.now,
            "event popped out of order: {} < {} ({} events pending)",
            time,
            self.now,
            self.len()
        );
        if time > self.now {
            self.ff_jumps += 1;
        }
        self.now = time;
        self.popped += 1;
        Some((time, event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty() && self.fifo.is_empty() && self.slot.is_none()
    }

    pub fn len(&self) -> usize {
        self.heap.len() + self.fifo.len() + usize::from(self.slot.is_some())
    }
}

/// The engine seam: one executor, two interchangeable queues. Every
/// method mirrors [`EventQueue`]'s, so swapping the backing queue does
/// not touch a single call site; [`Backend::schedule_replaceable`] is
/// plain `schedule` on the reference (the stale poll is popped and
/// ignored there, which is what makes the elision verifiable).
#[derive(Debug)]
pub enum Backend<E> {
    Reference(EventQueue<E>),
    Fast(FastQueue<E>),
}

impl<E> Backend<E> {
    pub fn new(profile: SimProfile) -> Self {
        match profile {
            SimProfile::Reference => Backend::Reference(EventQueue::new()),
            SimProfile::Fast => Backend::Fast(FastQueue::new()),
        }
    }

    pub fn profile(&self) -> SimProfile {
        match self {
            Backend::Reference(_) => SimProfile::Reference,
            Backend::Fast(_) => SimProfile::Fast,
        }
    }

    pub fn now(&self) -> Time {
        match self {
            Backend::Reference(q) => q.now(),
            Backend::Fast(q) => q.now(),
        }
    }

    pub fn dispatched(&self) -> u64 {
        match self {
            Backend::Reference(q) => q.dispatched(),
            Backend::Fast(q) => q.dispatched(),
        }
    }

    pub fn schedule(&mut self, at: Time, event: E) {
        match self {
            Backend::Reference(q) => q.schedule(at, event),
            Backend::Fast(q) => q.schedule(at, event),
        }
    }

    pub fn schedule_in(&mut self, delay: Time, event: E) {
        match self {
            Backend::Reference(q) => q.schedule_in(delay, event),
            Backend::Fast(q) => q.schedule_in(delay, event),
        }
    }

    /// Schedule an event the caller guarantees is a no-op once a newer
    /// one is scheduled (generation-stamped polls). Reference: a plain
    /// schedule. Fast: the replaceable slot.
    pub fn schedule_replaceable(&mut self, at: Time, event: E) {
        match self {
            Backend::Reference(q) => q.schedule(at, event),
            Backend::Fast(q) => q.schedule_replaceable(at, event),
        }
    }

    pub fn pop(&mut self) -> Option<(Time, E)> {
        match self {
            Backend::Reference(q) => q.pop(),
            Backend::Fast(q) => q.pop(),
        }
    }

    pub fn is_empty(&self) -> bool {
        match self {
            Backend::Reference(q) => q.is_empty(),
            Backend::Fast(q) => q.is_empty(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Backend::Reference(q) => q.len(),
            Backend::Fast(q) => q.len(),
        }
    }

    /// Fold this queue's per-run counters into the process-wide
    /// [`stats`] snapshot. Call exactly once, after the run drains.
    pub fn flush_counters(&self) {
        if let Backend::Fast(q) = self {
            // ordering: Relaxed — independent monotone counters; no other
            // memory is published through them, totals-only semantics.
            FF_JUMPS.fetch_add(q.ff_jumps, AtomicOrdering::Relaxed);
            HEAP_ELIDED.fetch_add(q.heap_bypassed, AtomicOrdering::Relaxed);
            STALE_SKIPPED.fetch_add(q.elided, AtomicOrdering::Relaxed);
            EVENTS_POPPED.fetch_add(q.popped, AtomicOrdering::Relaxed);
        }
    }
}

static FF_JUMPS: AtomicU64 = AtomicU64::new(0);
static HEAP_ELIDED: AtomicU64 = AtomicU64::new(0);
static STALE_SKIPPED: AtomicU64 = AtomicU64::new(0);
static EVENTS_POPPED: AtomicU64 = AtomicU64::new(0);
static TIMELINE_HITS: AtomicU64 = AtomicU64::new(0);
static TIMELINE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Process-wide fast-profile counters (monotone since process start).
/// The deltas between two snapshots attribute one run's speedup:
/// how often the clock jumped, how much heap work was skipped, and how
/// many whole timelines replayed from the memoizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FastStats {
    /// Pops that advanced the virtual clock (analytic fast-forwards
    /// over quiescent cycles).
    pub fast_forward_jumps: u64,
    /// Events that never entered the binary heap (same-cycle FIFO plus
    /// replaceable-slot schedules).
    pub heap_events_elided: u64,
    /// Stale completion polls skipped by slot overwrites (dispatched
    /// but never executed).
    pub stale_events_skipped: u64,
    /// Events actually popped by fast queues.
    pub events_popped: u64,
    /// Timeline-memoizer hits (whole runs replayed without simulating).
    pub timeline_hits: u64,
    /// Timeline-memoizer misses (runs that simulated and then seeded
    /// the memoizer).
    pub timeline_misses: u64,
}

/// Snapshot the process-wide fast-profile counters.
pub fn stats() -> FastStats {
    FastStats {
        // ordering: Relaxed — diagnostic snapshot of independent counters;
        // no cross-counter consistency is promised to callers.
        fast_forward_jumps: FF_JUMPS.load(AtomicOrdering::Relaxed),
        heap_events_elided: HEAP_ELIDED.load(AtomicOrdering::Relaxed),
        stale_events_skipped: STALE_SKIPPED.load(AtomicOrdering::Relaxed),
        events_popped: EVENTS_POPPED.load(AtomicOrdering::Relaxed),
        timeline_hits: TIMELINE_HITS.load(AtomicOrdering::Relaxed),
        timeline_misses: TIMELINE_MISSES.load(AtomicOrdering::Relaxed),
    }
}

// Ordered map, not a hash map: the memoizer lives in the sim domain
// (audit forbids unordered iteration there), and keeping it a BTreeMap
// means any future walk over it is deterministic by construction.
fn timeline() -> &'static Mutex<BTreeMap<String, Arc<Trace>>> {
    static MEMO: OnceLock<Mutex<BTreeMap<String, Arc<Trace>>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Poison-recovering lock, same rationale as `sweep::cache`: the map
/// only ever sees plain inserts of immutable `Arc<Trace>`s.
fn lock_timeline() -> MutexGuard<'static, BTreeMap<String, Arc<Trace>>> {
    timeline().lock().unwrap_or_else(PoisonError::into_inner)
}

/// The memoizer key of one specialized timeline. The caller supplies
/// the full config serialization (collision-free by construction, like
/// `sweep::cache::config_key`) and the store request-key grammar
/// (`<spec>-c<clusters>-<routine>`), joined with a separator neither
/// side can contain.
pub fn timeline_key(config_toml: &str, request_key: &str) -> String {
    format!("{config_toml}\u{1f}{request_key}")
}

/// Look up a memoized timeline; counts a hit or a miss.
pub fn timeline_lookup(key: &str) -> Option<Arc<Trace>> {
    let hit = lock_timeline().get(key).map(Arc::clone);
    match &hit {
        // ordering: Relaxed — hit/miss tallies are diagnostics only;
        // nothing reads them to order access to the memoized traces.
        Some(_) => TIMELINE_HITS.fetch_add(1, AtomicOrdering::Relaxed),
        None => TIMELINE_MISSES.fetch_add(1, AtomicOrdering::Relaxed),
    };
    hit
}

/// Seed the memoizer with a freshly simulated timeline. An existing
/// entry wins (the DES is deterministic, both are equal) so earlier
/// replays keep their `Arc` sharing.
pub fn timeline_insert(key: String, trace: Arc<Trace>) -> Arc<Trace> {
    Arc::clone(lock_timeline().entry(key).or_insert(trace))
}

/// Memoized timelines currently held (diagnostics).
pub fn timeline_len() -> usize {
    lock_timeline().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_names_round_trip() {
        for p in SimProfile::ALL {
            assert_eq!(SimProfile::parse(p.name()), Some(p));
        }
        assert_eq!(SimProfile::parse("warp"), None);
        assert_eq!(SimProfile::default(), SimProfile::Reference);
    }

    /// Drive both queues with an identical pseudo-random schedule script
    /// (no replaceable events) and check the pop streams are identical.
    #[test]
    fn fast_queue_matches_reference_pop_order() {
        let mut reference = EventQueue::new();
        let mut fast = FastQueue::new();
        let mut state: u64 = 0x9e3779b97f4a7c15;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        let mut pending = 0u64;
        let mut label = 0u64;
        for _ in 0..2000 {
            if pending == 0 || next() % 3 != 0 {
                // Schedule 0, same-cycle, or a forward jump.
                let delay = match next() % 4 {
                    0 => 0,
                    1 => 1,
                    _ => next() % 1000,
                };
                reference.schedule_in(delay, label);
                fast.schedule_in(delay, label);
                label += 1;
                pending += 1;
            } else {
                assert_eq!(reference.pop(), fast.pop());
                pending -= 1;
            }
        }
        loop {
            let (a, b) = (reference.pop(), fast.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(reference.dispatched(), fast.dispatched());
        assert_eq!(reference.now(), fast.now());
    }

    #[test]
    fn same_cycle_events_bypass_the_heap() {
        let mut q = FastQueue::new();
        q.schedule(0, "a");
        q.schedule(0, "b");
        q.schedule(5, "c");
        assert_eq!(q.heap_bypassed(), 2);
        assert_eq!(q.pop(), Some((0, "a")));
        assert_eq!(q.pop(), Some((0, "b")));
        assert_eq!(q.ff_jumps(), 0, "no clock movement yet");
        assert_eq!(q.pop(), Some((5, "c")));
        assert_eq!(q.ff_jumps(), 1, "the jump to t=5");
        assert!(q.is_empty());
    }

    #[test]
    fn replaceable_slot_counts_overwrites_as_dispatched() {
        let mut q = FastQueue::new();
        q.schedule_replaceable(10, "poll@10");
        q.schedule_replaceable(20, "poll@20"); // overwrites the first
        q.schedule(15, "work");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((15, "work")));
        assert_eq!(q.pop(), Some((20, "poll@20")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.elided(), 1);
        // popped(2) + elided(1) == the 3 schedules a reference engine
        // would have popped.
        assert_eq!(q.dispatched(), 3);
    }

    #[test]
    fn slot_respects_seq_order_against_heap_ties() {
        // A slot entry and a heap entry at the same instant pop in
        // schedule order, exactly like the reference heap.
        let mut q = FastQueue::new();
        q.schedule_replaceable(10, "poll");
        q.schedule(10, "work");
        assert_eq!(q.pop(), Some((10, "poll")));
        assert_eq!(q.pop(), Some((10, "work")));
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn fast_queue_rejects_past_events() {
        let mut q = FastQueue::new();
        q.schedule(5, ());
        q.pop();
        q.schedule(1, ());
    }

    #[test]
    fn backend_reference_treats_replaceable_as_plain_schedule() {
        let mut b: Backend<&str> = Backend::new(SimProfile::Reference);
        b.schedule_replaceable(10, "poll@10");
        b.schedule_replaceable(20, "poll@20");
        // The reference pops both (the stale one is the handler's
        // problem); dispatched counts agree with the fast profile's
        // popped + elided.
        assert_eq!(b.pop(), Some((10, "poll@10")));
        assert_eq!(b.pop(), Some((20, "poll@20")));
        assert_eq!(b.dispatched(), 2);
        let mut f: Backend<&str> = Backend::new(SimProfile::Fast);
        f.schedule_replaceable(10, "poll@10");
        f.schedule_replaceable(20, "poll@20");
        assert_eq!(f.pop(), Some((20, "poll@20")));
        assert_eq!(f.pop(), None);
        assert_eq!(f.dispatched(), 2);
    }

    #[test]
    fn timeline_memo_keeps_the_first_entry_and_counts_tiers() {
        let key = timeline_key("unit-test-config", "axpy_n1-c1-ideal");
        let before = stats();
        assert!(timeline_lookup(&key).is_none());
        let first = timeline_insert(key.clone(), Arc::new(Trace::new(1)));
        let second = timeline_insert(key.clone(), Arc::new(Trace::new(1)));
        assert!(Arc::ptr_eq(&first, &second));
        let hit = timeline_lookup(&key).expect("present after insert");
        assert!(Arc::ptr_eq(&first, &hit));
        let after = stats();
        assert!(after.timeline_hits >= before.timeline_hits + 1);
        assert!(after.timeline_misses >= before.timeline_misses + 1);
        assert!(timeline_len() >= 1);
    }

    #[test]
    fn distinct_configs_get_distinct_timeline_keys() {
        assert_ne!(
            timeline_key("a = 1\n", "axpy_n8-c1-ideal"),
            timeline_key("a = 2\n", "axpy_n8-c1-ideal")
        );
        assert_ne!(
            timeline_key("a = 1\n", "axpy_n8-c1-ideal"),
            timeline_key("a = 1\n", "axpy_n8-c2-ideal")
        );
    }
}
