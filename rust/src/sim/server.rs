//! Shared-resource contention models.
//!
//! Two arbitration disciplines cover every shared resource in the paper's
//! analysis:
//!
//! * [`FifoServer`] — a single-ported resource serving one request at a
//!   time in arrival order. Models the TCDM port of cluster 0 during the
//!   *Retrieve job pointer/arguments* phases and the AMO serialization of
//!   the software barrier counter (§5.5.C/D/H).
//!
//! * [`PsPort`] — a fluid processor-sharing server with a fixed aggregate
//!   rate (1 beat/cycle at the 512-bit wide SPM interface). The paper
//!   observes that "multiple short DMA transfers perfectly interleave,
//!   thus taking the same amount of time as a single DMA transfer of
//!   combined length at the SPM interface" (§5.5.E) — exactly
//!   processor-sharing semantics. Models the wide SPM port shared by the
//!   *Retrieve job operands* and *Writeback* DMA transfers of all clusters.

use super::engine::Time;

/// Single-server FIFO queue with deterministic service times.
///
/// Because service order equals arrival order and service times are known
/// at arrival, completion times can be assigned eagerly: the server is a
/// running "next free" watermark.
#[derive(Debug, Clone, Default)]
pub struct FifoServer {
    next_free: Time,
    served: u64,
    busy_cycles: u64,
}

impl FifoServer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a request arriving at `at` needing `service` cycles.
    /// Returns its completion time.
    pub fn serve(&mut self, at: Time, service: Time) -> Time {
        let start = self.next_free.max(at);
        self.next_free = start + service;
        self.served += 1;
        self.busy_cycles += service;
        self.next_free
    }

    /// Number of requests served.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Aggregate busy cycles (utilization numerator).
    pub fn busy_cycles(&self) -> u64 {
        self.busy_cycles
    }

    /// Time the server becomes idle given no further arrivals.
    pub fn next_free(&self) -> Time {
        self.next_free
    }
}

/// Identifier of a transfer inside a [`PsPort`].
pub type TransferId = u64;

#[derive(Debug, Clone)]
struct Active {
    id: TransferId,
    /// Remaining service in beats (fluid, fractional).
    remaining: f64,
}

/// Fluid processor-sharing port: aggregate rate of 1 beat/cycle divided
/// equally among active transfers.
///
/// Event-driven use: after any [`PsPort::join`], call
/// [`PsPort::next_completion`] and schedule a check at that time carrying
/// the returned generation stamp; on dispatch, drop stale generations and
/// call [`PsPort::collect_finished`].
#[derive(Debug, Clone, Default)]
pub struct PsPort {
    active: Vec<Active>,
    last_update: Time,
    generation: u64,
    next_id: TransferId,
    total_beats_served: f64,
}

impl PsPort {
    pub fn new() -> Self {
        Self::default()
    }

    fn advance(&mut self, now: Time) {
        debug_assert!(now >= self.last_update);
        let elapsed = (now - self.last_update) as f64;
        if elapsed > 0.0 && !self.active.is_empty() {
            let share = elapsed / self.active.len() as f64;
            for a in &mut self.active {
                a.remaining -= share;
            }
            self.total_beats_served += elapsed.min(
                self.active.len() as f64 * share, // == elapsed
            );
        }
        self.last_update = now;
    }

    /// A transfer of `beats` joins the port at time `now`.
    /// Returns its id and the new generation stamp.
    pub fn join(&mut self, now: Time, beats: u64) -> (TransferId, u64) {
        self.advance(now);
        let id = self.next_id;
        self.next_id += 1;
        self.active.push(Active {
            id,
            remaining: beats.max(1) as f64,
        });
        self.generation += 1;
        (id, self.generation)
    }

    /// Earliest time any active transfer completes, with the generation
    /// stamp that must still match when the event fires. `None` if idle.
    pub fn next_completion(&self, now: Time) -> Option<(Time, u64)> {
        let min = self
            .active
            .iter()
            .map(|a| a.remaining)
            .fold(f64::INFINITY, f64::min);
        if min.is_finite() {
            let k = self.active.len() as f64;
            let dt = (min.max(0.0) * k).ceil() as Time;
            Some((now + dt, self.generation))
        } else {
            None
        }
    }

    /// True if `generation` is still the latest (the scheduled completion
    /// check is not stale).
    pub fn is_current(&self, generation: u64) -> bool {
        self.generation == generation
    }

    /// Advance to `now` and remove every transfer with (numerically) zero
    /// remaining service. Returns their ids. Bumps the generation if
    /// anything finished (the sharing ratio changed).
    pub fn collect_finished(&mut self, now: Time) -> Vec<TransferId> {
        self.advance(now);
        let mut done = Vec::new();
        self.active.retain(|a| {
            // f64 tolerance: a transfer is done when its fluid remainder
            // is below half a beat-share of one cycle.
            if a.remaining <= 1e-9 {
                done.push(a.id);
                false
            } else {
                true
            }
        });
        if !done.is_empty() {
            self.generation += 1;
        }
        done
    }

    /// Number of in-flight transfers.
    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    /// Total beats served so far (utilization accounting).
    pub fn beats_served(&self) -> f64 {
        self.total_beats_served
    }
}

/// Transfer-granular round-robin port: the default model of the wide SPM
/// interface.
///
/// One transfer occupies the port for its full beat count; pending
/// transfers from different owners (clusters) are granted in round-robin
/// order, transfers of the same owner in FIFO order. This reproduces both
/// §5.5.E observations at once: the *last* completion equals the
/// combined-length single transfer (perfect interleaving at the
/// interface), while per-transfer grants stagger the per-cluster
/// completion times — the offsets that make phase G effectively
/// contention-free (§5.5.G) and that fair fluid sharing cannot produce.
/// [`PsPort`] (fluid processor sharing) is retained as an ablation.
#[derive(Debug, Clone)]
pub struct RrPort {
    queues: Vec<std::collections::VecDeque<(TransferId, u64)>>,
    rr_cursor: usize,
    busy: bool,
    next_id: TransferId,
    pending: usize,
    busy_cycles: u64,
}

impl RrPort {
    pub fn new(n_owners: usize) -> Self {
        Self {
            queues: vec![std::collections::VecDeque::new(); n_owners],
            rr_cursor: 0,
            busy: false,
            next_id: 0,
            pending: 0,
            busy_cycles: 0,
        }
    }

    /// Queue a transfer of `beats` for `owner`. Returns its id.
    pub fn submit(&mut self, owner: usize, beats: u64) -> TransferId {
        let id = self.next_id;
        self.next_id += 1;
        self.queues[owner].push_back((id, beats.max(1)));
        self.pending += 1;
        id
    }

    /// If the port is idle and work is pending, grant the next transfer
    /// (round-robin over owners) and return `(id, beats)`. The caller
    /// schedules the completion `beats` cycles later and then calls
    /// [`RrPort::complete`].
    pub fn try_grant(&mut self) -> Option<(TransferId, u64)> {
        if self.busy || self.pending == 0 {
            return None;
        }
        let n = self.queues.len();
        for k in 0..n {
            let owner = (self.rr_cursor + k) % n;
            if let Some((id, beats)) = self.queues[owner].pop_front() {
                self.rr_cursor = (owner + 1) % n;
                self.busy = true;
                self.pending -= 1;
                self.busy_cycles += beats;
                return Some((id, beats));
            }
        }
        unreachable!("pending > 0 but no queued transfer found");
    }

    /// The granted transfer finished; the port is idle again.
    pub fn complete(&mut self) {
        assert!(self.busy, "complete on an idle port");
        self.busy = false;
    }

    pub fn is_idle(&self) -> bool {
        !self.busy
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn busy_cycles(&self) -> u64 {
        self.busy_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_back_to_back() {
        let mut s = FifoServer::new();
        assert_eq!(s.serve(0, 2), 2);
        assert_eq!(s.serve(0, 2), 4); // queued behind the first
        assert_eq!(s.serve(10, 3), 13); // idle gap, starts at arrival
        assert_eq!(s.served(), 3);
        assert_eq!(s.busy_cycles(), 7);
    }

    #[test]
    fn ps_single_transfer_runs_at_full_rate() {
        let mut p = PsPort::new();
        let (_, g) = p.join(0, 100);
        let (t, g2) = p.next_completion(0).unwrap();
        assert_eq!((t, g2), (100, g));
        assert!(p.is_current(g));
        let done = p.collect_finished(100);
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn ps_two_equal_transfers_share_fairly() {
        // Two 100-beat transfers arriving together behave like one 200-beat
        // transfer (§5.5.E: perfect interleaving), both finishing at 200.
        let mut p = PsPort::new();
        p.join(0, 100);
        p.join(0, 100);
        let (t, _) = p.next_completion(0).unwrap();
        assert_eq!(t, 200);
        assert_eq!(p.collect_finished(200).len(), 2);
    }

    #[test]
    fn ps_staggered_arrival() {
        // T1 (100 beats) at t=0; T2 (100 beats) at t=50. T1 has 50 left,
        // shared rate 1/2 -> T1 done at 150. T2 then alone with 50 left ->
        // done at 200. Total port busy = 200 = total beats. Work conserving.
        let mut p = PsPort::new();
        p.join(0, 100);
        let (t1, _) = p.next_completion(0).unwrap();
        assert_eq!(t1, 100);
        p.join(50, 100);
        let (t, g) = p.next_completion(50).unwrap();
        assert_eq!(t, 150);
        assert!(p.is_current(g));
        assert_eq!(p.collect_finished(150).len(), 1);
        let (t2, _) = p.next_completion(150).unwrap();
        assert_eq!(t2, 200);
        assert_eq!(p.collect_finished(200).len(), 1);
    }

    #[test]
    fn ps_stale_generation_detected() {
        let mut p = PsPort::new();
        let (_, g1) = p.join(0, 100);
        let (_, g2) = p.join(10, 100);
        assert!(!p.is_current(g1));
        assert!(p.is_current(g2));
    }

    #[test]
    fn ps_zero_beat_transfer_counts_as_one() {
        let mut p = PsPort::new();
        p.join(0, 0);
        let (t, _) = p.next_completion(0).unwrap();
        assert_eq!(t, 1);
    }

    #[test]
    fn rr_single_owner_fifo() {
        let mut p = RrPort::new(2);
        p.submit(0, 10);
        p.submit(0, 20);
        let (id1, b1) = p.try_grant().unwrap();
        assert_eq!(b1, 10);
        assert!(p.try_grant().is_none(), "port busy");
        p.complete();
        let (id2, b2) = p.try_grant().unwrap();
        assert_eq!(b2, 20);
        assert!(id2 > id1);
        p.complete();
        assert!(p.try_grant().is_none());
    }

    #[test]
    fn rr_alternates_between_owners() {
        // Two owners submit (x, y) pairs: grant order is x0 x1 y0 y1 —
        // the §5.5.E multicast pattern where no cluster's second transfer
        // runs back-to-back with its first.
        let mut p = RrPort::new(2);
        let x0 = p.submit(0, 4);
        let x1 = p.submit(1, 4);
        let y0 = p.submit(0, 4);
        let y1 = p.submit(1, 4);
        let mut order = Vec::new();
        while let Some((id, _)) = p.try_grant() {
            order.push(id);
            p.complete();
        }
        assert_eq!(order, vec![x0, x1, y0, y1]);
    }

    #[test]
    fn rr_last_completion_equals_combined_length() {
        // Work conservation: total busy time == sum of beats.
        let mut p = RrPort::new(4);
        for o in 0..4 {
            p.submit(o, 32);
            p.submit(o, 32);
        }
        let mut t = 0u64;
        while let Some((_, beats)) = p.try_grant() {
            t += beats;
            p.complete();
        }
        assert_eq!(t, 8 * 32);
        assert_eq!(p.busy_cycles(), 256);
    }

    #[test]
    fn rr_zero_beats_counts_as_one() {
        let mut p = RrPort::new(1);
        p.submit(0, 0);
        assert_eq!(p.try_grant().unwrap().1, 1);
    }

    #[test]
    #[should_panic(expected = "idle port")]
    fn rr_complete_when_idle_panics() {
        let mut p = RrPort::new(1);
        p.complete();
    }
}
