//! Cycle-level simulation substrate: event engine, shared-resource
//! contention models, and mcycle-style trace instrumentation. Together
//! these replace the paper's QuestaSim RTL simulation (§5.1) — see
//! DESIGN.md's substitution table.

pub mod engine;
pub mod server;
pub mod trace;

pub use engine::{EventQueue, Time};
pub use server::{FifoServer, PsPort, RrPort, TransferId};
pub use trace::{Phase, PhaseSpan, PhaseStats, Trace};
