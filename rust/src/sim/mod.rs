//! Cycle-level simulation substrate: event engine, shared-resource
//! contention models, and mcycle-style trace instrumentation. Together
//! these replace the paper's QuestaSim RTL simulation (§5.1) — see
//! DESIGN.md's substitution table.
//!
//! Two engine profiles run every timeline ([`SimProfile`]): the
//! reference event-heap DES ([`EventQueue`]) and the `fast` profile
//! ([`fast::FastQueue`] behind the [`Backend`] seam), which batch-drains
//! same-cycle runs, elides stale completion polls, and memoizes whole
//! specialized timelines — bit-identical to the reference by
//! construction and enforced by `tests/integration_profiles.rs`.

pub mod engine;
pub mod fast;
pub mod server;
pub mod trace;

pub use engine::{EventQueue, Time};
pub use fast::{Backend, FastQueue, FastStats, SimProfile};
pub use server::{FifoServer, PsPort, RrPort, TransferId};
pub use trace::{Phase, PhaseSpan, PhaseStats, Trace};
