//! CVA6 host model (§3.1): the single application-class core that manages
//! the computation and offloads jobs. Functional state (WFI/interrupt
//! handshake with the CLINT/JCU) used by the coordinator; the host-side
//! phase timings (A, B issue, I) come from `config::TimingConfig`.

use crate::interrupt::Clint;

/// Host execution state around an offload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostState {
    /// Executing workload code.
    Running,
    /// In WFI waiting for job completion.
    Waiting,
}

#[derive(Debug, Clone)]
pub struct Host {
    pub state: HostState,
    offloads_issued: u64,
    completions_seen: u64,
}

impl Default for Host {
    fn default() -> Self {
        Self::new()
    }
}

impl Host {
    pub fn new() -> Self {
        Self {
            state: HostState::Running,
            offloads_issued: 0,
            completions_seen: 0,
        }
    }

    /// Issue an offload and enter WFI (the bare-metal runtime blocks; an
    /// OS would schedule other work — out of scope, §4.1).
    pub fn offload_and_wait(&mut self) {
        assert_eq!(self.state, HostState::Running, "offload while waiting");
        self.offloads_issued += 1;
        self.state = HostState::Waiting;
    }

    /// Completion interrupt delivered: clear MSIP and resume.
    pub fn on_completion(&mut self, clint: &mut Clint, hart: usize) {
        assert_eq!(self.state, HostState::Waiting);
        assert!(clint.pending(hart), "spurious completion interrupt");
        clint.clear_msip(hart);
        self.completions_seen += 1;
        self.state = HostState::Running;
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.offloads_issued, self.completions_seen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offload_handshake() {
        let mut h = Host::new();
        let mut clint = Clint::new(1);
        h.offload_and_wait();
        assert_eq!(h.state, HostState::Waiting);
        clint.set_msip(0);
        h.on_completion(&mut clint, 0);
        assert_eq!(h.state, HostState::Running);
        assert!(!clint.pending(0));
        assert_eq!(h.stats(), (1, 1));
    }

    #[test]
    #[should_panic(expected = "spurious")]
    fn completion_without_interrupt_panics() {
        let mut h = Host::new();
        let mut clint = Clint::new(1);
        h.offload_and_wait();
        h.on_completion(&mut clint, 0);
    }
}
