//! Minimal JSON parser and serializer for the AOT artifact manifest and
//! the campaign result stream.
//!
//! The build environment vendors only the crate set the xla bridge needs
//! (no serde_json), and both producers are machine-generated
//! (`python/compile/aot.py` manifests, `campaign::stream` JSONL), so a
//! small strict implementation suffices. Supports the full JSON value
//! grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null); parse errors carry byte offsets. [`Json::to_string`] is
//! deterministic — object keys are stored in a `BTreeMap`, so they
//! serialize in sorted order, and integral numbers within the exact-f64
//! range print without a fractional part, making parse/serialize a
//! round trip for the integer cycle counts the campaign store persists.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialize to a single-line JSON string (no insignificant whitespace).
/// Deterministic: object keys come out in `BTreeMap` order, and numbers
/// that are exactly-representable integers are written without a
/// fractional part, so `Json::parse(v.to_string()) == v` for the
/// documents this crate produces.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Exact-integer range of f64: |n| <= 2^53 round-trips losslessly. The
/// serializer's integer-formatting cutoff and the campaign codec's
/// strict-integer acceptance bound (`campaign::codec`) must agree, so
/// both use this constant.
pub const EXACT_INT: f64 = 9_007_199_254_740_992.0;

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no inf/NaN literals; `{:?}` would emit "inf"/"NaN"
        // and produce an unparseable document. Every non-finite float in
        // this crate is a degenerate statistic (e.g. a throughput over
        // zero cycles), so `null` — "no meaningful value" — is the
        // faithful encoding and every consumer can parse it.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= EXACT_INT {
        out.push_str(&format!("{}", n as i64));
    } else {
        // `{:?}` is Rust's shortest round-trip float formatting.
        out.push_str(&format!("{n:?}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .unwrap()
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (may be multi-byte).
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "format": "hlo-text",
          "artifacts": [
            {"kernel": "axpy", "id": "axpy_n256", "params": {"n": 256},
             "inputs": [{"shape": [], "dtype": "f64"},
                        {"shape": [256], "dtype": "f64"}],
             "outputs": [{"shape": [256], "dtype": "f64"}],
             "file": "axpy_n256.hlo.txt", "sha256": "abé"}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(
            arts[0].get("params").unwrap().get("n").unwrap().as_u64(),
            Some(256)
        );
        assert_eq!(arts[0].get("sha256").unwrap().as_str(), Some("abé"));
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("[1, 2, 3]").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\nd""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn serializer_round_trips() {
        let doc = r#"{"a": [1, 2.5, -3], "b": {"nested": true, "s": "x\"y\\z"}, "c": null}"#;
        let v = Json::parse(doc).unwrap();
        let line = v.to_string();
        assert!(!line.contains('\n'));
        assert_eq!(Json::parse(&line).unwrap(), v);
        // Deterministic: serializing twice gives the same bytes.
        assert_eq!(line, v.to_string());
    }

    #[test]
    fn serializer_preserves_large_cycle_counts() {
        // u64 cycle counts up to 2^53 must survive the f64 round trip
        // without a fractional suffix (the campaign store relies on it).
        let big = (1u64 << 53) - 1;
        let v = Json::Num(big as f64);
        assert_eq!(v.to_string(), format!("{big}"));
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_u64(), Some(big));
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
        assert_eq!(Json::Num(-0.25).to_string(), "-0.25");
    }

    #[test]
    fn serializer_escapes_control_characters() {
        let v = Json::Str("a\nb\t\"q\"\\ \u{1}".into());
        let line = v.to_string();
        assert_eq!(Json::parse(&line).unwrap(), v);
        assert!(line.contains("\\u0001"));
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        // `Metrics::jobs_per_sim_second` is INFINITY for zero-cycle
        // batches (a deliberate API choice pinned by a coordinator
        // test); the wire must still be valid JSON. Same for NaN and
        // non-finite values buried in containers.
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let line = Json::Num(bad).to_string();
            assert_eq!(line, "null");
            assert_eq!(Json::parse(&line).unwrap(), Json::Null);
        }
        let mut obj = BTreeMap::new();
        obj.insert("rate".to_string(), Json::Num(f64::INFINITY));
        obj.insert("ok".to_string(), Json::Num(2.5));
        let line = Json::Obj(obj).to_string();
        assert_eq!(line, r#"{"ok":2.5,"rate":null}"#);
        assert!(Json::parse(&line).is_ok());
    }

    #[test]
    fn every_control_character_round_trips_on_one_line() {
        // All of U+0000..U+001F plus DEL in one string: the serializer
        // must emit a single line of valid JSON (the event log and the
        // campaign stream are line-delimited) that parses back to the
        // identical string. DEL is legal raw in JSON strings; everything
        // below 0x20 must be escaped.
        let hostile: String =
            (0u32..0x20).chain([0x7f]).map(|c| char::from_u32(c).unwrap()).collect();
        let v = Json::Str(hostile);
        let line = v.to_string();
        assert!(!line.contains('\n') && !line.contains('\r'), "{line:?}");
        for esc in ["\\u0000", "\\u0008", "\\u000b", "\\u000c", "\\u001f", "\\n", "\\t", "\\r"] {
            assert!(line.contains(esc), "missing {esc} in {line}");
        }
        assert_eq!(Json::parse(&line).unwrap(), v);
    }

    #[test]
    fn hostile_strings_round_trip_as_keys_and_values() {
        let cases = [
            "snowman ☃ emoji 🦀 accents éü",
            "quote\"backslash\\slash/",
            "\\u0041 is a literal here, not an escape",
            "mixed \u{1} ctrl ☃ \"q\" \\ end",
            "",
        ];
        for s in cases {
            let mut obj = BTreeMap::new();
            obj.insert(s.to_string(), Json::Str(s.to_string()));
            let v = Json::Obj(obj);
            let line = v.to_string();
            let back = Json::parse(&line).unwrap_or_else(|e| panic!("{s:?} via {line}: {e}"));
            assert_eq!(back, v, "{s:?} via {line}");
            assert_eq!(back.to_string(), line, "unstable bytes for {s:?}");
        }
    }

    #[test]
    fn unpaired_surrogate_escapes_degrade_to_replacement() {
        // \uD800 names a UTF-16 surrogate with no pair; Rust strings
        // cannot hold it, so the parser substitutes U+FFFD rather than
        // erroring out of an otherwise-valid document.
        let v = Json::parse("\"a\\ud800b\"").unwrap();
        assert_eq!(v.as_str(), Some("a\u{fffd}b"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }
}
