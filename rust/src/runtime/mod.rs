//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes the
//! kernels' numerics on the request path. Python is never involved at
//! runtime — see `/opt/xla-example/README.md` for the interchange
//! gotchas this module encodes.

pub mod artifact;
pub mod executor;
pub mod jobs;
pub mod json;

pub use artifact::{ArtifactEntry, DType, Manifest, TensorSpec};
pub use executor::{PjrtRuntime, Value};
pub use jobs::{execute_job, run_and_verify, values_for, verify_job};

/// Default artifacts directory relative to the repo root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // Binaries run from the workspace root (cargo) or an arbitrary cwd;
    // honor OCCAMY_ARTIFACTS when set.
    if let Ok(dir) = std::env::var("OCCAMY_ARTIFACTS") {
        return dir.into();
    }
    std::path::PathBuf::from("artifacts")
}
