//! PJRT execution of the AOT-compiled kernels — the functional half of the
//! request path.
//!
//! Loads `artifacts/*.hlo.txt` (HLO *text*: the xla_extension 0.5.1 the
//! `xla` crate embeds rejects jax>=0.5's 64-bit-id serialized protos; the
//! text parser reassigns ids), compiles each once on the PJRT CPU client,
//! caches the loaded executables, and runs jobs with concrete inputs.
//! Python never runs here — the Rust binary is self-contained once
//! `make artifacts` has produced the HLO files.
//!
//! The `xla` crate cannot be vendored into this workspace, so the whole
//! PJRT path is gated behind the `pjrt` cargo feature. Without it,
//! [`PjrtRuntime::new`] returns a clear error and every timing-only path
//! (DES, sweep campaigns, `CoordinatorConfig::timing_only`) works
//! unchanged.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use super::artifact::ArtifactEntry;
use super::artifact::{DType, Manifest};

/// A typed host tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F64 { data: Vec<f64>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
    U32 { data: Vec<u32>, shape: Vec<usize> },
}

impl Value {
    pub fn scalar_f64(v: f64) -> Self {
        Value::F64 {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Value::I32 {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn scalar_u32(v: u32) -> Self {
        Value::U32 {
            data: vec![v],
            shape: vec![],
        }
    }

    pub fn vec_f64(data: Vec<f64>) -> Self {
        let shape = vec![data.len()];
        Value::F64 { data, shape }
    }

    pub fn mat_f64(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        Value::F64 {
            data,
            shape: vec![rows, cols],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F64 { shape, .. } | Value::I32 { shape, .. } | Value::U32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F64 { .. } => DType::F64,
            Value::I32 { .. } => DType::I32,
            Value::U32 { .. } => DType::U32,
        }
    }

    pub fn as_f64(&self) -> Option<&[f64]> {
        match self {
            Value::F64 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Value::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F64 { data, .. } => xla::Literal::vec1(data),
            Value::I32 { data, .. } => xla::Literal::vec1(data),
            Value::U32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.is_empty() {
            // 0-d scalar: reshape from [1] to [].
            Ok(lit.reshape(&[])?)
        } else if dims.len() == 1 {
            Ok(lit)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Value> {
        Ok(match dtype {
            DType::F64 => Value::F64 {
                data: lit.to_vec::<f64>()?,
                shape: shape.to_vec(),
            },
            DType::I32 => Value::I32 {
                data: lit.to_vec::<i32>()?,
                shape: shape.to_vec(),
            },
            DType::U32 => Value::U32 {
                data: lit.to_vec::<u32>()?,
                shape: shape.to_vec(),
            },
            DType::F32 => bail!("f32 outputs unused by this manifest"),
        })
    }
}

/// The PJRT runtime: client + manifest + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

/// Stub runtime for builds without the `pjrt` feature: construction
/// fails with a clear message after validating the manifest, so callers
/// degrade gracefully instead of failing to link.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn new(dir: &Path) -> Result<Self> {
        let _manifest = Manifest::load(dir)?;
        bail!(
            "PJRT backend not compiled in: rebuild with `--features pjrt` \
             (requires a vendored `xla` crate; timing-only paths are unaffected)"
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        0
    }

    /// Always fails: the PJRT backend is not compiled in.
    pub fn execute(&self, id: &str, _inputs: &[Value]) -> Result<Vec<Value>> {
        bail!("PJRT backend not compiled in (cannot execute artifact {id:?})")
    }
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn entry(&self, id: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .get(id)
            .ok_or_else(|| anyhow!("no artifact {id:?} in manifest"))
    }

    /// Compile (or fetch from cache) the executable of artifact `id`.
    pub fn load(&self, id: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(id) {
            return Ok(e.clone());
        }
        let entry = self.entry(id)?;
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {id}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(id.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute artifact `id` with `inputs`, validating shapes/dtypes
    /// against the manifest. Returns the outputs in manifest order.
    pub fn execute(&self, id: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let entry = self.entry(id)?.clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{id}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (k, (v, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "{id}: input {k} shape {:?} != manifest {:?}",
                    v.shape(),
                    spec.shape
                );
            }
            if v.dtype() != spec.dtype {
                bail!(
                    "{id}: input {k} dtype {:?} != manifest {:?}",
                    v.dtype(),
                    spec.dtype
                );
            }
        }
        let exe = self.load(id)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let mut parts = result;
        let elems = parts.decompose_tuple()?;
        if elems.len() != entry.outputs.len() {
            bail!(
                "{id}: executable returned {} outputs, manifest says {}",
                elems.len(),
                entry.outputs.len()
            );
        }
        elems
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec.dtype, &spec.shape))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_dtypes() {
        let v = Value::mat_f64(vec![0.0; 6], 2, 3);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), DType::F64);
        assert_eq!(Value::scalar_i32(7).shape(), &[] as &[usize]);
        assert_eq!(Value::vec_f64(vec![1.0, 2.0]).as_f64().unwrap().len(), 2);
    }

    #[test]
    #[should_panic]
    fn mat_validates_length() {
        Value::mat_f64(vec![0.0; 5], 2, 3);
    }
}
