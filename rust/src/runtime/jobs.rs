//! Job-level bridge: `kernels::JobSpec` + generated inputs → PJRT
//! execution → verification against the native references.
//!
//! This is what the coordinator calls on the request path: the DES
//! provides the *cycle* cost of an offload, this module provides (and
//! checks) its *numerics*.

use anyhow::{anyhow, bail, Result};

use crate::kernels::datagen::{self, JobExpected, JobInputs};
use crate::kernels::JobSpec;

use super::executor::{PjrtRuntime, Value};

/// Build the PJRT input values of a job.
pub fn values_for(spec: &JobSpec, inputs: &JobInputs) -> Result<Vec<Value>> {
    Ok(match (spec, inputs) {
        (JobSpec::Axpy { .. }, JobInputs::Axpy { alpha, x, y }) => vec![
            Value::scalar_f64(*alpha),
            Value::vec_f64(x.clone()),
            Value::vec_f64(y.clone()),
        ],
        (JobSpec::MonteCarlo { .. }, JobInputs::MonteCarlo { seed }) => {
            vec![Value::scalar_u32(*seed)]
        }
        (JobSpec::Matmul { m, n, k }, JobInputs::Matmul { a, b }) => vec![
            Value::mat_f64(a.clone(), *m as usize, *k as usize),
            Value::mat_f64(b.clone(), *k as usize, *n as usize),
        ],
        (JobSpec::Atax { m, n }, JobInputs::Atax { a, x }) => vec![
            Value::mat_f64(a.clone(), *m as usize, *n as usize),
            Value::vec_f64(x.clone()),
        ],
        (JobSpec::Covariance { m, n }, JobInputs::Covariance { data }) => {
            vec![Value::mat_f64(data.clone(), *m as usize, *n as usize)]
        }
        (JobSpec::Bfs { nodes, .. }, JobInputs::Bfs { adj, src }) => vec![
            Value::mat_f64(adj.clone(), *nodes as usize, *nodes as usize),
            Value::scalar_i32(*src),
        ],
        _ => bail!("inputs do not match job spec {spec:?}"),
    })
}

/// Execute `spec` on the runtime with `inputs`; returns the raw outputs.
pub fn execute_job(rt: &PjrtRuntime, spec: &JobSpec, inputs: &JobInputs) -> Result<Vec<Value>> {
    let id = spec.id();
    let values = values_for(spec, inputs)?;
    rt.execute(&id, &values)
}

/// Verify outputs against the expectation from `datagen::generate`.
pub fn verify_job(spec: &JobSpec, expected: &JobExpected, outputs: &[Value]) -> Result<()> {
    if outputs.len() != 1 {
        bail!("expected single-output jobs, got {}", outputs.len());
    }
    match (spec.kind(), &outputs[0]) {
        (crate::kernels::KernelKind::Bfs, Value::I32 { data, .. }) => {
            datagen::verify_i32(expected, data).map_err(|e| anyhow!("{spec:?}: {e}"))
        }
        (_, Value::F64 { data, .. }) => {
            datagen::verify_f64(expected, data, 1e-9, 1e-9).map_err(|e| anyhow!("{spec:?}: {e}"))
        }
        (k, v) => bail!("unexpected output dtype {:?} for {k:?}", v.dtype()),
    }
}

/// Generate inputs, execute through PJRT, verify. The full functional
/// round trip for one job; returns the outputs on success.
pub fn run_and_verify(rt: &PjrtRuntime, spec: &JobSpec, seed: u64) -> Result<Vec<Value>> {
    let (inputs, expected) = datagen::generate(spec, seed);
    let outputs = execute_job(rt, spec, &inputs)?;
    verify_job(spec, &expected, &outputs)?;
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_for_axpy_has_three_inputs() {
        let spec = JobSpec::Axpy { n: 8 };
        let (inputs, _) = datagen::generate(&spec, 1);
        let v = values_for(&spec, &inputs).unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].shape(), &[] as &[usize]);
        assert_eq!(v[1].shape(), &[8]);
    }

    #[test]
    fn values_for_rejects_mismatched_inputs() {
        let spec = JobSpec::Axpy { n: 8 };
        let (inputs, _) = datagen::generate(&JobSpec::MonteCarlo { samples: 8 }, 1);
        assert!(values_for(&spec, &inputs).is_err());
    }

    #[test]
    fn verify_rejects_wrong_values() {
        let spec = JobSpec::Axpy { n: 4 };
        let expected = JobExpected::F64(vec![1.0, 2.0, 3.0, 4.0]);
        let good = [Value::vec_f64(vec![1.0, 2.0, 3.0, 4.0])];
        let bad = [Value::vec_f64(vec![1.0, 2.0, 3.0, 5.0])];
        assert!(verify_job(&spec, &expected, &good).is_ok());
        assert!(verify_job(&spec, &expected, &bad).is_err());
    }
}
