//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (build time) and the Rust runtime (request path).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::json::Json;

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F64,
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f64" => DType::F64,
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_u64().map(|u| u as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("non-integer shape"))?;
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing dtype"))?,
        )?;
        Ok(Self { shape, dtype })
    }
}

/// One AOT-compiled kernel variant.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kernel: String,
    pub id: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub params: HashMap<String, u64>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
    by_id: HashMap<String, usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != "hlo-text" {
            bail!("unsupported manifest format {format:?} (want hlo-text)");
        }
        let mut entries = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let mut params = HashMap::new();
            if let Some(Json::Obj(p)) = a.get("params") {
                for (k, v) in p {
                    params.insert(
                        k.clone(),
                        v.as_u64().ok_or_else(|| anyhow!("non-integer param {k}"))?,
                    );
                }
            }
            let parse_specs = |k: &str| -> Result<Vec<TensorSpec>> {
                a.get(k)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            entries.push(ArtifactEntry {
                kernel: get_str("kernel")?,
                id: get_str("id")?,
                file: get_str("file")?,
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                params,
            });
        }
        let mut by_id = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            if by_id.insert(e.id.clone(), i).is_some() {
                bail!("duplicate artifact id {:?}", e.id);
            }
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            entries,
            by_id,
        })
    }

    pub fn get(&self, id: &str) -> Option<&ArtifactEntry> {
        self.by_id.get(id).map(|&i| &self.entries[i])
    }

    /// Absolute path of an entry's HLO text file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }

    /// Ids of all variants of a kernel, sorted.
    pub fn variants_of(&self, kernel: &str) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel)
            .map(|e| e.id.as_str())
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"kernel": "axpy", "id": "axpy_n256", "params": {"n": 256},
         "inputs": [{"shape": [], "dtype": "f64"},
                    {"shape": [256], "dtype": "f64"},
                    {"shape": [256], "dtype": "f64"}],
         "outputs": [{"shape": [256], "dtype": "f64"}],
         "file": "axpy_n256.hlo.txt", "sha256": "x"},
        {"kernel": "bfs", "id": "bfs_n64", "params": {"n": 64},
         "inputs": [{"shape": [64, 64], "dtype": "f64"},
                    {"shape": [], "dtype": "i32"}],
         "outputs": [{"shape": [64], "dtype": "i32"}],
         "file": "bfs_n64.hlo.txt", "sha256": "y"}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("axpy_n256").unwrap();
        assert_eq!(e.kernel, "axpy");
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, Vec::<usize>::new());
        assert_eq!(e.inputs[1].element_count(), 256);
        assert_eq!(e.params["n"], 256);
        assert_eq!(m.path_of(e), PathBuf::from("/tmp/a/axpy_n256.hlo.txt"));
    }

    #[test]
    fn bfs_entry_types() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        let e = m.get("bfs_n64").unwrap();
        assert_eq!(e.inputs[1].dtype, DType::I32);
        assert_eq!(e.outputs[0].dtype, DType::I32);
    }

    #[test]
    fn variants_lookup() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert_eq!(m.variants_of("axpy"), vec!["axpy_n256"]);
        assert!(m.variants_of("nope").is_empty());
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(Path::new("."), &bad).is_err());
    }

    #[test]
    fn rejects_duplicate_ids() {
        let dup = SAMPLE.replace("bfs_n64", "axpy_n256");
        assert!(Manifest::parse(Path::new("."), &dup).is_err());
    }
}
