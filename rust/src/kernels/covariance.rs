//! Covariance workload descriptor (§5.1).
//!
//! PolyBench covariance of an (M x N) data matrix. The paper groups it
//! with ATAX and BFS: "the Covariance and BFS kernels ... feature similar
//! communication patterns" (§5.3) — the full data matrix is broadcast to
//! every cluster (mean subtraction needs all N observations of every
//! variable), the centering pass is redundant per cluster, and only the
//! rank-N update producing an M/n-row slab of the output is partitioned.

use crate::config::TimingConfig;

use super::partition;

/// Cycles per element of the redundant mean+centering passes (2 sweeps
/// over the data at ~1 cy/elem each on the 8-core cluster — load-bound).
pub const CENTER_CYCLES_PER_ELEM: u64 = 2;

pub fn operand_transfers(m: u64, n: u64) -> Vec<u64> {
    // Whole data matrix to every cluster.
    vec![m * n * 8]
}

pub fn compute_cycles(
    m: u64,
    n: u64,
    n_clusters: usize,
    c: usize,
    t: &TimingConfig,
) -> u64 {
    let rows = partition(m, n_clusters, c);
    let center = CENTER_CYCLES_PER_ELEM * m * n;
    // Rank-N update for this cluster's row slab: rows * M * N MACs / 8.
    let update = (rows * m * n).div_ceil(8);
    t.compute_init + center + update
}

pub fn writeback_bytes(m: u64, _n: u64, n_clusters: usize, c: usize) -> u64 {
    partition(m, n_clusters, c) * m * 8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_class_volume() {
        let per: u64 = operand_transfers(32, 64).iter().sum();
        assert_eq!(per, 32 * 64 * 8);
    }

    #[test]
    fn update_parallelizes_centering_does_not() {
        let t = TimingConfig::default();
        let f1 = compute_cycles(32, 64, 1, 0, &t);
        let f32 = compute_cycles(32, 64, 32, 0, &t);
        // Large serial fraction: bounded speedup on phase F.
        let s = f1 as f64 / f32 as f64;
        assert!(s > 1.0 && s < 3.0, "speedup {s}");
    }

    #[test]
    fn writeback_covers_output() {
        let m = 32u64;
        let total: u64 = (0..8).map(|c| writeback_bytes(m, 64, 8, c)).sum();
        assert_eq!(total, m * m * 8);
    }
}
