//! Monte Carlo pi workload descriptor (§5.1).
//!
//! Sample generation happens on-cluster (no operands to fetch: phase E is
//! empty), making Monte Carlo the purest Amdahl-class workload: only the
//! 8-byte partial count returns in phase G. The per-sample cost models
//! the Snitch LCG + FP compare sequence.

use crate::config::TimingConfig;

use super::partition;

/// Cycles per sample per core: LCG advance (x2), scale to [0,1) (x2),
/// two multiplies, add, compare, conditional increment — pseudo-dual-issue
/// on Snitch streams this at ~11 cycles.
pub const CYCLES_PER_SAMPLE: u64 = 11;

/// No operands: points are generated from the seed argument.
pub fn operand_transfers() -> Vec<u64> {
    vec![]
}

pub fn compute_cycles(
    samples: u64,
    n_clusters: usize,
    c: usize,
    t: &TimingConfig,
) -> u64 {
    let mine = partition(samples, n_clusters, c);
    let cores = 8;
    t.compute_init + (mine * CYCLES_PER_SAMPLE).div_ceil(cores)
}

/// One 8-byte partial count per cluster.
pub fn writeback_bytes() -> u64 {
    8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_operand_traffic() {
        assert!(operand_transfers().is_empty());
    }

    #[test]
    fn near_perfect_strong_scaling() {
        let t = TimingConfig::default();
        let f1 = compute_cycles(4096, 1, 0, &t) - t.compute_init;
        let f16 = compute_cycles(4096, 16, 0, &t) - t.compute_init;
        assert!(f1 / f16 >= 15 && f1 / f16 <= 16);
    }

    #[test]
    fn writeback_is_tiny() {
        assert_eq!(writeback_bytes(), 8);
    }
}
