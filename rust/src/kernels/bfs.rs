//! BFS workload descriptor (Graph500-style traversal, §5.1).
//!
//! Dense-adjacency frontier expansion. The adjacency matrix is broadcast
//! to every cluster (each expansion step needs the full column set), the
//! node range is partitioned for the per-level expansion, and every level
//! ends with a cluster-wide synchronization — the per-level barrier and
//! frontier exchange are what keep BFS in the broadcast/non-Amdahl class
//! together with ATAX and Covariance (§5.3).

use crate::config::TimingConfig;

use super::partition;

/// Cycles per adjacency element scanned during one level expansion
/// (load + test + conditional distance update, 8 cores).
pub const SCAN_CYCLES_PER_ELEM_NUM: u64 = 2;

/// Per-level synchronization + frontier exchange overhead (cycles).
pub const LEVEL_SYNC_CYCLES: u64 = 60;

pub fn operand_transfers(nodes: u64) -> Vec<u64> {
    // Whole adjacency matrix to every cluster.
    vec![nodes * nodes * 8]
}

pub fn compute_cycles(nodes: u64, levels: u64, n_clusters: usize, t: &TimingConfig) -> u64 {
    // Each level scans the frontier's adjacency rows; aggregated over a
    // full traversal the scans cover ~the whole matrix once, split across
    // levels and partitioned across clusters.
    let my_cols = partition(nodes, n_clusters, 0); // max chunk
    let total_scan = (nodes * my_cols * SCAN_CYCLES_PER_ELEM_NUM).div_ceil(8);
    let lv = levels.max(1);
    t.compute_init + lv * LEVEL_SYNC_CYCLES + total_scan
}

pub fn writeback_bytes(nodes: u64, n_clusters: usize, c: usize) -> u64 {
    // int32 distances, partitioned.
    partition(nodes, n_clusters, c) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacency_broadcast() {
        assert_eq!(operand_transfers(64), vec![64 * 64 * 8]);
    }

    #[test]
    fn level_overhead_grows_with_depth() {
        let t = TimingConfig::default();
        let shallow = compute_cycles(64, 2, 8, &t);
        let deep = compute_cycles(64, 8, 8, &t);
        assert!(deep > shallow);
    }

    #[test]
    fn expansion_parallelizes() {
        let t = TimingConfig::default();
        let f1 = compute_cycles(128, 4, 1, &t);
        let f16 = compute_cycles(128, 4, 16, &t);
        assert!(f1 > f16);
    }

    #[test]
    fn distances_are_int32() {
        let total: u64 = (0..4).map(|c| writeback_bytes(100, 4, c)).sum();
        assert_eq!(total, 400);
    }
}
