//! ATAX workload descriptor, calibrated to the paper's Eq. 6:
//!
//! ```text
//! t(n) = 566 + 3.98*N*M + 2.9*N/(n*8) + N*(1+M)/8 * n
//! ```
//!
//! The implementation the paper measures broadcasts the whole A matrix
//! and x vector to every selected cluster (the `N*(1+M)/8 * n` term: n
//! sequential full-size transfers through the single wide-SPM port),
//! computes the A^T(Ax) passes redundantly per cluster (the `3.98*N*M`
//! term, independent of n), and partitions only the final y writeback
//! (part of the `2.9*N/(n*8)` term). This communication pattern is why
//! ATAX "does not follow Amdahl's law directly" (§5.6) and shows
//! near-constant ideal speedups (§5.3).

use crate::config::TimingConfig;

use super::partition;

/// Eq. 6 compute coefficient: 3.98 cycles per element of A, stored as a
/// rational for integer-exact simulation.
pub const CYCLES_PER_ELEM_NUM: u64 = 398;
pub const CYCLES_PER_ELEM_DEN: u64 = 100;

/// Phase-F constant for ATAX, chosen so the composed model constant is
/// Eq. 6's 566 (see `model::analytical` tests).
pub const INIT_CYCLES: u64 = 221;

/// Phase-F parallel coefficient: Eq. 6's 2.9*N/(8n) splits into N/(8n)
/// writeback beats (phase G) and 1.9*N/(8n) cycles of parallel epilogue
/// in phase F (per-column reduction + store of the y chunk).
pub const PAR_NUM: u64 = 19;
pub const PAR_DEN: u64 = 10;

/// Phase E: every cluster fetches the full A (M*N doubles) and x (N).
pub fn operand_transfers(m: u64, n: u64) -> Vec<u64> {
    vec![m * n * 8, n * 8]
}

/// Phase F: redundant full-A passes + parallelized epilogue.
pub fn compute_cycles(m: u64, n: u64, n_clusters: usize, t: &TimingConfig) -> u64 {
    let _ = t; // ATAX's init is its own calibrated constant
    let serial = (m * n * CYCLES_PER_ELEM_NUM).div_ceil(CYCLES_PER_ELEM_DEN);
    let chunk = partition(n, n_clusters, 0); // max chunk (first cluster)
    let parallel = (chunk * PAR_NUM).div_ceil(PAR_DEN * 8);
    INIT_CYCLES + serial + parallel
}

/// Phase G: the cluster's y chunk.
pub fn writeback_bytes(_m: u64, n: u64, n_clusters: usize, c: usize) -> u64 {
    partition(n, n_clusters, c) * 8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_volume_grows_linearly() {
        // Eq. 6's n-linear term: total phase-E bytes = n * (M*N + N) * 8.
        let (m, n) = (64u64, 64u64);
        let per: u64 = operand_transfers(m, n).iter().sum();
        assert_eq!(per, (m * n + n) * 8);
        for nc in [1u64, 8, 32] {
            assert_eq!(nc * per, nc * (m * n + n) * 8);
        }
    }

    #[test]
    fn beats_match_eq6_linear_term() {
        // N*(1+M)/8 beats per cluster on the 64 B/cycle port.
        let (m, n) = (64u64, 64u64);
        let bytes: u64 = operand_transfers(m, n).iter().sum();
        assert_eq!(bytes / 64, n * (1 + m) / 8);
    }

    #[test]
    fn compute_dominated_by_serial_term() {
        let t = TimingConfig::default();
        let f1 = compute_cycles(64, 64, 1, &t);
        let f32 = compute_cycles(64, 64, 32, &t);
        // Speedup of phase F alone is marginal (paper: near-constant
        // ideal speedups, Fig. 8).
        assert!((f1 as f64) / (f32 as f64) < 1.05, "f1={f1} f32={f32}");
        // And the 3.98*M*N term is present.
        let serial = 398 * 64 * 64 / 100;
        assert!(f1 >= serial);
    }

    #[test]
    fn writeback_partitions_y() {
        let total: u64 = (0..16).map(|c| writeback_bytes(64, 64, 16, c)).sum();
        assert_eq!(total, 64 * 8);
    }
}
