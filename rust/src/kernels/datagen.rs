//! Deterministic workload data generation + native reference results.
//!
//! The coordinator stages these inputs in the (simulated) wide SPM and
//! feeds them to the PJRT executables; the native references here are the
//! *second*, independent implementation used to verify the HLO artifacts'
//! numerics end-to-end (the first being python's `ref.py` at build time).

use crate::rng::Rng64;

use super::JobSpec;

/// Inputs of a job, in the layouts the HLO artifacts expect.
#[derive(Debug, Clone, PartialEq)]
pub enum JobInputs {
    /// alpha, x\[n\], y\[n\]
    Axpy { alpha: f64, x: Vec<f64>, y: Vec<f64> },
    /// seed for the on-device threefry generator
    MonteCarlo { seed: u32 },
    /// a\[m*k\], b\[k*n\] (row-major)
    Matmul { a: Vec<f64>, b: Vec<f64> },
    /// a\[m*n\], x\[n\]
    Atax { a: Vec<f64>, x: Vec<f64> },
    /// data\[m*n\]
    Covariance { data: Vec<f64> },
    /// adj\[n*n\] (0/1 doubles), src
    Bfs { adj: Vec<f64>, src: i32 },
}

/// Expected outputs for verification.
#[derive(Debug, Clone, PartialEq)]
pub enum JobExpected {
    /// Exact element-wise reference (f64) with tolerance.
    F64(Vec<f64>),
    /// Exact int32 reference.
    I32(Vec<i32>),
    /// A scalar in [lo, hi] (Monte Carlo estimates).
    ScalarRange { lo: f64, hi: f64 },
}

fn randn(rng: &mut Rng64, n: usize) -> Vec<f64> {
    // Uniform(-1,1): plenty for numerics checks and has no
    // tail-magnitude surprises.
    (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect()
}

/// Generate inputs + expected outputs for `spec`, deterministically from
/// `seed`.
pub fn generate(spec: &JobSpec, seed: u64) -> (JobInputs, JobExpected) {
    let mut rng = Rng64::seed_from_u64(seed);
    match *spec {
        JobSpec::Axpy { n } => {
            let alpha = rng.gen_range_f64(-2.0, 2.0);
            let x = randn(&mut rng, n as usize);
            let y = randn(&mut rng, n as usize);
            let z = axpy_ref(alpha, &x, &y);
            (JobInputs::Axpy { alpha, x, y }, JobExpected::F64(z))
        }
        JobSpec::MonteCarlo { samples } => {
            let seed32 = (seed & 0xffff_ffff) as u32;
            // 4-sigma binomial bound around pi.
            let n = samples as f64;
            let sigma = 4.0 * (std::f64::consts::PI / 4.0 * (1.0 - std::f64::consts::PI / 4.0) / n).sqrt();
            (
                JobInputs::MonteCarlo { seed: seed32 },
                JobExpected::ScalarRange {
                    lo: std::f64::consts::PI - 4.0 * sigma * 4.0,
                    hi: std::f64::consts::PI + 4.0 * sigma * 4.0,
                },
            )
        }
        JobSpec::Matmul { m, n, k } => {
            let a = randn(&mut rng, (m * k) as usize);
            let b = randn(&mut rng, (k * n) as usize);
            let c = matmul_ref(&a, &b, m as usize, n as usize, k as usize);
            (JobInputs::Matmul { a, b }, JobExpected::F64(c))
        }
        JobSpec::Atax { m, n } => {
            let a = randn(&mut rng, (m * n) as usize);
            let x = randn(&mut rng, n as usize);
            let y = atax_ref(&a, &x, m as usize, n as usize);
            (JobInputs::Atax { a, x }, JobExpected::F64(y))
        }
        JobSpec::Covariance { m, n } => {
            let data = randn(&mut rng, (m * n) as usize);
            let c = covariance_ref(&data, m as usize, n as usize);
            (JobInputs::Covariance { data }, JobExpected::F64(c))
        }
        JobSpec::Bfs { nodes, levels } => {
            let (adj, src) = gen_graph(&mut rng, nodes as usize, levels as usize);
            let dist = bfs_ref(&adj, nodes as usize, src);
            (
                JobInputs::Bfs {
                    adj,
                    src: src as i32,
                },
                JobExpected::I32(dist),
            )
        }
    }
}

// ------------------------------------------------------------ references

pub fn axpy_ref(alpha: f64, x: &[f64], y: &[f64]) -> Vec<f64> {
    x.iter().zip(y).map(|(a, b)| alpha * a + b).collect()
}

pub fn matmul_ref(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                c[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    c
}

pub fn atax_ref(a: &[f64], x: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut tmp = vec![0.0; m];
    for i in 0..m {
        tmp[i] = (0..n).map(|j| a[i * n + j] * x[j]).sum();
    }
    let mut y = vec![0.0; n];
    for i in 0..m {
        for j in 0..n {
            y[j] += a[i * n + j] * tmp[i];
        }
    }
    y
}

pub fn covariance_ref(data: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut mean = vec![0.0; m];
    for i in 0..m {
        mean[i] = (0..n).map(|j| data[i * n + j]).sum::<f64>() / n as f64;
    }
    let mut cov = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..m {
            let s: f64 = (0..n)
                .map(|t| (data[i * n + t] - mean[i]) * (data[j * n + t] - mean[j]))
                .sum();
            cov[i * m + j] = s / (n as f64 - 1.0);
        }
    }
    cov
}

pub fn bfs_ref(adj: &[f64], n: usize, src: usize) -> Vec<i32> {
    let mut dist = vec![-1i32; n];
    dist[src] = 0;
    let mut frontier = vec![src];
    let mut level = 0i32;
    while !frontier.is_empty() {
        level += 1;
        let mut next = Vec::new();
        for &u in &frontier {
            for v in 0..n {
                if adj[u * n + v] > 0.0 && dist[v] < 0 {
                    dist[v] = level;
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    dist
}

/// Random connected-ish graph whose BFS tree from node 0 has roughly
/// `levels` levels: a layered graph with random intra/inter-layer edges.
fn gen_graph(rng: &mut Rng64, n: usize, levels: usize) -> (Vec<f64>, usize) {
    let levels = levels.clamp(1, n.max(1));
    let mut adj = vec![0.0; n * n];
    let per_layer = n.div_ceil(levels);
    let layer_of = |v: usize| (v / per_layer).min(levels - 1);
    let add = |adj: &mut Vec<f64>, u: usize, v: usize| {
        if u != v {
            adj[u * n + v] = 1.0;
            adj[v * n + u] = 1.0;
        }
    };
    // Chain guaranteeing the layer structure: each vertex links to some
    // vertex of the previous layer.
    for v in 1..n {
        let l = layer_of(v);
        if l == 0 {
            add(&mut adj, v, 0);
        } else {
            let prev_start = (l - 1) * per_layer;
            let prev_end = (l * per_layer).min(n);
            let u = rng.gen_range_usize(prev_start, prev_end);
            add(&mut adj, v, u);
        }
    }
    // Extra random edges within / between adjacent layers.
    let extra = n; // sparse
    for _ in 0..extra {
        let u = rng.gen_range_usize(0, n);
        let lu = layer_of(u);
        let lo = lu.saturating_sub(1) * per_layer;
        let hi = (((lu + 1) * per_layer).min(n)).max(lo + 1);
        let v = rng.gen_range_usize(lo, hi);
        add(&mut adj, u, v);
    }
    (adj, 0)
}

/// Verify a flat f64 result against the expectation.
pub fn verify_f64(expected: &JobExpected, got: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    match expected {
        JobExpected::F64(want) => {
            if want.len() != got.len() {
                return Err(format!("length mismatch: {} vs {}", want.len(), got.len()));
            }
            for (i, (w, g)) in want.iter().zip(got).enumerate() {
                let tol = atol + rtol * w.abs();
                if (w - g).abs() > tol {
                    return Err(format!("elem {i}: want {w}, got {g} (tol {tol})"));
                }
            }
            Ok(())
        }
        JobExpected::ScalarRange { lo, hi } => {
            if got.len() != 1 {
                return Err(format!("expected scalar, got {} elems", got.len()));
            }
            if got[0] < *lo || got[0] > *hi {
                return Err(format!("scalar {} outside [{lo}, {hi}]", got[0]));
            }
            Ok(())
        }
        JobExpected::I32(_) => Err("expected i32 output, got f64".into()),
    }
}

/// Verify a flat i32 result.
pub fn verify_i32(expected: &JobExpected, got: &[i32]) -> Result<(), String> {
    match expected {
        JobExpected::I32(want) => {
            if want != got {
                let first = want
                    .iter()
                    .zip(got)
                    .position(|(a, b)| a != b)
                    .unwrap_or(usize::MAX);
                return Err(format!("i32 mismatch at {first}"));
            }
            Ok(())
        }
        _ => Err("expected f64/scalar output, got i32".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = JobSpec::Axpy { n: 64 };
        let (a, _) = generate(&spec, 7);
        let (b, _) = generate(&spec, 7);
        assert_eq!(a, b);
        let (c, _) = generate(&spec, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn axpy_reference() {
        let z = axpy_ref(2.0, &[1.0, 2.0], &[10.0, 20.0]);
        assert_eq!(z, vec![12.0, 24.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul_ref(&a, &i, 2, 2, 2), a);
    }

    #[test]
    fn atax_matches_two_matvecs() {
        // A = [[1,2],[3,4]], x = [1,1]: tmp = [3,7], y = A^T tmp = [24,34].
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let y = atax_ref(&a, &[1.0, 1.0], 2, 2);
        assert_eq!(y, vec![24.0, 34.0]);
    }

    #[test]
    fn covariance_of_constant_rows_is_zero() {
        let data = vec![5.0; 3 * 8];
        let c = covariance_ref(&data, 3, 8);
        assert!(c.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn covariance_symmetric() {
        let (inp, _) = generate(&JobSpec::Covariance { m: 8, n: 16 }, 3);
        let JobInputs::Covariance { data } = inp else {
            unreachable!()
        };
        let c = covariance_ref(&data, 8, 16);
        for i in 0..8 {
            for j in 0..8 {
                assert!((c[i * 8 + j] - c[j * 8 + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn bfs_graph_has_requested_depth() {
        for levels in [1usize, 2, 4, 6] {
            let (inp, exp) = generate(
                &JobSpec::Bfs {
                    nodes: 64,
                    levels: levels as u64,
                },
                11,
            );
            let JobInputs::Bfs { adj, src } = inp else {
                unreachable!()
            };
            let JobExpected::I32(dist) = exp else {
                unreachable!()
            };
            assert_eq!(dist, bfs_ref(&adj, 64, src as usize));
            let max_level = *dist.iter().max().unwrap();
            assert!(
                (max_level as i64 - levels as i64).abs() <= 1,
                "levels={levels} got {max_level}"
            );
            // Connected: everything reachable.
            assert!(dist.iter().all(|&d| d >= 0));
        }
    }

    #[test]
    fn verify_f64_catches_mismatch() {
        let exp = JobExpected::F64(vec![1.0, 2.0]);
        assert!(verify_f64(&exp, &[1.0, 2.0], 1e-12, 1e-12).is_ok());
        assert!(verify_f64(&exp, &[1.0, 2.1], 1e-12, 1e-12).is_err());
        assert!(verify_f64(&exp, &[1.0], 1e-12, 1e-12).is_err());
    }

    #[test]
    fn verify_scalar_range() {
        let exp = JobExpected::ScalarRange { lo: 3.0, hi: 3.3 };
        assert!(verify_f64(&exp, &[3.14], 0.0, 0.0).is_ok());
        assert!(verify_f64(&exp, &[2.0], 0.0, 0.0).is_err());
    }
}
