//! AXPY workload descriptor — the paper's running example (Eqs. 1, 2, 5).
//!
//! Vectors are partitioned contiguously across clusters: each cluster
//! DMA-fetches its x and y chunks (phase E), the eight compute cores
//! stream the FMA at the measured 1.47 cycles/element aggregate rate
//! (phase F, Eq. 2), and the z chunk is written back (phase G, Eq. 3).
//! Total communication volume is independent of the cluster count, which
//! is what makes AXPY Amdahl-class (§5.3).

use crate::config::TimingConfig;

use super::partition;

/// Measured per-element cost: "it then takes 1.47 cycles on average to
/// calculate each output vector element", distributed over the 8 compute
/// cores (§5.5.F). Stored as a rational (147/100) to keep the simulator
/// integer-exact.
pub const CYCLES_PER_ELEM_NUM: u64 = 147;
pub const CYCLES_PER_ELEM_DEN: u64 = 100;

/// Phase E: the cluster's x and y chunks (two DMA transfers, §5.5.E).
pub fn operand_transfers(n: u64, n_clusters: usize, c: usize) -> Vec<u64> {
    let elems = partition(n, n_clusters, c);
    if elems == 0 {
        return vec![];
    }
    vec![elems * 8, elems * 8]
}

/// Phase F (Eq. 2): t_init + elems * 1.47 / 8.
pub fn compute_cycles(n: u64, n_clusters: usize, c: usize, t: &TimingConfig) -> u64 {
    let elems = partition(n, n_clusters, c);
    let cores = 8;
    t.compute_init
        + (elems * CYCLES_PER_ELEM_NUM).div_ceil(CYCLES_PER_ELEM_DEN * cores)
}

/// Phase G: the cluster's z chunk (one DMA transfer, Eq. 3).
pub fn writeback_bytes(n: u64, n_clusters: usize, c: usize) -> u64 {
    partition(n, n_clusters, c) * 8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_phase_f_single_cluster() {
        // Eq. 2 with n=1, N=1024: 55 + 1.47*1024/8 = 55 + 188.16 -> 244.
        let t = TimingConfig::default();
        assert_eq!(compute_cycles(1024, 1, 0, &t), 55 + 189); // ceil
    }

    #[test]
    fn phase_f_scales_with_clusters() {
        let t = TimingConfig::default();
        let f1 = compute_cycles(4096, 1, 0, &t) - t.compute_init;
        let f32 = compute_cycles(4096, 32, 0, &t) - t.compute_init;
        // Parallel fraction shrinks ~32x (integer rounding aside).
        assert!(f1 >= 31 * f32 && f1 <= 33 * f32, "f1={f1} f32={f32}");
    }

    #[test]
    fn eq1_total_beats_constant() {
        // 16 KiB total (N=1024 doubles x 2 vectors) regardless of the
        // offload configuration (§5.5.E).
        for n_clusters in [1usize, 2, 4, 8, 16, 32] {
            let total: u64 = (0..n_clusters)
                .map(|c| operand_transfers(1024, n_clusters, c).iter().sum::<u64>())
                .sum();
            assert_eq!(total, 16 * 1024);
        }
    }

    #[test]
    fn writeback_partitions_exactly() {
        let total: u64 = (0..32).map(|c| writeback_bytes(1000, 32, c)).sum();
        assert_eq!(total, 8000);
    }

    #[test]
    fn idle_cluster_has_no_transfers() {
        // More clusters than elements: the surplus clusters fetch nothing.
        assert!(operand_transfers(2, 4, 3).is_empty());
    }
}
