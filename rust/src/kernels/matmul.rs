//! Matmul workload descriptor (§5.1).
//!
//! C is partitioned on a 2-D grid of clusters (gr x gc): cluster (i, j)
//! fetches an M/gr-row slab of A and a N/gc-column slab of B, computes its
//! C tile at 1 MAC/cycle/core, and writes the tile back. Replication
//! grows only with the grid perimeter (~sqrt(n)), so at the benchmarked
//! sizes compute dominates and matmul behaves Amdahl-class (§5.3: "the
//! memory transfers and corresponding stalls are short").

use crate::config::TimingConfig;

use super::partition;

/// Split `n` clusters into a near-square (rows, cols) grid; both factors
/// are powers of two when `n` is.
pub fn grid(n_clusters: usize) -> (usize, usize) {
    let mut rows = 1usize;
    while rows * rows < n_clusters {
        rows *= 2;
    }
    while n_clusters % rows != 0 {
        rows /= 2;
    }
    (rows, n_clusters / rows)
}

fn tile(m: u64, n: u64, n_clusters: usize, c: usize) -> (u64, u64) {
    let (gr, gc) = grid(n_clusters);
    let (i, j) = (c / gc, c % gc);
    (partition(m, gr, i), partition(n, gc, j))
}

/// Phase E: the A slab and the B slab.
pub fn operand_transfers(m: u64, n: u64, k: u64, n_clusters: usize, c: usize) -> Vec<u64> {
    let (tm, tn) = tile(m, n, n_clusters, c);
    let mut v = Vec::new();
    if tm > 0 {
        v.push(tm * k * 8);
    }
    if tn > 0 {
        v.push(k * tn * 8);
    }
    if tm == 0 || tn == 0 {
        v.clear();
    }
    v
}

/// Phase F: tile MACs at 1 MAC/cycle/core over 8 cores.
pub fn compute_cycles(
    m: u64,
    n: u64,
    k: u64,
    n_clusters: usize,
    c: usize,
    t: &TimingConfig,
) -> u64 {
    let (tm, tn) = tile(m, n, n_clusters, c);
    t.compute_init + (tm * tn * k).div_ceil(8)
}

/// Phase G: the C tile.
pub fn writeback_bytes(m: u64, n: u64, _k: u64, n_clusters: usize, c: usize) -> u64 {
    let (tm, tn) = tile(m, n, n_clusters, c);
    tm * tn * 8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_factors() {
        assert_eq!(grid(1), (1, 1));
        assert_eq!(grid(2), (2, 1));
        assert_eq!(grid(4), (2, 2));
        assert_eq!(grid(8), (4, 2));
        assert_eq!(grid(16), (4, 4));
        assert_eq!(grid(32), (8, 4));
    }

    #[test]
    fn writeback_tiles_cover_c() {
        for nc in [1usize, 2, 4, 8, 16, 32] {
            let total: u64 = (0..nc).map(|c| writeback_bytes(64, 64, 64, nc, c)).sum();
            assert_eq!(total, 64 * 64 * 8, "nc={nc}");
        }
    }

    #[test]
    fn macs_cover_problem() {
        let t = TimingConfig::default();
        for nc in [1usize, 4, 32] {
            let total: u64 = (0..nc)
                .map(|c| compute_cycles(64, 64, 64, nc, c, &t) - t.compute_init)
                .sum();
            // Total cycle-sum ~ M*N*K/8 (ceil rounding per cluster).
            let want = 64u64 * 64 * 64 / 8;
            assert!(total >= want && total <= want + nc as u64, "nc={nc}");
        }
    }

    #[test]
    fn replication_grows_sublinearly() {
        // Total operand volume at 32 clusters is well below 32x the
        // single-cluster volume (contrast with ATAX's full replication).
        let v1: u64 = operand_transfers(64, 64, 64, 1, 0).iter().sum();
        let v32: u64 = (0..32)
            .map(|c| operand_transfers(64, 64, 64, 32, c).iter().sum::<u64>())
            .sum();
        assert!(v32 < 8 * v1, "v1={v1} v32={v32}");
    }
}
