//! The six offloaded workloads of the paper (§5.1) as *workload
//! descriptors*: per-cluster DMA transfer plans (phase E/G) and compute
//! cost functions (phase F), calibrated to the paper's measured
//! coefficients (Eq. 2 for AXPY, Eq. 6 for ATAX; see each kernel module).
//!
//! The descriptors drive both the cycle-level DES (`offload::executor`)
//! and the analytical runtime model (`model::analytical`) — the paper's
//! methodology of composing per-phase models (Eq. 4) reuses exactly these
//! quantities. The *numerics* of each kernel run separately through the
//! AOT-compiled HLO artifacts (`runtime`).


use crate::config::TimingConfig;

pub mod atax;
pub mod axpy;
pub mod bfs;
pub mod covariance;
pub mod datagen;
pub mod matmul;
pub mod montecarlo;

/// Kernel families.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelKind {
    Axpy,
    MonteCarlo,
    Matmul,
    Atax,
    Covariance,
    Bfs,
}

impl KernelKind {
    pub const ALL: [KernelKind; 6] = [
        KernelKind::Axpy,
        KernelKind::MonteCarlo,
        KernelKind::Matmul,
        KernelKind::Atax,
        KernelKind::Covariance,
        KernelKind::Bfs,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Axpy => "axpy",
            KernelKind::MonteCarlo => "montecarlo",
            KernelKind::Matmul => "matmul",
            KernelKind::Atax => "atax",
            KernelKind::Covariance => "covariance",
            KernelKind::Bfs => "bfs",
        }
    }
}

/// A fully-specified job: kernel + problem size. `Ord` (derived, so
/// variant order then field order) lets sim-domain containers key on
/// jobs deterministically instead of in hash order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum JobSpec {
    /// AXPY over vectors of length `n` (paper's running example).
    Axpy { n: u64 },
    /// Monte Carlo pi with `samples` points.
    MonteCarlo { samples: u64 },
    /// (m x k) @ (k x n) matmul.
    Matmul { m: u64, n: u64, k: u64 },
    /// ATAX: A is (m x n), x length n.
    Atax { m: u64, n: u64 },
    /// Covariance of an (m x n) data matrix.
    Covariance { m: u64, n: u64 },
    /// BFS over `nodes` vertices; `levels` = traversal depth of the input
    /// graph (datagen controls it).
    Bfs { nodes: u64, levels: u64 },
}

impl JobSpec {
    pub fn kind(&self) -> KernelKind {
        match self {
            JobSpec::Axpy { .. } => KernelKind::Axpy,
            JobSpec::MonteCarlo { .. } => KernelKind::MonteCarlo,
            JobSpec::Matmul { .. } => KernelKind::Matmul,
            JobSpec::Atax { .. } => KernelKind::Atax,
            JobSpec::Covariance { .. } => KernelKind::Covariance,
            JobSpec::Bfs { .. } => KernelKind::Bfs,
        }
    }

    /// Bytes of job arguments CVA6 communicates in phase A / clusters
    /// fetch in phase D (pointers + sizes + scalars; one cache line).
    pub fn args_bytes(&self) -> u64 {
        match self {
            JobSpec::Axpy { .. } => 40,       // alpha, n, x*, y*, z*
            JobSpec::MonteCarlo { .. } => 24, // seed, samples, out*
            JobSpec::Matmul { .. } => 64,
            JobSpec::Atax { .. } => 48,
            JobSpec::Covariance { .. } => 40,
            JobSpec::Bfs { .. } => 48,
        }
    }

    /// Phase-E DMA plan of cluster `c` out of `n_clusters`: payload bytes
    /// per transfer, fetched from the wide SPM.
    pub fn operand_transfers(&self, n_clusters: usize, c: usize) -> Vec<u64> {
        match *self {
            JobSpec::Axpy { n } => axpy::operand_transfers(n, n_clusters, c),
            JobSpec::MonteCarlo { .. } => montecarlo::operand_transfers(),
            JobSpec::Matmul { m, n, k } => {
                matmul::operand_transfers(m, n, k, n_clusters, c)
            }
            JobSpec::Atax { m, n } => atax::operand_transfers(m, n),
            JobSpec::Covariance { m, n } => covariance::operand_transfers(m, n),
            JobSpec::Bfs { nodes, .. } => bfs::operand_transfers(nodes),
        }
    }

    /// Phase-F compute cycles of cluster `c` (includes the paper's
    /// measured init cost, Eq. 2).
    pub fn compute_cycles(&self, n_clusters: usize, c: usize, t: &TimingConfig) -> u64 {
        match *self {
            JobSpec::Axpy { n } => axpy::compute_cycles(n, n_clusters, c, t),
            JobSpec::MonteCarlo { samples } => {
                montecarlo::compute_cycles(samples, n_clusters, c, t)
            }
            JobSpec::Matmul { m, n, k } => {
                matmul::compute_cycles(m, n, k, n_clusters, c, t)
            }
            JobSpec::Atax { m, n } => atax::compute_cycles(m, n, n_clusters, t),
            JobSpec::Covariance { m, n } => {
                covariance::compute_cycles(m, n, n_clusters, c, t)
            }
            JobSpec::Bfs { nodes, levels } => {
                bfs::compute_cycles(nodes, levels, n_clusters, t)
            }
        }
    }

    /// Phase-G writeback bytes of cluster `c`.
    pub fn writeback_bytes(&self, n_clusters: usize, c: usize) -> u64 {
        match *self {
            JobSpec::Axpy { n } => axpy::writeback_bytes(n, n_clusters, c),
            JobSpec::MonteCarlo { .. } => montecarlo::writeback_bytes(),
            JobSpec::Matmul { m, n, k } => {
                matmul::writeback_bytes(m, n, k, n_clusters, c)
            }
            JobSpec::Atax { m, n } => atax::writeback_bytes(m, n, n_clusters, c),
            JobSpec::Covariance { m, n } => {
                covariance::writeback_bytes(m, n, n_clusters, c)
            }
            JobSpec::Bfs { nodes, .. } => bfs::writeback_bytes(nodes, n_clusters, c),
        }
    }

    /// Total operand bytes across all clusters (communication volume).
    pub fn total_operand_bytes(&self, n_clusters: usize) -> u64 {
        (0..n_clusters)
            .map(|c| self.operand_transfers(n_clusters, c).iter().sum::<u64>())
            .sum()
    }

    /// Useful floating-point work of the job (for efficiency metrics).
    pub fn flops(&self) -> u64 {
        match *self {
            JobSpec::Axpy { n } => 2 * n,
            JobSpec::MonteCarlo { samples } => 4 * samples,
            JobSpec::Matmul { m, n, k } => 2 * m * n * k,
            JobSpec::Atax { m, n } => 4 * m * n,
            JobSpec::Covariance { m, n } => 2 * m * n + m * m * n,
            JobSpec::Bfs { nodes, .. } => 2 * nodes * nodes,
        }
    }

    /// Short id for tables/artifact lookup (matches python aot variants
    /// when the sizes line up).
    pub fn id(&self) -> String {
        match *self {
            JobSpec::Axpy { n } => format!("axpy_n{n}"),
            JobSpec::MonteCarlo { samples } => format!("montecarlo_n{samples}"),
            JobSpec::Matmul { m, n, k } => format!("matmul_k{k}_m{m}_n{n}"),
            JobSpec::Atax { m, n } => format!("atax_m{m}_n{n}"),
            JobSpec::Covariance { m, n } => format!("covariance_m{m}_n{n}"),
            JobSpec::Bfs { nodes, .. } => format!("bfs_n{nodes}"),
        }
    }

    /// The trace-store spelling of this spec — the grammar shared by the
    /// campaign store's on-disk filenames, `obs::report::parse_request_key`,
    /// and the fast profile's timeline memoizer. It differs from
    /// [`JobSpec::id`] (which predates the store and is frozen for CSV
    /// compatibility): every dimension is spelled out (`bfs` keeps its
    /// levels, `montecarlo` uses `s` for samples, `matmul` orders m/n/k).
    pub fn store_id(&self) -> String {
        match *self {
            JobSpec::Axpy { n } => format!("axpy_n{n}"),
            JobSpec::MonteCarlo { samples } => format!("montecarlo_s{samples}"),
            JobSpec::Matmul { m, n, k } => format!("matmul_m{m}_n{n}_k{k}"),
            JobSpec::Atax { m, n } => format!("atax_m{m}_n{n}"),
            JobSpec::Covariance { m, n } => format!("covariance_m{m}_n{n}"),
            JobSpec::Bfs { nodes, levels } => format!("bfs_n{nodes}_l{levels}"),
        }
    }
}

/// Evenly partition `total` items over `n` clusters: first `total % n`
/// clusters take one extra item.
pub fn partition(total: u64, n_clusters: usize, c: usize) -> u64 {
    let n = n_clusters as u64;
    let base = total / n;
    let extra = total % n;
    base + if (c as u64) < extra { 1 } else { 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_everything() {
        for total in [0u64, 1, 7, 1024, 1025] {
            for n in [1usize, 2, 3, 8, 32] {
                let sum: u64 = (0..n).map(|c| partition(total, n, c)).sum();
                assert_eq!(sum, total, "total={total} n={n}");
                // Balanced within 1.
                let parts: Vec<u64> = (0..n).map(|c| partition(total, n, c)).collect();
                let (mn, mx) = (
                    *parts.iter().min().unwrap(),
                    *parts.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1);
            }
        }
    }

    #[test]
    fn ids_match_python_aot_naming() {
        assert_eq!(JobSpec::Axpy { n: 1024 }.id(), "axpy_n1024");
        assert_eq!(
            JobSpec::Matmul { m: 64, n: 64, k: 64 }.id(),
            "matmul_k64_m64_n64"
        );
        assert_eq!(JobSpec::Atax { m: 128, n: 128 }.id(), "atax_m128_n128");
    }

    #[test]
    fn amdahl_class_vs_broadcast_class_volume() {
        // §5.3's two application classes: AXPY/MC/Matmul keep (near-)
        // constant total operand volume as clusters scale; ATAX/Cov/BFS
        // replicate operands so volume grows linearly.
        let axpy = JobSpec::Axpy { n: 1024 };
        assert_eq!(
            axpy.total_operand_bytes(1),
            axpy.total_operand_bytes(32)
        );
        let atax = JobSpec::Atax { m: 64, n: 64 };
        assert_eq!(
            atax.total_operand_bytes(32),
            32 * atax.total_operand_bytes(1)
        );
    }

    #[test]
    fn kind_names_unique() {
        let mut names: Vec<&str> = KernelKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6);
    }
}
