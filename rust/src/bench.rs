//! Minimal benchmark harness (criterion is not in the vendored crate
//! set): warmup + timed iterations, mean/σ/min reporting, and a
//! `black_box` to defeat const-folding. Used by every bench target under
//! `rust/benches/`.

use std::hint::black_box as hint_black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} mean  {:>12} min  (±{:?}, {} iters)",
            self.name,
            format!("{:?}", self.mean),
            format!("{:?}", self.min),
            self.std_dev,
            self.iters
        )
    }
}

/// Benchmark runner with fixed warmup/measure iteration counts chosen at
/// call time (simulations here are deterministic, so variance is purely
/// host noise).
pub struct Bench {
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self {
            results: Vec::new(),
        }
    }

    /// Run `f` for `warmup` + `iters` iterations, record stats.
    pub fn run<T>(&mut self, name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) {
        assert!(iters >= 1);
        for _ in 0..warmup {
            hint_black_box(f());
        }
        let mut times = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            hint_black_box(f());
            times.push(t0.elapsed());
        }
        let mean_ns =
            times.iter().map(|d| d.as_nanos()).sum::<u128>() as f64 / iters as f64;
        let var = times
            .iter()
            .map(|d| (d.as_nanos() as f64 - mean_ns).powi(2))
            .sum::<f64>()
            / iters as f64;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean: Duration::from_nanos(mean_ns as u64),
            std_dev: Duration::from_nanos(var.sqrt() as u64),
            min: *times.iter().min().unwrap(),
        };
        println!("{}", m.report());
        self.results.push(m);
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print a footer; call at the end of a bench main.
    pub fn finish(self, target: &str) {
        println!("--- {target}: {} benchmarks done ---", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new();
        b.run("noop", 1, 5, || 42u64);
        b.run("spin", 0, 3, || (0..1000u64).sum::<u64>());
        assert_eq!(b.results().len(), 2);
        assert!(b.results()[1].mean.as_nanos() > 0);
        b.finish("test");
    }
}
