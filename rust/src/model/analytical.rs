//! Analytical offload-runtime model (§5.6).
//!
//! The paper models the runtime of a job offloaded with the *multicast*
//! routines — whose per-phase runtimes are (near-)identical across
//! clusters — as the sum over phases of the per-phase maxima (Eq. 4):
//!
//! ```text
//! t̂(n) = Σ_{p ∈ [A, I]} max_{i ∈ [0, n)} t_p(n, N, i)
//! ```
//!
//! Each phase model below mirrors §5.5's closed forms: constants for
//! A/B/C/D/H/I, Eq. 1 for phase E (single wide-SPM port ⇒ the max sees
//! the combined transfer length), the kernel's compute function for phase
//! F (Eq. 2 for AXPY), and Eq. 3 for phase G (the phase-E completion skew
//! makes writebacks effectively contention-free). The same workload
//! descriptors drive the DES, so model-vs-simulation error (Fig. 12)
//! measures exactly what the paper's validation measures: how much the
//! closed forms miss of the emergent contention/overlap effects.

use crate::config::Config;
use crate::dma::DmaTransfer;
use crate::kernels::JobSpec;
use crate::sim::Phase;

/// Cycles the DM core spends observing a completed DMA (matches the
/// executor's constant).
const DMA_POLL: u64 = 2;
/// CVA6 store-issue cost (matches the executor).
const HOST_STORE_ISSUE: u64 = 8;
/// Per-extra-multicast-transaction cost (matches the executor).
const HOST_EXTRA_TXN: u64 = 4;

/// Per-phase runtime estimates (cycles), composable per Eq. 4.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseEstimates {
    phases: [(Phase, u64); 9],
}

impl PhaseEstimates {
    pub fn get(&self, p: Phase) -> u64 {
        self.phases
            .iter()
            .find(|(q, _)| *q == p)
            .map(|(_, v)| *v)
            .expect("all phases present")
    }

    /// Eq. 4: total = sum of per-phase maxima.
    pub fn total(&self) -> u64 {
        self.phases.iter().map(|(_, v)| v).sum()
    }

    /// The constant (problem-size-independent) part: phases A-D, H, I.
    pub fn offload_constant(&self) -> u64 {
        self.phases
            .iter()
            .filter(|(p, _)| {
                !matches!(
                    p,
                    Phase::RetrieveOperands | Phase::Execute | Phase::Writeback
                )
            })
            .map(|(_, v)| v)
            .sum()
    }
}

/// The analytical model of one job offloaded with the multicast routines.
pub struct OffloadModel<'a> {
    cfg: &'a Config,
}

impl<'a> OffloadModel<'a> {
    pub fn new(cfg: &'a Config) -> Self {
        Self { cfg }
    }

    /// Per-phase estimates for `spec` offloaded to `n` clusters.
    pub fn phases(&self, spec: &JobSpec, n: usize) -> PhaseEstimates {
        let t = &self.cfg.timing;
        let bus = self.cfg.soc.wide_bus_bytes;
        let txns = n.count_ones() as u64; // masked writes per subcube

        // A) Send job information: multicast write + CSR toggles.
        let a = t.host_send_info + t.host_mcast_csr + (txns - 1) * HOST_EXTRA_TXN;
        // B) Wakeup: one (set of) masked MCIP write(s), §5.5.B.
        let b = HOST_STORE_ISSUE + (txns - 1) * HOST_EXTRA_TXN + t.wakeup_hw() + t.mcip_clear;
        // C) Retrieve job pointer: local TCDM access (§5.5.C multicast).
        let c = t.dispatch_load_ptr + t.tcdm_local_load;
        // D) Eliminated by the multicast job-info write (§4.2).
        let d = 0;

        // E) Eq. 1 generalized: single wide-SPM port ⇒ the slowest cluster
        // sees the combined length of ALL clusters' transfers.
        let mut total_beats = 0u64;
        let mut max_transfers = 0u64;
        for i in 0..n {
            let transfers = spec.operand_transfers(n, i);
            max_transfers = max_transfers.max(transfers.len() as u64);
            total_beats += transfers
                .iter()
                .map(|&bytes| {
                    DmaTransfer {
                        bytes,
                        into_tcdm: true,
                    }
                    .beats(bus)
                })
                .sum::<u64>();
        }
        let e = if max_transfers == 0 {
            0
        } else {
            t.dma_setup_phase_entry
                + max_transfers * t.dma_setup_per_transfer
                + t.dma_roundtrip
                + total_beats
                + DMA_POLL
        };

        // F) Kernel compute model (Eq. 2 for AXPY), plus the HW barrier
        // handshakes on both sides.
        let f = (0..n)
            .map(|i| spec.compute_cycles(n, i, t))
            .max()
            .unwrap()
            + t.cluster_barrier;

        // G) Eq. 3: phase-E skew makes the writeback contention-free; the
        // per-cluster runtime is a single transfer.
        let max_wb = (0..n).map(|i| spec.writeback_bytes(n, i)).max().unwrap();
        let g = if max_wb == 0 {
            0
        } else {
            t.cluster_barrier
                + t.dma_setup_per_transfer
                + t.dma_roundtrip
                + DmaTransfer {
                    bytes: max_wb,
                    into_tcdm: false,
                }
                .beats(bus)
                + DMA_POLL
        };

        // H) JCU notification (§4.3): constant and predictable.
        let h = t.jcu_notify_instr + t.cluster_to_clint_oneway() + t.jcu_fire + t.host_wake;
        // I) Resume on host.
        let i = t.host_resume;

        PhaseEstimates {
            phases: [
                (Phase::SendInfo, a),
                (Phase::Wakeup, b),
                (Phase::RetrievePtr, c),
                (Phase::RetrieveArgs, d),
                (Phase::RetrieveOperands, e),
                (Phase::Execute, f),
                (Phase::Writeback, g),
                (Phase::Notify, h),
                (Phase::Resume, i),
            ],
        }
    }

    /// Eq. 4 total estimate.
    pub fn estimate(&self, spec: &JobSpec, n: usize) -> u64 {
        self.phases(spec, n).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_cfg() -> Config {
        Config::default()
    }

    #[test]
    fn axpy_model_matches_eq5_shape() {
        // Eq. 5: t̂(n) = const + N/4 + 2.47*N/(8n). Verify the model's
        // N- and n-dependence matches those coefficients exactly.
        let cfg = model_cfg();
        let m = OffloadModel::new(&cfg);
        let t = |n: usize, nn: u64| m.estimate(&JobSpec::Axpy { n: nn }, n) as f64;
        // N-dependence at fixed n=1: d t / d N = 1/4 (port) + 2.47/8.
        let slope = (t(1, 4096) - t(1, 2048)) / 2048.0;
        let want = 0.25 + 2.47 / 8.0;
        assert!(
            (slope - want).abs() < 0.01,
            "slope {slope} vs eq5 {want}"
        );
        // n-dependence: the parallel part scales as 1/n.
        let par_16 = t(16, 4096) - t(16, 0_u64.max(4096) / 1); // placeholder
        let _ = par_16;
        let diff_1 = t(1, 4096) - (0.25 * 4096.0); // strip port term
        let diff_32 = t(32, 4096) - (0.25 * 4096.0);
        // parallel fraction shrinks by ~(1 - 1/32) of 2.47*N/8
        let shrink = diff_1 - diff_32;
        let want_shrink = 2.47 * 4096.0 / 8.0 * (1.0 - 1.0 / 32.0);
        assert!(
            (shrink - want_shrink).abs() / want_shrink < 0.05,
            "shrink {shrink} vs {want_shrink}"
        );
    }

    #[test]
    fn axpy_model_constant_near_eq5() {
        // Eq. 5's constant is 400 on the paper's testbed; ours composes
        // to the same order (within ~20%, see EXPERIMENTS.md).
        let cfg = model_cfg();
        let m = OffloadModel::new(&cfg);
        let n = 1024u64;
        let est = m.estimate(&JobSpec::Axpy { n }, 8) as f64;
        let variable = n as f64 / 4.0 + 2.47 * n as f64 / (8.0 * 8.0);
        let konst = est - variable;
        assert!(
            (320.0..480.0).contains(&konst),
            "composed constant {konst} out of range"
        );
    }

    #[test]
    fn atax_model_has_eq6_linear_term() {
        // Eq. 6's n-linear term: N*(1+M)/8 beats per additional cluster.
        let cfg = model_cfg();
        let m = OffloadModel::new(&cfg);
        let (mm, nn) = (64u64, 64u64);
        let spec = JobSpec::Atax { m: mm, n: nn };
        let t16 = m.estimate(&spec, 16) as i64;
        let t32 = m.estimate(&spec, 32) as i64;
        let per_cluster_beats = (nn * (1 + mm) / 8) as i64;
        let grew = t32 - t16;
        let want = 16 * per_cluster_beats; // 16 extra clusters
        assert!(
            (grew - want).abs() as f64 / (want as f64) < 0.05,
            "grew {grew} vs {want}"
        );
    }

    #[test]
    fn montecarlo_has_no_transfer_phases() {
        let cfg = model_cfg();
        let m = OffloadModel::new(&cfg);
        let p = m.phases(&JobSpec::MonteCarlo { samples: 4096 }, 8);
        assert_eq!(p.get(Phase::RetrieveOperands), 0);
        assert!(p.get(Phase::Writeback) > 0); // partial counts return
        assert!(p.get(Phase::Execute) > 0);
    }

    #[test]
    fn offload_constant_excludes_efg() {
        let cfg = model_cfg();
        let m = OffloadModel::new(&cfg);
        let p = m.phases(&JobSpec::Axpy { n: 1024 }, 4);
        let k = p.offload_constant();
        assert_eq!(
            k + p.get(Phase::RetrieveOperands)
                + p.get(Phase::Execute)
                + p.get(Phase::Writeback),
            p.total()
        );
        // The constant is independent of the problem size.
        let p2 = m.phases(&JobSpec::Axpy { n: 4096 }, 4);
        assert_eq!(k, p2.offload_constant());
    }
}
