//! Model validation against the cycle-level simulation (§5.6, Fig. 12).
//!
//! The paper validates its analytical models on a variety of problem
//! sizes and cluster counts, reporting relative error |t − t̂| / t
//! consistently below 15 %. Here `t` is the DES runtime of the multicast
//! routine and `t̂` the Eq.-4 composition from `analytical`.

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::RoutineKind;
use crate::sweep::{OffloadRequest, Sweep, SweepResults};

use super::analytical::OffloadModel;

/// One validation point.
#[derive(Debug, Clone, PartialEq)]
pub struct ValidationPoint {
    pub spec: JobSpec,
    pub n_clusters: usize,
    /// Simulated runtime (cycles).
    pub simulated: u64,
    /// Model estimate (cycles).
    pub estimated: u64,
}

impl ValidationPoint {
    /// Relative error |t − t̂| / t.
    pub fn rel_error(&self) -> f64 {
        (self.simulated as f64 - self.estimated as f64).abs() / self.simulated as f64
    }
}

/// Validate the model on one configuration.
pub fn validate_point(cfg: &Config, spec: &JobSpec, n_clusters: usize) -> ValidationPoint {
    let simulated = crate::sweep::run_one(
        cfg,
        OffloadRequest::new(*spec, n_clusters, RoutineKind::Multicast),
    )
    .total;
    let estimated = OffloadModel::new(cfg).estimate(spec, n_clusters);
    ValidationPoint {
        spec: *spec,
        n_clusters,
        simulated,
        estimated,
    }
}

/// Validate over a grid of (spec, n) points; returns all points in
/// (specs outer, cluster_counts inner) order. The simulations run as one
/// parallel sweep; the (cheap) model estimates are computed inline.
pub fn validate_grid(
    cfg: &Config,
    specs: &[JobSpec],
    cluster_counts: &[usize],
) -> Vec<ValidationPoint> {
    let mut sweep = Sweep::new()
        .clusters(cluster_counts.iter().copied())
        .routines([RoutineKind::Multicast]);
    for spec in specs {
        sweep = sweep.kernel(spec.kind().name(), *spec);
    }
    validate_results(cfg, &sweep.run(cfg))
}

/// Build validation points from pre-computed results (e.g. merged
/// campaign output): every Multicast record is compared against the
/// (cheap, inline) model estimate. `cfg` must be the config the results
/// were simulated with.
pub fn validate_results(cfg: &Config, results: &SweepResults) -> Vec<ValidationPoint> {
    let model = OffloadModel::new(cfg);
    results
        .records()
        .iter()
        .filter(|r| r.req().routine == RoutineKind::Multicast)
        .map(|r| {
            let req = r.req();
            ValidationPoint {
                spec: req.spec,
                n_clusters: req.n_clusters,
                simulated: r.total(),
                estimated: model.estimate(&req.spec, req.n_clusters),
            }
        })
        .collect()
}

/// Maximum relative error over a set of points.
pub fn max_rel_error(points: &[ValidationPoint]) -> f64 {
    points.iter().map(|p| p.rel_error()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_error_below_15_percent() {
        // The paper's headline validation claim (Fig. 12); like the
        // paper, the <15 % envelope holds "for small problem sizes"
        // (§6) — beyond N~4096 the phase-E/G port overlap the model
        // deliberately omits (§5.5.G) grows past it.
        let cfg = Config::default();
        let specs: Vec<JobSpec> = [64u64, 128, 256, 512, 1024, 2048]
            .iter()
            .map(|&n| JobSpec::Axpy { n })
            .collect();
        let pts = validate_grid(&cfg, &specs, &[1, 2, 4, 8, 16, 32]);
        for p in &pts {
            assert!(
                p.rel_error() < 0.15,
                "{:?} n={} sim={} est={} err={:.3}",
                p.spec,
                p.n_clusters,
                p.simulated,
                p.estimated,
                p.rel_error()
            );
        }
    }

    #[test]
    fn atax_error_below_15_percent() {
        let cfg = Config::default();
        let specs: Vec<JobSpec> = [16u64, 32, 64, 128, 256]
            .iter()
            .map(|&m| JobSpec::Atax { m, n: m })
            .collect();
        let pts = validate_grid(&cfg, &specs, &[1, 2, 4, 8, 16, 32]);
        assert!(
            max_rel_error(&pts) < 0.15,
            "max err {:.3}",
            max_rel_error(&pts)
        );
    }

    #[test]
    fn error_grows_gracefully_at_large_sizes() {
        // Document the envelope edge: at N=4096 the model's missing E/G
        // overlap term pushes the error slightly past 15 % on some
        // configurations, but never past 25 %.
        let cfg = Config::default();
        let pts = validate_grid(
            &cfg,
            &[JobSpec::Axpy { n: 4096 }, JobSpec::Axpy { n: 8192 }],
            &[1, 2, 4, 8, 16, 32],
        );
        assert!(max_rel_error(&pts) < 0.25, "max err {:.3}", max_rel_error(&pts));
    }

    #[test]
    fn all_kernels_error_below_15_percent() {
        let cfg = Config::default();
        let specs = [
            JobSpec::MonteCarlo { samples: 4096 },
            JobSpec::Matmul { m: 32, n: 32, k: 32 },
            JobSpec::Covariance { m: 32, n: 64 },
            JobSpec::Bfs { nodes: 64, levels: 4 },
        ];
        let pts = validate_grid(&cfg, &specs, &[1, 4, 16, 32]);
        for p in &pts {
            assert!(
                p.rel_error() < 0.15,
                "{:?} n={} sim={} est={} err={:.3}",
                p.spec,
                p.n_clusters,
                p.simulated,
                p.estimated,
                p.rel_error()
            );
        }
    }
}
