//! Analytical offload-runtime model (§5.6) and its validation against the
//! cycle-level simulation (Fig. 12).

pub mod analytical;
pub mod validate;

pub use analytical::{OffloadModel, PhaseEstimates};
pub use validate::{
    max_rel_error, validate_grid, validate_point, validate_results, ValidationPoint,
};
