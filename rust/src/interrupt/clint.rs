//! Interrupt infrastructure (§2.3, §3.1): the centralized CLINT with its
//! memory-mapped MSIP (Machine Software Interrupt Pending) bits, and the
//! per-cluster MCIP (Machine Cluster Interrupt Pending) registers that
//! provide locally-clearable interrupts and single-store multicast wakeup
//! of all cores in a cluster.

/// Hart identifier: 0 = CVA6, 1.. = Snitch harts.
pub type HartId = usize;

/// The centralized interrupt controller in the peripherals domain.
#[derive(Debug, Clone)]
pub struct Clint {
    msip: Vec<bool>,
    sets: u64,
    clears: u64,
}

impl Clint {
    pub fn new(n_harts: usize) -> Self {
        Self {
            msip: vec![false; n_harts],
            sets: 0,
            clears: 0,
        }
    }

    /// Write the MSIP bit of `hart` (any hart in the system may do this,
    /// §2.3). Returns true if this is a rising edge (interrupt fires).
    pub fn set_msip(&mut self, hart: HartId) -> bool {
        self.sets += 1;
        let rising = !self.msip[hart];
        self.msip[hart] = true;
        rising
    }

    /// The target hart clears its pending bit.
    pub fn clear_msip(&mut self, hart: HartId) {
        self.clears += 1;
        self.msip[hart] = false;
    }

    pub fn pending(&self, hart: HartId) -> bool {
        self.msip[hart]
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.sets, self.clears)
    }
}

/// One cluster's MCIP register: one locally-clearable pending bit per
/// core, packed so a single store wakes the whole cluster (§2.3).
#[derive(Debug, Clone)]
pub struct McipReg {
    bits: u32,
    n_cores: usize,
}

impl McipReg {
    pub fn new(n_cores: usize) -> Self {
        assert!(n_cores <= 32);
        Self { bits: 0, n_cores }
    }

    /// Store a wakeup mask (single store = multicast to all cores in the
    /// cluster). Returns the set of cores whose bit had a rising edge.
    pub fn set(&mut self, mask: u32) -> Vec<usize> {
        let valid = if self.n_cores == 32 {
            u32::MAX
        } else {
            (1u32 << self.n_cores) - 1
        };
        let m = mask & valid;
        let rising = m & !self.bits;
        self.bits |= m;
        (0..self.n_cores).filter(|c| rising >> c & 1 == 1).collect()
    }

    /// Wake every core in the cluster.
    pub fn set_all(&mut self) -> Vec<usize> {
        self.set(u32::MAX)
    }

    /// A core clears its own bit — a local, low-latency access, unlike a
    /// trip to the centralized CLINT (§2.3).
    pub fn clear(&mut self, core: usize) {
        assert!(core < self.n_cores);
        self.bits &= !(1 << core);
    }

    pub fn pending(&self, core: usize) -> bool {
        self.bits >> core & 1 == 1
    }

    pub fn any_pending(&self) -> bool {
        self.bits != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msip_rising_edge_detection() {
        let mut c = Clint::new(2);
        assert!(c.set_msip(0));
        assert!(!c.set_msip(0)); // already pending: no new edge
        c.clear_msip(0);
        assert!(!c.pending(0));
        assert!(c.set_msip(0));
        assert_eq!(c.stats(), (3, 1));
    }

    #[test]
    fn mcip_single_store_wakes_all_cores() {
        let mut m = McipReg::new(9); // 8 compute + 1 DMA core
        let woken = m.set_all();
        assert_eq!(woken, (0..9).collect::<Vec<_>>());
        assert!(m.any_pending());
    }

    #[test]
    fn mcip_local_clear() {
        let mut m = McipReg::new(9);
        m.set_all();
        for c in 0..9 {
            m.clear(c);
        }
        assert!(!m.any_pending());
    }

    #[test]
    fn mcip_partial_mask() {
        let mut m = McipReg::new(9);
        assert_eq!(m.set(0b101), vec![0, 2]);
        // Setting again is not a rising edge.
        assert_eq!(m.set(0b101), Vec::<usize>::new());
        // Out-of-range bits are ignored.
        assert_eq!(m.set(1 << 20), Vec::<usize>::new());
    }
}
