//! Interrupt infrastructure: CLINT + MCIP registers (§2.3) and the job
//! completion unit (§4.3).

pub mod clint;
pub mod jcu;

pub use clint::{Clint, HartId, McipReg};
pub use jcu::{ArrivalOutcome, Jcu, JobId};
