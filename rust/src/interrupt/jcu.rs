//! Job completion unit (§4.3, Fig. 6).
//!
//! Integrated in the CLINT: per job slot, CVA6 programs the `offload`
//! register with the number of clusters selected for offload; each
//! completing cluster writes the `arrivals` register (atomically
//! incremented as a side effect). When `arrivals == offload` the job is
//! complete: the unit fires a software interrupt to CVA6 (deferred if one
//! is already pending), resets the arrivals counter for the next offload,
//! and records the job ID as the interrupt cause for host inspection.
//! Multiple slots support multiple outstanding jobs (e.g. task
//! overlapping, §4.3).


use std::collections::VecDeque;

/// Job identifier used to address a JCU slot.
pub type JobId = u32;

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct Slot {
    offload: u32,
    arrivals: u32,
}

/// Outcome of an arrivals-register write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalOutcome {
    /// More clusters still outstanding.
    Pending { arrivals: u32, expected: u32 },
    /// Job complete; interrupt fired immediately with this cause.
    CompleteFired { cause: JobId },
    /// Job complete, but an interrupt is already pending: delivery is
    /// deferred until the host clears the previous one.
    CompleteDeferred { cause: JobId },
}

/// The job completion unit.
#[derive(Debug, Clone)]
pub struct Jcu {
    slots: Vec<Slot>,
    /// Completed-but-undelivered job causes, in completion order.
    /// A deque: causes pop from the front on every `host_clear`, and a
    /// long chain of deferred completions must not turn each delivery
    /// into an O(n) shift.
    deferred: VecDeque<JobId>,
    /// Whether a software interrupt to the host is currently pending.
    irq_pending: bool,
    fired: u64,
}

impl Jcu {
    pub fn new(n_slots: usize) -> Self {
        assert!(n_slots >= 1);
        Self {
            slots: vec![Slot::default(); n_slots],
            deferred: VecDeque::new(),
            irq_pending: false,
            fired: 0,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// CVA6 programs a slot for an offload of `n_clusters` clusters.
    /// Programming a slot with a job still in flight is a host bug.
    pub fn program(&mut self, job: JobId, n_clusters: u32) {
        assert!(n_clusters >= 1, "offload register must be >= 1");
        let idx = job as usize % self.slots.len();
        let s = &mut self.slots[idx];
        // Guard on the offload register, not the arrivals counter: a slot
        // programmed for a job whose clusters have not arrived yet has
        // `arrivals == 0` but is still in flight, and reprogramming it
        // would silently clobber the outstanding job — exactly the state
        // overlapped dispatch creates between program and first arrival.
        assert_eq!(
            s.offload, 0,
            "JCU slot reprogrammed while a job is in flight"
        );
        s.offload = n_clusters;
    }

    /// A cluster writes the arrivals register of `job`'s slot.
    pub fn arrive(&mut self, job: JobId) -> ArrivalOutcome {
        let idx = job as usize % self.slots.len();
        let s = &mut self.slots[idx];
        assert!(s.offload > 0, "arrival on an unprogrammed JCU slot");
        s.arrivals += 1;
        if s.arrivals < s.offload {
            return ArrivalOutcome::Pending {
                arrivals: s.arrivals,
                expected: s.offload,
            };
        }
        // Complete: auto-reset for the next offload (Fig. 6).
        s.arrivals = 0;
        s.offload = 0;
        if self.irq_pending {
            self.deferred.push_back(job);
            ArrivalOutcome::CompleteDeferred { cause: job }
        } else {
            self.irq_pending = true;
            self.fired += 1;
            ArrivalOutcome::CompleteFired { cause: job }
        }
    }

    /// Host clears the pending interrupt; if a deferred completion is
    /// queued, the next interrupt fires as soon as the previous one is
    /// cleared (§4.3) and its cause is returned.
    pub fn host_clear(&mut self) -> Option<JobId> {
        assert!(self.irq_pending, "host cleared a non-pending interrupt");
        match self.deferred.pop_front() {
            None => {
                self.irq_pending = false;
                None
            }
            Some(cause) => {
                self.fired += 1;
                Some(cause)
            }
        }
    }

    /// Whether a slot currently has a programmed (uncompleted) offload.
    pub fn slot_busy(&self, job: JobId) -> bool {
        self.slots[job as usize % self.slots.len()].offload > 0
    }

    pub fn irq_pending(&self) -> bool {
        self.irq_pending
    }

    pub fn interrupts_fired(&self) -> u64 {
        self.fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_job_completion() {
        let mut j = Jcu::new(1);
        j.program(0, 3);
        assert_eq!(
            j.arrive(0),
            ArrivalOutcome::Pending {
                arrivals: 1,
                expected: 3
            }
        );
        assert_eq!(
            j.arrive(0),
            ArrivalOutcome::Pending {
                arrivals: 2,
                expected: 3
            }
        );
        assert_eq!(j.arrive(0), ArrivalOutcome::CompleteFired { cause: 0 });
        assert!(j.irq_pending());
        assert_eq!(j.host_clear(), None);
        assert!(!j.irq_pending());
    }

    #[test]
    fn auto_reset_allows_next_offload() {
        let mut j = Jcu::new(1);
        j.program(0, 2);
        j.arrive(0);
        j.arrive(0);
        j.host_clear();
        // Same slot immediately reusable (arrivals auto-reset, Fig. 6).
        j.program(1, 1);
        assert_eq!(j.arrive(1), ArrivalOutcome::CompleteFired { cause: 1 });
    }

    #[test]
    fn deferred_interrupt_when_one_pending() {
        let mut j = Jcu::new(2);
        j.program(0, 1);
        j.program(1, 1);
        assert_eq!(j.arrive(0), ArrivalOutcome::CompleteFired { cause: 0 });
        // Second job completes while the first interrupt is pending.
        assert_eq!(j.arrive(1), ArrivalOutcome::CompleteDeferred { cause: 1 });
        // Clearing the first delivers the second.
        assert_eq!(j.host_clear(), Some(1));
        assert_eq!(j.host_clear(), None);
        assert_eq!(j.interrupts_fired(), 2);
    }

    #[test]
    fn multiple_outstanding_jobs_use_distinct_slots() {
        let mut j = Jcu::new(4);
        j.program(2, 2);
        j.program(3, 1);
        assert!(matches!(j.arrive(2), ArrivalOutcome::Pending { .. }));
        assert_eq!(j.arrive(3), ArrivalOutcome::CompleteFired { cause: 3 });
        j.host_clear();
        assert_eq!(j.arrive(2), ArrivalOutcome::CompleteFired { cause: 2 });
    }

    #[test]
    #[should_panic(expected = "unprogrammed")]
    fn arrival_on_unprogrammed_slot_panics() {
        let mut j = Jcu::new(1);
        j.arrive(0);
    }

    #[test]
    #[should_panic(expected = "in flight")]
    fn reprogram_in_flight_panics() {
        let mut j = Jcu::new(1);
        j.program(0, 2);
        j.arrive(0);
        j.program(0, 2);
    }

    #[test]
    #[should_panic(expected = "in flight")]
    fn reprogram_before_first_arrival_panics() {
        // Regression: the guard used to check `arrivals == 0`, so a slot
        // programmed for a job whose clusters had not arrived yet was
        // silently clobbered — the exact state overlapped dispatch
        // creates between program and first arrival.
        let mut j = Jcu::new(1);
        j.program(0, 2);
        j.program(0, 3);
    }

    #[test]
    fn slot_busy_tracks_program_and_completion() {
        let mut j = Jcu::new(2);
        assert!(!j.slot_busy(0));
        j.program(0, 2);
        assert!(j.slot_busy(0));
        assert!(!j.slot_busy(1));
        j.arrive(0);
        assert!(j.slot_busy(0), "busy until the last arrival");
        j.arrive(0);
        assert!(!j.slot_busy(0), "auto-reset frees the slot");
    }

    #[test]
    fn deferred_chain_fires_n_interrupts_in_completion_order() {
        // Regression: `interrupts_fired` was only ever covered at
        // deferral depth 1. A chain of N deferred completions must
        // deliver N interrupts, in completion order.
        const N: u32 = 8;
        let mut j = Jcu::new(N as usize);
        for slot in 0..N {
            j.program(slot, 1);
        }
        // All N complete while the first interrupt stays pending.
        assert_eq!(j.arrive(0), ArrivalOutcome::CompleteFired { cause: 0 });
        for slot in 1..N {
            assert_eq!(j.arrive(slot), ArrivalOutcome::CompleteDeferred { cause: slot });
        }
        // Host clears one at a time: each clear delivers the next cause
        // in completion order.
        let mut delivered = Vec::new();
        while let Some(cause) = j.host_clear() {
            delivered.push(cause);
        }
        assert_eq!(delivered, (1..N).collect::<Vec<_>>());
        assert!(!j.irq_pending());
        assert_eq!(j.interrupts_fired(), u64::from(N));
    }
}
