//! Multicast address encoding (§4.2, Fig. 5).
//!
//! A multicast write carries one representative address plus a bit mask:
//! mask bits set to 1 mark address bits that are *don't care*, so a mask
//! with n bits set encodes 2^n destination addresses. The same
//! representation encodes the XBAR master-port address maps (any
//! power-of-two-sized, size-aligned interval), and matching reduces to the
//! paper's single-line condition:
//!
//! ```text
//! match = &((req.mask | am.mask) | ~(req.addr ^ am.addr));
//! ```


/// An address with a don't-care mask: encodes the set
/// `{ a : a & !mask == addr & !mask }`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MaskedAddr {
    pub addr: u64,
    pub mask: u64,
}

impl MaskedAddr {
    /// A unicast (exact) address.
    pub fn unicast(addr: u64) -> Self {
        Self { addr, mask: 0 }
    }

    /// The address map of an interval `[base, base + size)`.
    /// `size` must be a power of two and `base` size-aligned (the Occamy
    /// conditions, §4.2).
    pub fn interval(base: u64, size: u64) -> Self {
        assert!(size.is_power_of_two(), "interval size must be 2^k: {size:#x}");
        assert_eq!(base % size, 0, "interval base must be size-aligned");
        Self {
            addr: base,
            mask: size - 1,
        }
    }

    /// Number of concrete addresses encoded: 2^popcount(mask).
    pub fn cardinality(&self) -> u128 {
        1u128 << self.mask.count_ones()
    }

    /// The paper's match condition: true iff the two masked-address sets
    /// intersect. For a request vs. an address map this decides whether
    /// the request (partially) targets that master port.
    pub fn matches(&self, other: &MaskedAddr) -> bool {
        // match = &((req.mask | am.mask) | ~(req.addr ^ am.addr))
        ((self.mask | other.mask) | !(self.addr ^ other.addr)) == u64::MAX
    }

    /// True iff concrete address `a` is a member of this set.
    pub fn contains(&self, a: u64) -> bool {
        (a & !self.mask) == (self.addr & !self.mask)
    }

    /// Enumerate all concrete addresses (ascending). Only valid for small
    /// masks; panics above 2^16 members to catch runaway enumerations.
    pub fn expand(&self) -> Vec<u64> {
        let bits: Vec<u32> = (0..64).filter(|b| self.mask >> b & 1 == 1).collect();
        assert!(bits.len() <= 16, "refusing to expand 2^{} addresses", bits.len());
        let base = self.addr & !self.mask;
        let mut out = Vec::with_capacity(1 << bits.len());
        for combo in 0u64..(1 << bits.len()) {
            let mut a = base;
            for (i, b) in bits.iter().enumerate() {
                if combo >> i & 1 == 1 {
                    a |= 1 << b;
                }
            }
            out.push(a);
        }
        out.sort_unstable();
        out
    }

    /// Multicast encoding for a set of *cluster indices* given the Occamy
    /// cluster memory layout (`base + idx * stride`, stride a power of
    /// two): returns `Some` iff the index set is exactly expressible as a
    /// masked address (i.e. it is an affine subcube of the index bits).
    /// `offset` is the common offset within each cluster's address space.
    pub fn for_clusters(
        base: u64,
        stride: u64,
        offset: u64,
        clusters: &[usize],
    ) -> Option<Self> {
        assert!(stride.is_power_of_two());
        assert!(offset < stride);
        if clusters.is_empty() {
            return None;
        }
        let shift = stride.trailing_zeros();
        // The subcube test: OR of indices vs AND of indices gives the
        // candidate don't-care bits; the set is a subcube iff its size is
        // 2^popcount(diff) and every member agrees outside diff.
        let and = clusters.iter().fold(usize::MAX, |a, &c| a & c);
        let or = clusters.iter().fold(0usize, |a, &c| a | c);
        let diff = and ^ or;
        let mut uniq: Vec<usize> = clusters.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        if uniq.len() != 1usize << diff.count_ones() {
            return None;
        }
        for &c in &uniq {
            if c & !diff != and & !diff {
                return None;
            }
        }
        Some(Self {
            addr: base + (uniq[0] as u64) * stride + offset,
            mask: (diff as u64) << shift,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig5_example() {
        // Fig. 5: bits [0,17] in-cluster offset, [18,19] cluster index,
        // [20,22] quadrant index. Addressing cluster 1 of quadrant 2 with
        // bits 19 and 21 masked encodes clusters {1,3} of quadrants {0,2}.
        let stride = 0x40000u64;
        let addr = 2 << 20 | 1 << 18; // quadrant 2, cluster 1, offset 0
        let m = MaskedAddr {
            addr,
            mask: 1 << 19 | 1 << 21,
        };
        assert_eq!(m.cardinality(), 4);
        let got = m.expand();
        // Global cluster index = quadrant * 4 + cluster; expected clusters
        // 1 and 3 in quadrants 0 and 2 -> indices {1, 3, 9, 11}.
        let want: Vec<u64> = [1u64, 3, 9, 11].iter().map(|c| c * stride).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn unicast_matches_only_its_interval() {
        let am0 = MaskedAddr::interval(0x0, 0x40000);
        let am1 = MaskedAddr::interval(0x40000, 0x40000);
        let req = MaskedAddr::unicast(0x40008);
        assert!(!req.matches(&am0));
        assert!(req.matches(&am1));
    }

    #[test]
    fn multicast_matches_multiple_intervals() {
        // Mask bit 18 -> clusters 0 and 1.
        let req = MaskedAddr {
            addr: 0x100,
            mask: 1 << 18,
        };
        let am0 = MaskedAddr::interval(0x0, 0x40000);
        let am1 = MaskedAddr::interval(0x40000, 0x40000);
        let am2 = MaskedAddr::interval(0x80000, 0x40000);
        assert!(req.matches(&am0));
        assert!(req.matches(&am1));
        assert!(!req.matches(&am2));
    }

    #[test]
    fn match_equals_set_intersection_on_samples() {
        // The single-line match rule must agree with concrete membership.
        let a = MaskedAddr {
            addr: 0b1010_0000,
            mask: 0b0100_1111,
        };
        let b = MaskedAddr::interval(0b1110_0000, 0x10);
        let inter_a: Vec<u64> = a.expand().into_iter().filter(|x| b.contains(*x)).collect();
        assert_eq!(a.matches(&b), !inter_a.is_empty());
    }

    #[test]
    fn for_clusters_full_broadcast() {
        let all: Vec<usize> = (0..32).collect();
        let m = MaskedAddr::for_clusters(0, 0x40000, 0x20, &all).unwrap();
        assert_eq!(m.cardinality(), 32);
        assert_eq!(m.mask, 0b11111 << 18);
        let got = m.expand();
        assert_eq!(got.len(), 32);
        assert_eq!(got[0], 0x20);
        assert_eq!(got[31], 31 * 0x40000 + 0x20);
    }

    #[test]
    fn for_clusters_prefix_power_of_two() {
        // First 8 clusters: indices 0..8 form the subcube mask 0b111.
        let m = MaskedAddr::for_clusters(0, 0x40000, 0, &(0..8).collect::<Vec<_>>())
            .unwrap();
        assert_eq!(m.mask, 0b111 << 18);
    }

    #[test]
    fn for_clusters_non_subcube_rejected() {
        // {0, 1, 2} is not a subcube (size 3).
        assert!(MaskedAddr::for_clusters(0, 0x40000, 0, &[0, 1, 2]).is_none());
        // {0, 3} is not a subcube either (disagree in 2 bits, size 2).
        assert!(MaskedAddr::for_clusters(0, 0x40000, 0, &[0, 3]).is_none());
        // but {1, 3} is (bit 1 don't care, bit 0 fixed at 1).
        assert!(MaskedAddr::for_clusters(0, 0x40000, 0, &[1, 3]).is_some());
    }

    #[test]
    #[should_panic(expected = "2^k")]
    fn interval_validates_size() {
        MaskedAddr::interval(0, 3);
    }
}
