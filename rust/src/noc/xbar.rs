//! AXI crossbar model with the multicast extension (§4.2, Fig. 4).
//!
//! Masters connect to slave ports, slaves to master ports. A write request
//! arriving on a slave port is compared against every master port's
//! address map by the address decoder; with the multicast extension a
//! masked request may match — and is simultaneously forwarded to —
//! multiple master ports. The paper reports this extension costs 11 kGE
//! (<10 % of an 8x8 XBAR) at 1 GHz in GF 12LP+; area is outside this
//! reproduction's scope (see DESIGN.md).

use super::addr::MaskedAddr;

/// One master port: an address map plus an opaque endpoint tag.
#[derive(Debug, Clone)]
pub struct MasterPort<T> {
    pub address_map: MaskedAddr,
    pub endpoint: T,
}

/// Crossbar routing table.
#[derive(Debug, Clone)]
pub struct Xbar<T> {
    ports: Vec<MasterPort<T>>,
    /// Whether the multicast extension is present. Without it, masked
    /// requests are rejected (the baseline XBAR has no mask signal).
    multicast: bool,
}

/// Routing outcome.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// Request decodes to exactly these master-port indices.
    To(Vec<usize>),
    /// No port matched (AXI DECERR).
    DecodeError,
    /// Masked request on a baseline (non-multicast) XBAR.
    Unsupported,
}

impl<T> Xbar<T> {
    pub fn new(multicast: bool) -> Self {
        Self {
            ports: Vec::new(),
            multicast,
        }
    }

    /// Register a master port; address maps must be pairwise
    /// non-overlapping (AXI requires unambiguous unicast decode).
    pub fn add_port(&mut self, address_map: MaskedAddr, endpoint: T) -> usize {
        for p in &self.ports {
            assert!(
                !p.address_map.matches(&address_map),
                "overlapping address maps: {:?} vs {:?}",
                p.address_map,
                address_map
            );
        }
        self.ports.push(MasterPort {
            address_map,
            endpoint,
        });
        self.ports.len() - 1
    }

    pub fn n_ports(&self) -> usize {
        self.ports.len()
    }

    pub fn endpoint(&self, port: usize) -> &T {
        &self.ports[port].endpoint
    }

    /// Decode a (possibly multicast) request into the set of matching
    /// master ports — the extended `addr_decode` + demux of Fig. 4.
    pub fn route(&self, req: MaskedAddr) -> Route {
        if req.mask != 0 && !self.multicast {
            return Route::Unsupported;
        }
        let hits: Vec<usize> = self
            .ports
            .iter()
            .enumerate()
            .filter(|(_, p)| req.matches(&p.address_map))
            .map(|(i, _)| i)
            .collect();
        if hits.is_empty() {
            Route::DecodeError
        } else {
            Route::To(hits)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_xbar(multicast: bool) -> Xbar<usize> {
        // A quadrant-level XBAR: 4 cluster ports.
        let mut x = Xbar::new(multicast);
        for c in 0..4usize {
            x.add_port(MaskedAddr::interval(c as u64 * 0x40000, 0x40000), c);
        }
        x
    }

    #[test]
    fn unicast_routes_to_one_port() {
        let x = quad_xbar(false);
        assert_eq!(x.route(MaskedAddr::unicast(0x80000 + 0x20)), Route::To(vec![2]));
    }

    #[test]
    fn unmapped_address_is_decode_error() {
        let x = quad_xbar(true);
        assert_eq!(x.route(MaskedAddr::unicast(0x40000 * 8)), Route::DecodeError);
    }

    #[test]
    fn masked_request_unsupported_on_baseline() {
        let x = quad_xbar(false);
        let req = MaskedAddr {
            addr: 0x20,
            mask: 0b11 << 18,
        };
        assert_eq!(x.route(req), Route::Unsupported);
    }

    #[test]
    fn masked_request_fans_out_on_multicast_xbar() {
        let x = quad_xbar(true);
        // mask bits 18-19: all four clusters.
        let req = MaskedAddr {
            addr: 0x20,
            mask: 0b11 << 18,
        };
        assert_eq!(x.route(req), Route::To(vec![0, 1, 2, 3]));
        // mask bit 19 only: clusters 0 and 2.
        let req2 = MaskedAddr {
            addr: 0x20,
            mask: 0b1 << 19,
        };
        assert_eq!(x.route(req2), Route::To(vec![0, 2]));
    }

    #[test]
    #[should_panic(expected = "overlapping")]
    fn overlapping_maps_rejected() {
        let mut x = quad_xbar(true);
        x.add_port(MaskedAddr::interval(0x0, 0x80000), 9);
    }
}
