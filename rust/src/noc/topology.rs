//! The two-level XBAR tree of Occamy's narrow interconnect (§3.1, Fig. 2)
//! and its end-to-end routing/latency functions.
//!
//! Every four clusters hang off a quadrant-level XBAR; the eight quadrant
//! XBARs, the CVA6 host, the SPMs and the peripherals (CLINT) hang off the
//! top-level XBAR. [`NarrowNoc::route_clusters`] performs the full
//! two-level multicast decode used by the optimized offload routines, and
//! the latency methods provide the hop-composed delays the DES uses.

use crate::config::{Config, SocConfig};

use super::addr::MaskedAddr;
use super::xbar::{Route, Xbar};

/// Endpoints reachable through the narrow NoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Endpoint {
    Cluster(usize),
    Quadrant(usize),
    Host,
    Clint,
    NarrowSpm,
}

/// The assembled two-level narrow interconnect.
#[derive(Debug, Clone)]
pub struct NarrowNoc {
    /// Top-level XBAR: routes to quadrants / peripherals.
    top: Xbar<Endpoint>,
    /// One XBAR per quadrant, routing to its clusters.
    quads: Vec<Xbar<Endpoint>>,
    soc: SocConfig,
    /// Address region of the CLINT (outside the cluster window).
    clint_base: u64,
    /// Address region of the narrow SPM.
    spm_base: u64,
}

impl NarrowNoc {
    /// CLINT and SPM live above the cluster address window.
    pub fn new(cfg: &Config, multicast: bool) -> Self {
        let soc = cfg.soc.clone();
        let cluster_window = soc.cluster_stride * soc.n_clusters() as u64;
        let clint_base = (2 * cluster_window).next_power_of_two();
        // The narrow SPM window must be aligned to its own (power-of-two)
        // size for the masked-interval address-map encoding.
        let spm_size = soc.narrow_spm_bytes.next_power_of_two();
        let spm_base = (clint_base + soc.cluster_stride).next_multiple_of(spm_size);

        let mut top = Xbar::new(multicast);
        let mut quads = Vec::with_capacity(soc.n_quadrants);
        for q in 0..soc.n_quadrants {
            let qsize = soc.cluster_stride * soc.clusters_per_quadrant as u64;
            top.add_port(
                MaskedAddr::interval(soc.cluster_base + q as u64 * qsize, qsize),
                Endpoint::Quadrant(q),
            );
            let mut qx = Xbar::new(multicast);
            for c in 0..soc.clusters_per_quadrant {
                let idx = q * soc.clusters_per_quadrant + c;
                qx.add_port(
                    MaskedAddr::interval(soc.cluster_addr(idx), soc.cluster_stride),
                    Endpoint::Cluster(idx),
                );
            }
            quads.push(qx);
        }
        top.add_port(
            MaskedAddr::interval(clint_base, soc.cluster_stride),
            Endpoint::Clint,
        );
        top.add_port(
            MaskedAddr::interval(spm_base, soc.narrow_spm_bytes.next_power_of_two()),
            Endpoint::NarrowSpm,
        );
        Self {
            top,
            quads,
            soc,
            clint_base,
            spm_base,
        }
    }

    pub fn clint_base(&self) -> u64 {
        self.clint_base
    }

    pub fn spm_base(&self) -> u64 {
        self.spm_base
    }

    /// Route a (possibly multicast) request through both XBAR levels to
    /// the final set of cluster indices. Non-cluster endpoints are
    /// returned separately.
    pub fn route(&self, req: MaskedAddr) -> Result<(Vec<usize>, Vec<Endpoint>), String> {
        let mut clusters = Vec::new();
        let mut others = Vec::new();
        match self.top.route(req) {
            Route::DecodeError => return Err(format!("DECERR at top level: {req:?}")),
            Route::Unsupported => {
                return Err("masked request on baseline XBAR".to_string())
            }
            Route::To(ports) => {
                for p in ports {
                    match *self.top.endpoint(p) {
                        Endpoint::Quadrant(q) => match self.quads[q].route(req) {
                            Route::To(cports) => {
                                for cp in cports {
                                    if let Endpoint::Cluster(c) = *self.quads[q].endpoint(cp)
                                    {
                                        clusters.push(c);
                                    }
                                }
                            }
                            Route::DecodeError => {
                                return Err(format!("DECERR in quadrant {q}"))
                            }
                            Route::Unsupported => {
                                return Err("masked request on baseline quadrant XBAR"
                                    .to_string())
                            }
                        },
                        e => others.push(e),
                    }
                }
            }
        }
        clusters.sort_unstable();
        Ok((clusters, others))
    }

    /// Convenience: the set of clusters a multicast write to
    /// `offset`-within-every-cluster reaches, for a masked cluster set.
    pub fn route_clusters(&self, req: MaskedAddr) -> Result<Vec<usize>, String> {
        let (clusters, others) = self.route(req)?;
        if !others.is_empty() {
            return Err(format!("request leaked outside clusters: {others:?}"));
        }
        Ok(clusters)
    }

    /// Encode a multicast write to the first `n` clusters at in-cluster
    /// `offset`. Returns per-subcube masked addresses: a non-power-of-two
    /// `n` needs popcount(n) transactions (each subcube one masked write).
    pub fn encode_first_n(&self, n: usize, offset: u64) -> Vec<MaskedAddr> {
        assert!(n >= 1 && n <= self.soc.n_clusters());
        let mut out = Vec::new();
        let mut start = 0usize;
        let mut rem = n;
        // Greedy decomposition of [0, n) into aligned power-of-two blocks.
        while rem > 0 {
            let block = 1usize << (usize::BITS - 1 - rem.leading_zeros());
            let idxs: Vec<usize> = (start..start + block).collect();
            out.push(
                MaskedAddr::for_clusters(
                    self.soc.cluster_base,
                    self.soc.cluster_stride,
                    offset,
                    &idxs,
                )
                .expect("aligned power-of-two range is a subcube"),
            );
            start += block;
            rem -= block;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noc(multicast: bool) -> NarrowNoc {
        NarrowNoc::new(&Config::default(), multicast)
    }

    #[test]
    fn unicast_reaches_exactly_one_cluster() {
        let n = noc(false);
        for c in [0usize, 1, 7, 31] {
            let req = MaskedAddr::unicast(c as u64 * 0x40000 + 0x10);
            assert_eq!(n.route_clusters(req).unwrap(), vec![c]);
        }
    }

    #[test]
    fn broadcast_all_32_clusters() {
        let n = noc(true);
        let req = MaskedAddr {
            addr: 0x20,
            mask: 0b11111 << 18,
        };
        assert_eq!(n.route_clusters(req).unwrap(), (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn paper_fig5_routes_to_clusters_1_3_9_11() {
        let n = noc(true);
        let req = MaskedAddr {
            addr: (2 << 20) | (1 << 18),
            mask: (1 << 19) | (1 << 21),
        };
        assert_eq!(n.route_clusters(req).unwrap(), vec![1, 3, 9, 11]);
    }

    #[test]
    fn masked_rejected_without_extension() {
        let n = noc(false);
        let req = MaskedAddr {
            addr: 0x0,
            mask: 1 << 18,
        };
        assert!(n.route_clusters(req).is_err());
    }

    #[test]
    fn clint_is_reachable_and_disjoint_from_clusters() {
        let n = noc(true);
        let (clusters, others) = n.route(MaskedAddr::unicast(n.clint_base())).unwrap();
        assert!(clusters.is_empty());
        assert_eq!(others, vec![Endpoint::Clint]);
        let (c2, o2) = n.route(MaskedAddr::unicast(n.spm_base())).unwrap();
        assert!(c2.is_empty());
        assert_eq!(o2, vec![Endpoint::NarrowSpm]);
    }

    #[test]
    fn encode_first_n_power_of_two_is_single_transaction() {
        let n = noc(true);
        for k in [1usize, 2, 4, 8, 16, 32] {
            let msgs = n.encode_first_n(k, 0x8);
            assert_eq!(msgs.len(), 1, "k={k}");
            let mut all = Vec::new();
            for m in &msgs {
                all.extend(n.route_clusters(*m).unwrap());
            }
            all.sort_unstable();
            assert_eq!(all, (0..k).collect::<Vec<_>>());
        }
    }

    #[test]
    fn encode_first_n_general() {
        let n = noc(true);
        for k in 1..=32usize {
            let msgs = n.encode_first_n(k, 0x8);
            assert_eq!(msgs.len() as u32, k.count_ones(), "k={k}");
            let mut all = Vec::new();
            for m in &msgs {
                all.extend(n.route_clusters(*m).unwrap());
            }
            all.sort_unstable();
            assert_eq!(all, (0..k).collect::<Vec<_>>(), "k={k}");
        }
    }
}
