//! Network-on-Chip models: the multicast address encoding (§4.2, Fig. 5),
//! the extended AXI XBAR (Fig. 4) and the assembled two-level tree of the
//! Occamy narrow interconnect (Fig. 2).

pub mod addr;
pub mod topology;
pub mod xbar;

pub use addr::MaskedAddr;
pub use topology::{Endpoint, NarrowNoc};
pub use xbar::{Route, Xbar};
