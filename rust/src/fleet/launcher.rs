//! The launcher seam: how the fleet scheduler turns "shard i/N should
//! be running" into an actual worker.
//!
//! The scheduler only ever talks to [`Launcher`] and [`WorkerHandle`] —
//! spawn, poll, kill. [`LocalLauncher`] implements it with
//! `occamy campaign run --shard i/N` subprocesses on this host;
//! [`SshLauncher`] fans the same workers out over
//! `ssh <host> <remote-occamy> campaign run ...` against a shared
//! mount. Nothing in the scheduler changes between them, because all
//! *state* (results, heartbeat leases, the trace store) already lives
//! on the shared filesystem — the launcher only decides *where* the
//! process runs and how to kill it.

use std::io::BufRead;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

use crate::campaign::{HostSpec, Shard};

/// Everything a launcher needs to start one worker attempt.
#[derive(Debug, Clone)]
pub struct WorkerTask {
    /// The campaign TOML the worker re-reads (specs are files, not
    /// serialized state — any host with the shared checkout can run it).
    pub spec_path: PathBuf,
    pub shard: Shard,
    pub out_dir: PathBuf,
    /// Persistent trace store root; `None` disables the store.
    pub store: Option<PathBuf>,
    /// The lease file this worker must heartbeat.
    pub lease_path: PathBuf,
    pub lease_ttl_secs: u64,
    pub run_id: String,
    /// 0 for the initial launch, +1 per relaunch.
    pub attempt: usize,
    /// Cap on points executed this attempt (`--max-points`); the
    /// scheduler's chaos injection sets it to rehearse crash recovery.
    pub max_points: Option<usize>,
    /// Trace context the worker inherits (`--trace-parent`), so every
    /// shard's spans — on any host — stitch under the fleet-run root.
    pub trace_parent: Option<String>,
}

/// Observed state of a launched worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    Running,
    Exited { success: bool },
}

/// A launched worker the scheduler can poll and kill. `kill` must be
/// idempotent and safe on an already-exited worker.
pub trait WorkerHandle: Send {
    fn poll(&mut self) -> anyhow::Result<WorkerState>;
    fn kill(&mut self);
    /// Human-readable identity for log lines (e.g. `pid 1234`).
    fn describe(&self) -> String;
}

/// Spawns workers for shard tasks. Implementations decide *where* a
/// worker runs; the scheduler decides *what* runs and *when*.
pub trait Launcher {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>>;
}

/// Runs workers as local `occamy campaign run` subprocesses.
pub struct LocalLauncher {
    /// The `occamy` binary to spawn (usually the running one).
    pub exe: PathBuf,
    /// Silence worker stdout (the scheduler summarizes instead); worker
    /// stderr is always inherited so failures stay visible.
    pub quiet: bool,
}

impl LocalLauncher {
    /// Launch workers with the currently-running binary.
    pub fn current_exe() -> anyhow::Result<Self> {
        Ok(Self {
            exe: std::env::current_exe()
                .map_err(|e| anyhow::anyhow!("cannot resolve the current executable: {e}"))?,
            quiet: true,
        })
    }

    /// The `campaign run` argument vector for a task (separated out so
    /// tests can assert on it without spawning anything).
    pub fn args_of(task: &WorkerTask) -> Vec<std::ffi::OsString> {
        let mut args: Vec<std::ffi::OsString> = vec![
            "campaign".into(),
            "run".into(),
            "--spec".into(),
            task.spec_path.clone().into(),
            "--shard".into(),
            task.shard.to_string().into(),
            "--out".into(),
            task.out_dir.clone().into(),
        ];
        match &task.store {
            Some(root) => {
                args.push("--store".into());
                args.push(root.clone().into());
            }
            None => args.push("--no-store".into()),
        }
        args.push("--lease".into());
        args.push(task.lease_path.clone().into());
        args.push("--lease-ttl".into());
        args.push(task.lease_ttl_secs.to_string().into());
        args.push("--run-id".into());
        args.push(task.run_id.clone().into());
        args.push("--attempt".into());
        args.push(task.attempt.to_string().into());
        if let Some(cap) = task.max_points {
            args.push("--max-points".into());
            args.push(cap.to_string().into());
        }
        if let Some(tp) = &task.trace_parent {
            args.push("--trace-parent".into());
            args.push(tp.clone().into());
        }
        args
    }
}

impl Launcher for LocalLauncher {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
        let mut cmd = Command::new(&self.exe);
        cmd.args(Self::args_of(task));
        cmd.stdin(Stdio::null());
        if self.quiet {
            cmd.stdout(Stdio::null());
        }
        let child = cmd.spawn().map_err(|e| {
            anyhow::anyhow!(
                "spawn {} for shard {} (attempt {}): {e}",
                self.exe.display(),
                task.shard,
                task.attempt
            )
        })?;
        Ok(Box::new(LocalWorker { child }))
    }
}

struct LocalWorker {
    child: Child,
}

impl WorkerHandle for LocalWorker {
    fn poll(&mut self) -> anyhow::Result<WorkerState> {
        match self.child.try_wait() {
            Ok(None) => Ok(WorkerState::Running),
            Ok(Some(status)) => Ok(WorkerState::Exited {
                success: status.success(),
            }),
            Err(e) => Err(anyhow::anyhow!("poll pid {}: {e}", self.child.id())),
        }
    }

    fn kill(&mut self) {
        // Both calls fail harmlessly on an already-reaped child.
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    fn describe(&self) -> String {
        format!("pid {}", self.child.id())
    }
}

/// The line a remote worker's wrapping shell prints before `exec`ing the
/// worker, carrying the pid the scheduler later kills: because `exec`
/// replaces the shell, `$$` *is* the worker's pid on the remote host.
const PID_BANNER: &str = "__occamy_remote_pid";

/// Options on every ssh invocation: never prompt for credentials, and
/// bound the connect wait — the kill path runs synchronously inside the
/// scheduler loop, so an unreachable host must cost seconds, not the
/// TCP timeout. Shim scripts skip leading `-o <value>` pairs.
const SSH_OPTIONS: &[&str] = &["-o", "BatchMode=yes", "-o", "ConnectTimeout=5"];

/// Runs workers over SSH against a shared mount: shard `i` of attempt
/// `a` lands on `hosts[(i + a) % len]` — deterministic round-robin for
/// the initial placement, and a relaunched shard rotates to the *next*
/// host, so a single bad machine cannot eat a shard's whole restart
/// budget.
///
/// The remote command is
/// `echo __occamy_remote_pid $$; exec <bin> campaign run ...`: the pid
/// is captured from the remote shell's banner line on stdout, and
/// [`WorkerHandle::kill`] becomes `ssh <host> kill <pid>` (killing the
/// local `ssh` client alone would leave the remote worker running).
/// Everything else — results, leases, resume — already flows through
/// the shared filesystem, so the scheduler is untouched.
///
/// Hermetic testing needs no remote host: point [`SshLauncher::ssh`] at
/// a shim script that drops the host argument and runs the command
/// locally (`tests/integration_ssh.rs`, the `fleet-ssh` CI job).
pub struct SshLauncher {
    /// Hosts to round-robin shards over; must be non-empty.
    pub hosts: Vec<HostSpec>,
    /// Remote binary for hosts without their own `bin=` attribute.
    pub remote_bin: String,
    /// Local prefix that per-host `root=` attributes replace in every
    /// task path (for mounts that sit at different points per host).
    pub local_root: Option<PathBuf>,
    /// The ssh client to spawn — `ssh` from `PATH` in production, a
    /// shim script under test.
    pub ssh: PathBuf,
    /// Silence forwarded worker stdout (stderr is always inherited).
    pub quiet: bool,
}

impl SshLauncher {
    /// Check the host list is usable: non-empty, and per-host `root=`
    /// mappings have a `local_root` to map from.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.hosts.is_empty(),
            "an SSH fleet needs at least one host ([fleet] hosts or --hosts)"
        );
        for h in &self.hosts {
            anyhow::ensure!(
                h.remote_root.is_none() || self.local_root.is_some(),
                "host {:?} maps root={} but no local_root names the local prefix to replace",
                h.name,
                h.remote_root.as_ref().unwrap().display()
            );
        }
        Ok(())
    }

    /// The host this shard attempt lands on.
    pub fn host_for(&self, shard: Shard, attempt: usize) -> &HostSpec {
        &self.hosts[(shard.index + attempt) % self.hosts.len()]
    }

    /// Rewrite one task path for `host`: a path under `local_root` gets
    /// the host's `remote_root` prefix instead; everything else (and
    /// every path on hosts without a mapping) passes through untouched.
    fn map_path(&self, host: &HostSpec, path: &std::path::Path) -> PathBuf {
        if let (Some(local), Some(remote)) = (&self.local_root, &host.remote_root) {
            if let Ok(rest) = path.strip_prefix(local) {
                return remote.join(rest);
            }
        }
        path.to_path_buf()
    }

    /// The `(host, remote command)` pair for a task: the banner+exec
    /// payload wrapped as `sh -c '...'`, because sshd hands the command
    /// string to the user's *login* shell, which need not be POSIX
    /// (fish, for one, rejects `$$`) — under `sh` the payload behaves
    /// identically everywhere.
    pub fn remote_command(&self, task: &WorkerTask) -> anyhow::Result<(String, String)> {
        let (host, payload) = self.payload(task)?;
        Ok((host, format!("sh -c {}", shell_quote(&payload))))
    }

    /// The unwrapped worker invocation
    /// (`echo <banner> $$; exec <bin> campaign run ...`) — separated out
    /// so tests can assert on placement, path mapping and quoting
    /// without spawning anything.
    ///
    /// Task paths are absolutized against the scheduler's cwd first: a
    /// relative `--out` would otherwise resolve against the remote
    /// login directory and the scheduler would watch files no worker
    /// ever writes. `remote_bin` is deliberately left alone — a bare
    /// name resolves on the remote `PATH`.
    fn payload(&self, task: &WorkerTask) -> anyhow::Result<(String, String)> {
        let host = self.host_for(task.shard, task.attempt);
        let bin = host.remote_bin.as_deref().unwrap_or(&self.remote_bin);
        let mut mapped = task.clone();
        mapped.spec_path = self.map_path(host, &absolutize(&task.spec_path));
        mapped.out_dir = self.map_path(host, &absolutize(&task.out_dir));
        mapped.store = task.store.as_deref().map(|s| self.map_path(host, &absolutize(s)));
        mapped.lease_path = self.map_path(host, &absolutize(&task.lease_path));
        let mut command = format!("echo {PID_BANNER} $$; exec {}", shell_quote(bin));
        for arg in LocalLauncher::args_of(&mapped) {
            let arg = arg.to_str().ok_or_else(|| {
                anyhow::anyhow!(
                    "task path {:?} is not UTF-8; the ssh transport cannot carry it",
                    arg
                )
            })?;
            command.push(' ');
            command.push_str(&shell_quote(arg));
        }
        Ok((host.name.clone(), command))
    }
}

impl Launcher for SshLauncher {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
        let (host, command) = self.remote_command(task)?;
        let mut cmd = Command::new(&self.ssh);
        cmd.args(SSH_OPTIONS);
        cmd.arg(&host);
        cmd.arg(&command);
        cmd.stdin(Stdio::null());
        // stdout is always piped: the pid banner arrives there.
        cmd.stdout(Stdio::piped());
        let mut child = cmd.spawn().map_err(|e| {
            anyhow::anyhow!(
                "spawn {} {host} for shard {} (attempt {}): {e}",
                self.ssh.display(),
                task.shard,
                task.attempt
            )
        })?;
        let pid = Arc::new(Mutex::new(None));
        let reader = child.stdout.take().map(|out| {
            let pid = Arc::clone(&pid);
            let quiet = self.quiet;
            let host = host.clone();
            // Drain stdout off-thread so a chatty worker can never fill
            // the pipe and wedge itself; the first banner line is the
            // remote pid, the rest is forwarded (unless quiet).
            std::thread::spawn(move || {
                for line in std::io::BufReader::new(out).lines() {
                    let Ok(line) = line else { break };
                    if let Some(rest) = line.trim().strip_prefix(PID_BANNER) {
                        if let Ok(p) = rest.trim().parse::<u32>() {
                            *pid.lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
                                Some(p);
                            continue;
                        }
                    }
                    if !quiet {
                        println!("[{host}] {line}");
                    }
                }
            })
        });
        Ok(Box::new(SshWorker {
            child,
            host,
            ssh: self.ssh.clone(),
            pid,
            reader,
            remote_done: false,
        }))
    }
}

struct SshWorker {
    /// The local ssh client; its exit status is the remote command's.
    child: Child,
    host: String,
    ssh: PathBuf,
    /// Remote worker pid, once the banner line has arrived.
    pid: Arc<Mutex<Option<u32>>>,
    reader: Option<std::thread::JoinHandle<()>>,
    /// The *remote command itself* was observed to finish (ssh relayed
    /// a real exit code) — kill() then only reaps the local client
    /// instead of paying an ssh round-trip. A transport death (ssh exit
    /// 255, or the client killed by a signal) does NOT set this: the
    /// remote worker may still be running and must be killed remotely
    /// before its shard is handed to a replacement.
    remote_done: bool,
}

impl SshWorker {
    fn remote_pid(&self) -> Option<u32> {
        *self.pid.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// ssh's own exit code for "the connection failed", as opposed to a
/// relayed remote exit code.
const SSH_TRANSPORT_FAILURE: i32 = 255;

impl WorkerHandle for SshWorker {
    fn poll(&mut self) -> anyhow::Result<WorkerState> {
        // ssh exits with the remote command's status (255 for transport
        // failure, which correctly reads as a failed attempt).
        match self.child.try_wait() {
            Ok(None) => Ok(WorkerState::Running),
            Ok(Some(status)) => {
                self.remote_done = status.code().is_some_and(|c| c != SSH_TRANSPORT_FAILURE);
                Ok(WorkerState::Exited {
                    success: status.success(),
                })
            }
            Err(e) => Err(anyhow::anyhow!("poll ssh {}: {e}", self.host)),
        }
    }

    fn kill(&mut self) {
        // Remote first: killing the local ssh client alone leaves the
        // remote worker running (there is no tty to carry a hangup), and
        // after a transport failure the client is gone but the worker
        // may not be — an orphan writing next to its replacement. A
        // worker whose banner never arrived cannot be killed remotely;
        // it then just goes stale and is superseded, which resume makes
        // safe.
        if !self.remote_done {
            if let Some(pid) = self.remote_pid() {
                let _ = Command::new(&self.ssh)
                    .args(SSH_OPTIONS)
                    .arg(&self.host)
                    .arg(format!("kill {pid}"))
                    .stdin(Stdio::null())
                    .stdout(Stdio::null())
                    .stderr(Stdio::null())
                    .status();
            }
        }
        // Both calls fail harmlessly on an already-reaped child; wait()
        // closes the stdout pipe, which ends the reader thread.
        let _ = self.child.kill();
        let _ = self.child.wait();
        self.remote_done = true;
        if let Some(t) = self.reader.take() {
            let _ = t.join();
        }
    }

    fn describe(&self) -> String {
        match self.remote_pid() {
            Some(pid) => format!("ssh {}, remote pid {pid}", self.host),
            None => format!("ssh {}, remote pid pending", self.host),
        }
    }
}

/// Resolve a relative path against this process's cwd (shared-mount
/// paths must mean the same thing on every host; a failure to read the
/// cwd degrades to passing the path through unchanged).
fn absolutize(path: &std::path::Path) -> PathBuf {
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        std::env::current_dir().map(|d| d.join(path)).unwrap_or_else(|_| path.to_path_buf())
    }
}

/// Quote one argument for the remote POSIX shell: plain tokens pass
/// through, anything else is single-quoted with embedded quotes escaped.
fn shell_quote(s: &str) -> String {
    let plain = !s.is_empty()
        && s.bytes().all(|b| {
            b.is_ascii_alphanumeric()
                || matches!(b, b'_' | b'-' | b'.' | b'/' | b':' | b'=' | b'@' | b'%' | b'+')
        });
    if plain {
        s.to_string()
    } else {
        format!("'{}'", s.replace('\'', "'\\''"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_cover_every_task_field() {
        let task = WorkerTask {
            spec_path: PathBuf::from("spec.toml"),
            shard: Shard::new(1, 3).unwrap(),
            out_dir: PathBuf::from("out"),
            store: Some(PathBuf::from("store-root")),
            lease_path: PathBuf::from("lease/shard-1-of-3.lease"),
            lease_ttl_secs: 12,
            run_id: "demo".into(),
            attempt: 2,
            max_points: Some(1),
            trace_parent: Some("0011223344556677-8899aabbccddeeff".into()),
        };
        let args: Vec<String> = LocalLauncher::args_of(&task)
            .into_iter()
            .map(|a| a.to_string_lossy().into_owned())
            .collect();
        let joined = args.join(" ");
        assert_eq!(&args[..2], ["campaign", "run"]);
        assert!(joined.contains("--spec spec.toml"), "{joined}");
        assert!(joined.contains("--shard 1/3"), "{joined}");
        assert!(joined.contains("--store store-root"), "{joined}");
        assert!(joined.contains("--lease-ttl 12"), "{joined}");
        assert!(joined.contains("--run-id demo"), "{joined}");
        assert!(joined.contains("--attempt 2"), "{joined}");
        assert!(joined.contains("--max-points 1"), "{joined}");
        assert!(joined.contains("--trace-parent 0011223344556677-8899aabbccddeeff"), "{joined}");
        assert!(!joined.contains("--no-store"), "{joined}");

        let mut bare = task.clone();
        bare.store = None;
        bare.max_points = None;
        bare.trace_parent = None;
        let joined = LocalLauncher::args_of(&bare)
            .into_iter()
            .map(|a| a.to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join(" ");
        assert!(joined.contains("--no-store"), "{joined}");
        assert!(!joined.contains("--max-points"), "{joined}");
        assert!(!joined.contains("--trace-parent"), "{joined}");
        assert!(!joined.contains("--store "), "{joined}");
    }

    fn ssh_task() -> WorkerTask {
        WorkerTask {
            spec_path: PathBuf::from("/mnt/shared/specs/demo.toml"),
            shard: Shard::new(0, 2).unwrap(),
            out_dir: PathBuf::from("/mnt/shared/out"),
            store: Some(PathBuf::from("/mnt/shared/out/store")),
            lease_path: PathBuf::from("/mnt/shared/out/store/fleet/demo/shard-0-of-2.lease"),
            lease_ttl_secs: 30,
            run_id: "demo".into(),
            attempt: 0,
            max_points: None,
            trace_parent: None,
        }
    }

    fn ssh_launcher(hosts: &[&str]) -> SshLauncher {
        SshLauncher {
            hosts: hosts.iter().map(|h| HostSpec::parse(h).unwrap()).collect(),
            remote_bin: "occamy".into(),
            local_root: None,
            ssh: PathBuf::from("ssh"),
            quiet: true,
        }
    }

    #[test]
    fn shards_round_robin_and_restarts_rotate_hosts() {
        let l = ssh_launcher(&["alpha", "beta", "gamma"]);
        let shard = |i| Shard::new(i, 5).unwrap();
        assert_eq!(l.host_for(shard(0), 0).name, "alpha");
        assert_eq!(l.host_for(shard(1), 0).name, "beta");
        assert_eq!(l.host_for(shard(2), 0).name, "gamma");
        assert_eq!(l.host_for(shard(3), 0).name, "alpha");
        // A relaunch moves to the next host, so one bad machine cannot
        // eat a shard's whole restart budget.
        assert_eq!(l.host_for(shard(0), 1).name, "beta");
        assert_eq!(l.host_for(shard(0), 2).name, "gamma");
    }

    #[test]
    fn payload_carries_banner_exec_and_worker_args() {
        let l = ssh_launcher(&["alpha", "beta bin=/opt/occamy"]);
        let (host, cmd) = l.payload(&ssh_task()).unwrap();
        assert_eq!(host, "alpha");
        assert!(cmd.starts_with("echo __occamy_remote_pid $$; exec occamy campaign run "), "{cmd}");
        assert!(cmd.contains("--shard 0/2"), "{cmd}");
        assert!(cmd.contains("--spec /mnt/shared/specs/demo.toml"), "{cmd}");
        assert!(cmd.contains("--store /mnt/shared/out/store"), "{cmd}");
        // Shard 1 lands on beta and uses its per-host binary.
        let mut t = ssh_task();
        t.shard = Shard::new(1, 2).unwrap();
        let (host, cmd) = l.payload(&t).unwrap();
        assert_eq!(host, "beta");
        assert!(cmd.contains("exec /opt/occamy campaign run"), "{cmd}");
    }

    #[test]
    fn remote_command_wraps_the_payload_for_any_login_shell() {
        // sshd hands the command to the user's login shell, which need
        // not be POSIX — the wire format always runs the payload under
        // `sh -c`.
        let l = ssh_launcher(&["alpha"]);
        let (_, payload) = l.payload(&ssh_task()).unwrap();
        let (host, cmd) = l.remote_command(&ssh_task()).unwrap();
        assert_eq!(host, "alpha");
        assert_eq!(cmd, format!("sh -c {}", shell_quote(&payload)));
        assert!(cmd.starts_with("sh -c 'echo __occamy_remote_pid $$; exec "), "{cmd}");
    }

    #[test]
    fn payload_maps_shared_mount_prefixes_per_host() {
        let mut l = ssh_launcher(&["alpha root=/data/shared", "beta"]);
        l.local_root = Some(PathBuf::from("/mnt/shared"));
        l.validate().unwrap();
        let (_, cmd) = l.payload(&ssh_task()).unwrap();
        // Every path under local_root is rewritten for alpha...
        assert!(cmd.contains("--spec /data/shared/specs/demo.toml"), "{cmd}");
        assert!(cmd.contains("--out /data/shared/out"), "{cmd}");
        assert!(cmd.contains("--store /data/shared/out/store"), "{cmd}");
        assert!(cmd.contains("--lease /data/shared/out/store/fleet/demo/shard-0-of-2.lease"), "{cmd}");
        assert!(!cmd.contains("/mnt/shared"), "{cmd}");
        // ...and passes through untouched for beta (no root= mapping).
        let mut t = ssh_task();
        t.shard = Shard::new(1, 2).unwrap();
        let (_, cmd) = l.payload(&t).unwrap();
        assert!(cmd.contains("--spec /mnt/shared/specs/demo.toml"), "{cmd}");
    }

    #[test]
    fn payload_absolutizes_relative_task_paths() {
        let l = ssh_launcher(&["alpha"]);
        let mut t = ssh_task();
        t.out_dir = PathBuf::from("rel-out");
        let (_, cmd) = l.payload(&t).unwrap();
        let abs = std::env::current_dir().unwrap().join("rel-out");
        assert!(
            cmd.contains(&format!("--out {}", shell_quote(&abs.to_string_lossy()))),
            "{cmd}"
        );
        // A bare remote binary name stays bare: it resolves on the
        // remote PATH, not against the scheduler's cwd.
        assert!(cmd.contains("exec occamy "), "{cmd}");
    }

    #[test]
    fn payload_quotes_hostile_paths() {
        let l = ssh_launcher(&["alpha"]);
        let mut t = ssh_task();
        t.out_dir = PathBuf::from("/mnt/shared/out dir with spaces");
        t.run_id = "it's a run; rm -rf /".into();
        let (_, cmd) = l.payload(&t).unwrap();
        assert!(cmd.contains("'/mnt/shared/out dir with spaces'"), "{cmd}");
        assert!(cmd.contains("'it'\\''s a run; rm -rf /'"), "{cmd}");
    }

    #[test]
    fn shell_quote_passes_plain_tokens_and_wraps_the_rest() {
        assert_eq!(shell_quote("campaign"), "campaign");
        assert_eq!(shell_quote("/a/b-c_d.e:f=g@h%i+j"), "/a/b-c_d.e:f=g@h%i+j");
        assert_eq!(shell_quote(""), "''");
        assert_eq!(shell_quote("a b"), "'a b'");
        assert_eq!(shell_quote("a,b"), "'a,b'");
        assert_eq!(shell_quote("$HOME"), "'$HOME'");
        assert_eq!(shell_quote("a'b"), "'a'\\''b'");
        assert_eq!(shell_quote("`ls`"), "'`ls`'");
    }

    #[test]
    fn launcher_validation_rejects_broken_configs() {
        let empty = SshLauncher {
            hosts: Vec::new(),
            remote_bin: "occamy".into(),
            local_root: None,
            ssh: PathBuf::from("ssh"),
            quiet: true,
        };
        assert!(empty.validate().unwrap_err().to_string().contains("at least one host"));
        let unmapped = ssh_launcher(&["alpha root=/data/shared"]);
        let err = unmapped.validate().unwrap_err().to_string();
        assert!(err.contains("local_root"), "{err}");
    }
}
