//! The launcher seam: how the fleet scheduler turns "shard i/N should
//! be running" into an actual worker.
//!
//! The scheduler only ever talks to [`Launcher`] and [`WorkerHandle`] —
//! spawn, poll, kill. [`LocalLauncher`] implements it with
//! `occamy campaign run --shard i/N` subprocesses on this host; an SSH
//! or Kubernetes launcher would implement the same two traits and
//! nothing else changes, because all *state* (results, heartbeat
//! leases, the trace store) already lives on the shared filesystem.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};

use crate::campaign::Shard;

/// Everything a launcher needs to start one worker attempt.
#[derive(Debug, Clone)]
pub struct WorkerTask {
    /// The campaign TOML the worker re-reads (specs are files, not
    /// serialized state — any host with the shared checkout can run it).
    pub spec_path: PathBuf,
    pub shard: Shard,
    pub out_dir: PathBuf,
    /// Persistent trace store root; `None` disables the store.
    pub store: Option<PathBuf>,
    /// The lease file this worker must heartbeat.
    pub lease_path: PathBuf,
    pub lease_ttl_secs: u64,
    pub run_id: String,
    /// 0 for the initial launch, +1 per relaunch.
    pub attempt: usize,
    /// Cap on points executed this attempt (`--max-points`); the
    /// scheduler's chaos injection sets it to rehearse crash recovery.
    pub max_points: Option<usize>,
}

/// Observed state of a launched worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    Running,
    Exited { success: bool },
}

/// A launched worker the scheduler can poll and kill. `kill` must be
/// idempotent and safe on an already-exited worker.
pub trait WorkerHandle: Send {
    fn poll(&mut self) -> anyhow::Result<WorkerState>;
    fn kill(&mut self);
    /// Human-readable identity for log lines (e.g. `pid 1234`).
    fn describe(&self) -> String;
}

/// Spawns workers for shard tasks. Implementations decide *where* a
/// worker runs; the scheduler decides *what* runs and *when*.
pub trait Launcher {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>>;
}

/// Runs workers as local `occamy campaign run` subprocesses.
pub struct LocalLauncher {
    /// The `occamy` binary to spawn (usually the running one).
    pub exe: PathBuf,
    /// Silence worker stdout (the scheduler summarizes instead); worker
    /// stderr is always inherited so failures stay visible.
    pub quiet: bool,
}

impl LocalLauncher {
    /// Launch workers with the currently-running binary.
    pub fn current_exe() -> anyhow::Result<Self> {
        Ok(Self {
            exe: std::env::current_exe()
                .map_err(|e| anyhow::anyhow!("cannot resolve the current executable: {e}"))?,
            quiet: true,
        })
    }

    /// The `campaign run` argument vector for a task (separated out so
    /// tests can assert on it without spawning anything).
    pub fn args_of(task: &WorkerTask) -> Vec<std::ffi::OsString> {
        let mut args: Vec<std::ffi::OsString> = vec![
            "campaign".into(),
            "run".into(),
            "--spec".into(),
            task.spec_path.clone().into(),
            "--shard".into(),
            task.shard.to_string().into(),
            "--out".into(),
            task.out_dir.clone().into(),
        ];
        match &task.store {
            Some(root) => {
                args.push("--store".into());
                args.push(root.clone().into());
            }
            None => args.push("--no-store".into()),
        }
        args.push("--lease".into());
        args.push(task.lease_path.clone().into());
        args.push("--lease-ttl".into());
        args.push(task.lease_ttl_secs.to_string().into());
        args.push("--run-id".into());
        args.push(task.run_id.clone().into());
        args.push("--attempt".into());
        args.push(task.attempt.to_string().into());
        if let Some(cap) = task.max_points {
            args.push("--max-points".into());
            args.push(cap.to_string().into());
        }
        args
    }
}

impl Launcher for LocalLauncher {
    fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
        let mut cmd = Command::new(&self.exe);
        cmd.args(Self::args_of(task));
        cmd.stdin(Stdio::null());
        if self.quiet {
            cmd.stdout(Stdio::null());
        }
        let child = cmd.spawn().map_err(|e| {
            anyhow::anyhow!(
                "spawn {} for shard {} (attempt {}): {e}",
                self.exe.display(),
                task.shard,
                task.attempt
            )
        })?;
        Ok(Box::new(LocalWorker { child }))
    }
}

struct LocalWorker {
    child: Child,
}

impl WorkerHandle for LocalWorker {
    fn poll(&mut self) -> anyhow::Result<WorkerState> {
        match self.child.try_wait() {
            Ok(None) => Ok(WorkerState::Running),
            Ok(Some(status)) => Ok(WorkerState::Exited {
                success: status.success(),
            }),
            Err(e) => Err(anyhow::anyhow!("poll pid {}: {e}", self.child.id())),
        }
    }

    fn kill(&mut self) {
        // Both calls fail harmlessly on an already-reaped child.
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    fn describe(&self) -> String {
        format!("pid {}", self.child.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_cover_every_task_field() {
        let task = WorkerTask {
            spec_path: PathBuf::from("spec.toml"),
            shard: Shard::new(1, 3).unwrap(),
            out_dir: PathBuf::from("out"),
            store: Some(PathBuf::from("store-root")),
            lease_path: PathBuf::from("lease/shard-1-of-3.lease"),
            lease_ttl_secs: 12,
            run_id: "demo".into(),
            attempt: 2,
            max_points: Some(1),
        };
        let args: Vec<String> = LocalLauncher::args_of(&task)
            .into_iter()
            .map(|a| a.to_string_lossy().into_owned())
            .collect();
        let joined = args.join(" ");
        assert_eq!(&args[..2], ["campaign", "run"]);
        assert!(joined.contains("--spec spec.toml"), "{joined}");
        assert!(joined.contains("--shard 1/3"), "{joined}");
        assert!(joined.contains("--store store-root"), "{joined}");
        assert!(joined.contains("--lease-ttl 12"), "{joined}");
        assert!(joined.contains("--run-id demo"), "{joined}");
        assert!(joined.contains("--attempt 2"), "{joined}");
        assert!(joined.contains("--max-points 1"), "{joined}");
        assert!(!joined.contains("--no-store"), "{joined}");

        let mut bare = task.clone();
        bare.store = None;
        bare.max_points = None;
        let joined = LocalLauncher::args_of(&bare)
            .into_iter()
            .map(|a| a.to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join(" ");
        assert!(joined.contains("--no-store"), "{joined}");
        assert!(!joined.contains("--max-points"), "{joined}");
        assert!(!joined.contains("--store "), "{joined}");
    }
}
