//! Store compaction for long-lived shared trace stores (`fleet gc`).
//!
//! A store that outlives many fleet runs accumulates garbage that
//! nothing on the hot path may touch, precisely *because* every hot-path
//! write is careful: atomic temp+rename publication means a writer
//! killed between the write and the rename leaks a `.tmp-` file forever,
//! completed runs leave their lease directories behind as provenance,
//! and specs that stop being swept leave whole config-fingerprint
//! directories of traces nothing will read again. `occamy fleet gc`
//! sweeps all three, off the hot path, with a `--dry-run` mode that
//! reports without touching anything:
//!
//! * **Orphaned temp files** — any `.lease-tmp-*` or `.<stem>.tmp-*`
//!   file older than [`GcOptions::tmp_grace`]. The grace window keeps a
//!   *live* writer's milliseconds-old temp file safe; ages are computed
//!   with the same future-mtime clamp as [`super::lease::age`], so
//!   cross-host clock skew can only delay a sweep, never delete fresh
//!   work.
//! * **Lease directories of finished runs** — a
//!   `<root>/fleet/<run-id>/` directory whose lease files *all* read as
//!   `done` (or that carries a cancel marker: cancelled workers die
//!   before writing `done`, and a fresh run clears the marker) and
//!   whose newest entry is older than [`GcOptions::retention`]. A
//!   running or torn lease without a marker keeps the whole directory:
//!   conservative by design, since a torn lease on a non-atomic network
//!   filesystem may belong to a live worker.
//! * **Unreferenced config directories** — fingerprint directories not
//!   named by any spec passed on the command line. Pruning only runs
//!   when at least one spec *is* passed ([`GcOptions::keep_fingerprints`]
//!   is `Some`): with no referenced set in hand, "unreferenced" is
//!   unknowable and the pass is skipped rather than guessed.

use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

use crate::campaign::{check_point, stream, CampaignSpec};
use crate::sweep::SweepRecord;

use super::lease::{self, LeaseState};

/// What one [`run`] pass may touch.
#[derive(Debug, Clone)]
pub struct GcOptions {
    /// Completed-run lease directories younger than this are kept.
    pub retention: Duration,
    /// Temp files younger than this are presumed live and kept.
    pub tmp_grace: Duration,
    /// Report what would be removed without removing anything.
    pub dry_run: bool,
    /// Config fingerprints still referenced by known specs; directories
    /// outside the set are pruned. `None` skips the pruning pass.
    pub keep_fingerprints: Option<HashSet<String>>,
}

impl Default for GcOptions {
    fn default() -> Self {
        Self {
            retention: Duration::from_secs(7 * 24 * 3600),
            tmp_grace: Duration::from_secs(3600),
            dry_run: false,
            keep_fingerprints: None,
        }
    }
}

/// What a [`run`] pass found (and, unless dry-run, removed).
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    pub root: PathBuf,
    pub dry_run: bool,
    /// Orphaned temp files swept.
    pub orphaned_tmp: Vec<PathBuf>,
    /// Completed-run lease directories past retention, removed whole.
    pub removed_lease_dirs: Vec<PathBuf>,
    /// Lease directories kept (running, torn, or inside retention).
    pub kept_lease_dirs: usize,
    /// Config fingerprint directories pruned as unreferenced.
    pub pruned_configs: Vec<String>,
    /// Config directories kept, and the traces they hold.
    pub kept_configs: usize,
    pub kept_traces: usize,
    /// Best-effort removals that failed (the pass continues past them).
    pub errors: Vec<String>,
}

impl GcReport {
    /// Nothing was (or would be) removed.
    pub fn is_clean(&self) -> bool {
        self.orphaned_tmp.is_empty()
            && self.removed_lease_dirs.is_empty()
            && self.pruned_configs.is_empty()
    }
}

impl std::fmt::Display for GcReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let verb = if self.dry_run { "would remove" } else { "removed" };
        writeln!(
            f,
            "fleet gc {}{}:",
            self.root.display(),
            if self.dry_run { " (dry run)" } else { "" }
        )?;
        writeln!(f, "  orphaned temp file(s): {} {verb}", self.orphaned_tmp.len())?;
        for p in &self.orphaned_tmp {
            writeln!(f, "    {}", p.display())?;
        }
        writeln!(
            f,
            "  lease dir(s): {} completed past retention {verb}, {} kept",
            self.removed_lease_dirs.len(),
            self.kept_lease_dirs
        )?;
        for p in &self.removed_lease_dirs {
            writeln!(f, "    {}", p.display())?;
        }
        write!(
            f,
            "  config dir(s): {} kept ({} trace(s))",
            self.kept_configs, self.kept_traces
        )?;
        if self.pruned_configs.is_empty() {
            writeln!(f)?;
        } else {
            writeln!(
                f,
                ", {} unreferenced {verb}: {}",
                self.pruned_configs.len(),
                self.pruned_configs.join(", ")
            )?;
        }
        for e in &self.errors {
            writeln!(f, "  error: {e}")?;
        }
        Ok(())
    }
}

/// One compaction pass over a store root. Read-only when
/// `opts.dry_run`; otherwise removals are best-effort — a path that
/// cannot be removed lands in [`GcReport::errors`] and the pass
/// continues.
pub fn run(root: &Path, opts: &GcOptions) -> anyhow::Result<GcReport> {
    anyhow::ensure!(
        root.is_dir(),
        "store root {} does not exist (or is not a directory)",
        root.display()
    );
    let now = SystemTime::now();
    let mut report = GcReport {
        root: root.to_path_buf(),
        dry_run: opts.dry_run,
        ..GcReport::default()
    };
    // Temp files first: an orphan inside a removable lease directory is
    // then reported as what it is, instead of vanishing with the dir.
    sweep_tmp(root, now, opts, &mut report);
    sweep_lease_dirs(&root.join("fleet"), now, opts, &mut report);
    prune_configs(root, opts, &mut report);
    Ok(report)
}

/// Temp-file name patterns the atomic writers use:
/// `.<stem>.tmp-<pid>-<seq>` (the shared `campaign::store::atomic_write`
/// behind traces, manifests and [`super::lease::write`]) plus the
/// legacy `.lease-tmp-<pid>-<seq>` form older lease writers left
/// behind. Every legitimate store/lease file (traces `*.json`,
/// `config.toml`, `*.lease`, `*.jsonl`, `cancel`) starts with a
/// non-dot character.
fn is_orphan_tmp(name: &str) -> bool {
    name.starts_with(".lease-tmp-") || (name.starts_with('.') && name.contains(".tmp-"))
}

/// `<root>/<16 lowercase hex digits>` — the shape `store::fingerprint`
/// gives config directories. The `fleet/` subtree never matches.
fn is_fingerprint_name(name: &str) -> bool {
    name.len() == 16 && name.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
}

/// Age of a path from its mtime — [`lease::age_at`], so gc shares the
/// one future-mtime clamp (cross-host clock skew may delay a sweep,
/// never hasten it).
fn age_of(path: &Path, now: SystemTime) -> Option<Duration> {
    lease::age_at(path, now)
}

/// Recursively sweep orphaned temp files older than the grace window.
fn sweep_tmp(dir: &Path, now: SystemTime, opts: &GcOptions, report: &mut GcReport) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            report.errors.push(format!("read {}: {e}", dir.display()));
            return;
        }
    };
    for entry in entries.filter_map(Result::ok) {
        let path = entry.path();
        let Ok(ft) = entry.file_type() else { continue };
        if ft.is_dir() {
            sweep_tmp(&path, now, opts, report);
            continue;
        }
        let name = entry.file_name();
        if !is_orphan_tmp(&name.to_string_lossy()) {
            continue;
        }
        // Unknown age reads as zero: never delete what cannot be dated.
        let age = age_of(&path, now).unwrap_or(Duration::ZERO);
        if age < opts.tmp_grace {
            continue;
        }
        if !opts.dry_run {
            if let Err(e) = std::fs::remove_file(&path) {
                report.errors.push(format!("remove {}: {e}", path.display()));
                continue;
            }
        }
        report.orphaned_tmp.push(path);
    }
}

/// Remove `<root>/fleet/<run-id>/` directories whose runs completed
/// (every lease `done`) longer ago than the retention window.
fn sweep_lease_dirs(fleet_dir: &Path, now: SystemTime, opts: &GcOptions, report: &mut GcReport) {
    let entries = match std::fs::read_dir(fleet_dir) {
        // No fleet/ subtree at all is simply a store no fleet ever used.
        Err(_) => return,
        Ok(e) => e,
    };
    for entry in entries.filter_map(Result::ok) {
        let path = entry.path();
        if !entry.file_type().map(|t| t.is_dir()).unwrap_or(false) {
            continue;
        }
        match completed_run_age(&path, now) {
            Some(age) if age >= opts.retention => {
                if !opts.dry_run {
                    if let Err(e) = std::fs::remove_dir_all(&path) {
                        report.errors.push(format!("remove {}: {e}", path.display()));
                        report.kept_lease_dirs += 1;
                        continue;
                    }
                }
                report.removed_lease_dirs.push(path);
            }
            _ => report.kept_lease_dirs += 1,
        }
    }
}

/// `Some(age of the newest entry)` when the run can never resume:
/// either every lease file reads as `done`, or a cancel marker is
/// present (`fleet cancel` kills the workers before they can write
/// `done` leases, and a fresh `fleet run` clears the marker on startup
/// — so marker + past-retention age is unambiguously a dead run).
/// `None` (keep) when any lease is running, torn, or unreadable with no
/// marker — a torn lease on a non-atomic network filesystem may belong
/// to a live worker.
fn completed_run_age(dir: &Path, now: SystemTime) -> Option<Duration> {
    let cancelled = super::cancel_path(dir).exists();
    let mut newest = age_of(dir, now)?;
    for entry in std::fs::read_dir(dir).ok()?.filter_map(Result::ok) {
        let path = entry.path();
        if let Some(age) = age_of(&path, now) {
            newest = newest.min(age);
        }
        if path.extension().is_some_and(|x| x == "lease") && !cancelled {
            match lease::read(&path) {
                Some(l) if l.state == LeaseState::Done => {}
                _ => return None,
            }
        }
    }
    Some(newest)
}

/// Remove top-level fingerprint directories outside the referenced set;
/// count what stays either way so the report shows store size.
fn prune_configs(root: &Path, opts: &GcOptions, report: &mut GcReport) {
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(e) => {
            report.errors.push(format!("read {}: {e}", root.display()));
            return;
        }
    };
    for entry in entries.filter_map(Result::ok) {
        let path = entry.path();
        if !entry.file_type().map(|t| t.is_dir()).unwrap_or(false) {
            continue;
        }
        let name = entry.file_name().to_string_lossy().into_owned();
        if !is_fingerprint_name(&name) {
            continue;
        }
        let referenced = match &opts.keep_fingerprints {
            None => true, // no specs given: pruning pass disabled
            Some(keep) => keep.contains(&name),
        };
        if referenced {
            report.kept_configs += 1;
            report.kept_traces += traces_in_dir(&path);
        } else {
            if !opts.dry_run {
                if let Err(e) = std::fs::remove_dir_all(&path) {
                    report.errors.push(format!("remove {}: {e}", path.display()));
                    report.kept_configs += 1;
                    continue;
                }
            }
            report.pruned_configs.push(name);
        }
    }
    report.pruned_configs.sort_unstable();
}

fn traces_in_dir(dir: &Path) -> usize {
    match std::fs::read_dir(dir) {
        Err(_) => 0,
        Ok(entries) => entries
            .filter_map(Result::ok)
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .count(),
    }
}

/// Outcome of one [`prune_merged`] pass (`fleet gc --prune-merged`).
#[derive(Debug, Clone, Default)]
pub struct PruneReport {
    /// The merged file the shards were verified against.
    pub merged: PathBuf,
    pub dry_run: bool,
    /// Points the merged file was re-verified to cover.
    pub points: usize,
    /// Shard files whose every record matched the merged file — deleted
    /// (or, dry-run, deletable).
    pub pruned_shards: Vec<PathBuf>,
    /// Shard files kept, with the reason each survived.
    pub kept_shards: Vec<(PathBuf, String)>,
}

impl std::fmt::Display for PruneReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let verb = if self.dry_run { "would prune" } else { "pruned" };
        writeln!(
            f,
            "prune-merged {}{}: {} point(s) re-verified",
            self.merged.display(),
            if self.dry_run { " (dry run)" } else { "" },
            self.points
        )?;
        writeln!(
            f,
            "  shard file(s): {} {verb}, {} kept",
            self.pruned_shards.len(),
            self.kept_shards.len()
        )?;
        for p in &self.pruned_shards {
            writeln!(f, "    {}", p.display())?;
        }
        for (p, reason) in &self.kept_shards {
            writeln!(f, "    {} kept: {reason}", p.display())?;
        }
        Ok(())
    }
}

/// Delete the shard JSONL files behind a completed, verified merge.
///
/// Shard files are the write-ahead form of a campaign's results; once
/// `campaign merge --verify` has recombined them the merged file is the
/// canonical copy and the shards are redundant bulk (each line carries a
/// full trace). But "the merge succeeded once" is exactly the kind of
/// fact a long-lived shared store cannot trust — the merged file may
/// have been torn by a later crash, truncated by a copy, or left over
/// from a different grid. So this pass **re-verifies the merged file
/// from scratch, now**: every line must parse, carry the spec's config
/// fingerprint, match the spec's expansion point-for-point, and the
/// index set must cover the whole campaign exactly once. Any failure
/// aborts the pass with nothing deleted. A shard file is then pruned
/// only if every record it holds is bit-identical to the merged record
/// at the same index; mismatched or foreign shard files are kept and
/// reported, never silently dropped.
pub fn prune_merged(spec: &CampaignSpec, out_dir: &Path, dry_run: bool) -> anyhow::Result<PruneReport> {
    let fp = crate::campaign::store::fingerprint(&spec.config);
    let points = spec.expand();
    let merged = out_dir.join(stream::merged_file_name(&spec.name));
    let text = std::fs::read_to_string(&merged).map_err(|e| {
        anyhow::anyhow!("no merged file to verify against ({}: {e}); run `campaign merge` first", merged.display())
    })?;

    // Re-verify the merged file line by line. Every failure path prunes
    // nothing: a torn or foreign merge means the shards are still the
    // only trustworthy copy.
    let mut records: BTreeMap<usize, SweepRecord> = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let (line_fp, index, rec, _source) = stream::record_from_line(line).map_err(|e| {
            anyhow::anyhow!("{} line {}: {e} — merged file is torn, pruning nothing", merged.display(), lineno + 1)
        })?;
        anyhow::ensure!(
            line_fp == fp,
            "{} line {}: config fingerprint {line_fp} does not match the spec ({fp}), pruning nothing",
            merged.display(),
            lineno + 1
        );
        check_point(&points, index, &rec, &merged)?;
        anyhow::ensure!(
            records.insert(index, rec).is_none(),
            "{}: point {index} appears twice, pruning nothing",
            merged.display()
        );
    }
    anyhow::ensure!(
        records.len() == points.len(),
        "{}: {}/{} points present — merge incomplete, pruning nothing",
        merged.display(),
        records.len(),
        points.len()
    );

    let mut report = PruneReport {
        merged,
        dry_run,
        points: points.len(),
        ..PruneReport::default()
    };
    let prefix = format!("{}.shard-", spec.name);
    let mut shard_paths: Vec<PathBuf> = std::fs::read_dir(out_dir)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", out_dir.display()))?
        .filter_map(Result::ok)
        .filter(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            name.starts_with(&prefix) && name.ends_with(".jsonl")
        })
        .map(|e| e.path())
        .collect();
    shard_paths.sort();
    for path in shard_paths {
        match shard_subsumed_by(&path, &fp, &records) {
            Ok(()) => {
                if !dry_run {
                    if let Err(e) = std::fs::remove_file(&path) {
                        report.kept_shards.push((path, format!("remove failed: {e}")));
                        continue;
                    }
                }
                report.pruned_shards.push(path);
            }
            Err(reason) => report.kept_shards.push((path, reason)),
        }
    }
    Ok(report)
}

/// Every record in the shard file must be bit-identical to the verified
/// merged record at the same index. Torn tail lines don't block — the
/// merge was just proven complete, so a half-written line holds nothing
/// the merged file lacks.
fn shard_subsumed_by(
    path: &Path,
    fp: &str,
    merged: &BTreeMap<usize, SweepRecord>,
) -> Result<(), String> {
    let file = stream::read_shard(path, fp).map_err(|e| e.to_string())?;
    for (index, rec) in &file.records {
        match merged.get(index) {
            Some(m) if m == rec => {}
            Some(_) => return Err(format!("point {index} differs from the merged record")),
            None => return Err(format!("point {index} is not in the merged file")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::store::{fingerprint, TraceStore};
    use crate::campaign::Shard;
    use crate::config::Config;
    use crate::fleet::lease::Lease;
    use crate::kernels::JobSpec;
    use crate::offload::RoutineKind;
    use crate::sweep::OffloadRequest;

    /// Retention/grace of zero: everything eligible is eligible *now*.
    fn eager() -> GcOptions {
        GcOptions {
            retention: Duration::ZERO,
            tmp_grace: Duration::ZERO,
            dry_run: false,
            keep_fingerprints: None,
        }
    }

    fn temp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("occamy-gc-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A store root with one real trace, two planted orphans, one old
    /// completed run dir and one live running run dir.
    fn populated(tag: &str) -> (PathBuf, String, OffloadRequest) {
        let root = temp_root(tag);
        let cfg = Config::default();
        let fp = fingerprint(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 96 }, 2, RoutineKind::Baseline);
        let store = TraceStore::open(&root).unwrap();
        store.save(&fp, &cfg, &req, &req.run(&cfg)).unwrap();
        // Orphans: a killed trace writer and a killed lease writer.
        std::fs::write(root.join(&fp).join(".axpy_n96.tmp-999-0"), "torn").unwrap();
        let done_dir = root.join("fleet").join("old-run");
        std::fs::create_dir_all(&done_dir).unwrap();
        std::fs::write(done_dir.join(".lease-tmp-999-1"), "torn").unwrap();
        let mut done = Lease::new("old-run", Shard::SINGLE, 0, 5);
        done.state = LeaseState::Done;
        lease::write(&done_dir.join(lease::file_name(Shard::SINGLE)), &done).unwrap();
        let live_dir = root.join("fleet").join("live-run");
        let live = Lease::new("live-run", Shard::SINGLE, 0, 5);
        lease::write(&live_dir.join(lease::file_name(Shard::SINGLE)), &live).unwrap();
        (root, fp, req)
    }

    #[test]
    fn gc_sweeps_orphans_and_done_runs_but_keeps_live_state() {
        let (root, fp, req) = populated("sweep");
        // Dry run: everything reported, nothing touched.
        let dry = run(&root, &GcOptions { dry_run: true, ..eager() }).unwrap();
        assert_eq!(dry.orphaned_tmp.len(), 2, "{dry:?}");
        assert_eq!(dry.removed_lease_dirs.len(), 1, "{dry:?}");
        assert_eq!(dry.kept_lease_dirs, 1);
        assert!(root.join(&fp).join(".axpy_n96.tmp-999-0").exists());
        assert!(root.join("fleet").join("old-run").exists());
        let text = dry.to_string();
        assert!(text.contains("(dry run)"), "{text}");
        assert!(text.contains("orphaned temp file(s): 2 would remove"), "{text}");

        // Real pass: orphans and the old completed run go, live state stays.
        let report = run(&root, &eager()).unwrap();
        assert_eq!(report.orphaned_tmp.len(), 2, "{report:?}");
        assert_eq!(report.removed_lease_dirs.len(), 1);
        assert_eq!(report.kept_lease_dirs, 1);
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert!(!root.join(&fp).join(".axpy_n96.tmp-999-0").exists());
        assert!(!root.join("fleet").join("old-run").exists());
        assert!(root.join("fleet").join("live-run").exists(), "running lease survives");
        // The real trace and manifest are untouched and still load.
        let store = TraceStore::open(&root).unwrap();
        assert!(store.load(&fp, &req).is_some(), "valid trace survives gc");
        assert!(root.join(&fp).join("config.toml").exists());
        let report_text = report.to_string();
        assert!(report_text.contains("orphaned temp file(s): 2 removed"), "{report_text}");

        // A second pass finds nothing.
        let again = run(&root, &eager()).unwrap();
        assert!(again.is_clean(), "{again:?}");
    }

    #[test]
    fn cancelled_runs_age_out_despite_running_leases() {
        let root = temp_root("cancelled");
        // A cancelled run: workers were killed mid-shard, so their
        // leases are stuck Running, and the cancel marker is present.
        let dir = root.join("fleet").join("cancelled-run");
        let stuck = Lease::new("cancelled-run", Shard::SINGLE, 0, 5);
        lease::write(&dir.join(lease::file_name(Shard::SINGLE)), &stuck).unwrap();
        std::fs::write(crate::fleet::cancel_path(&dir), "cancelled\n").unwrap();
        // Without the marker an identical dir is kept forever...
        let live = root.join("fleet").join("live-run");
        lease::write(
            &live.join(lease::file_name(Shard::SINGLE)),
            &Lease::new("live-run", Shard::SINGLE, 0, 5),
        )
        .unwrap();
        let report = run(&root, &eager()).unwrap();
        assert_eq!(report.removed_lease_dirs, vec![dir.clone()]);
        assert_eq!(report.kept_lease_dirs, 1);
        assert!(!dir.exists());
        assert!(live.exists());
    }

    #[test]
    fn fresh_temp_files_survive_the_grace_window() {
        let (root, fp, _) = populated("grace");
        let opts = GcOptions {
            retention: Duration::ZERO,
            tmp_grace: Duration::from_secs(3600),
            dry_run: false,
            keep_fingerprints: None,
        };
        let report = run(&root, &opts).unwrap();
        assert!(report.orphaned_tmp.is_empty(), "just-planted temps are presumed live");
        assert!(root.join(&fp).join(".axpy_n96.tmp-999-0").exists());

        // A future mtime (clock skew) also reads as fresh — skew delays
        // sweeps, it never deletes fresh work.
        let tmp = root.join(&fp).join(".axpy_n96.tmp-999-0");
        let file = std::fs::OpenOptions::new().append(true).open(&tmp).unwrap();
        if file
            .set_modified(SystemTime::now() + Duration::from_secs(7200))
            .is_ok()
        {
            let report = run(&root, &opts).unwrap();
            assert!(report.orphaned_tmp.is_empty(), "{report:?}");
        }
    }

    #[test]
    fn unreferenced_config_dirs_prune_only_when_specs_are_known() {
        let (root, fp, req) = populated("prune");
        // A second, unreferenced config directory.
        let mut other_cfg = Config::default();
        other_cfg.timing.host_ipi_issue_gap += 1;
        let other_fp = fingerprint(&other_cfg);
        let store = TraceStore::open(&root).unwrap();
        store.save(&other_fp, &other_cfg, &req, &req.run(&other_cfg)).unwrap();

        // No keep set: both kept, pruning skipped.
        let no_specs = run(&root, &eager()).unwrap();
        assert!(no_specs.pruned_configs.is_empty());
        assert_eq!(no_specs.kept_configs, 2);
        assert_eq!(no_specs.kept_traces, 2);

        // Keep set naming only the first: the other is pruned.
        let opts = GcOptions {
            keep_fingerprints: Some([fp.clone()].into_iter().collect()),
            ..eager()
        };
        let report = run(&root, &opts).unwrap();
        assert_eq!(report.pruned_configs, vec![other_fp.clone()]);
        assert_eq!(report.kept_configs, 1);
        assert_eq!(report.kept_traces, 1);
        assert!(!root.join(&other_fp).exists());
        assert!(root.join(&fp).exists());
        assert!(root.join("fleet").exists(), "fleet/ is never fingerprint-shaped");
        assert!(report.to_string().contains("unreferenced removed"), "{}", report.to_string());
    }

    #[test]
    fn name_classifiers_are_precise() {
        assert!(is_orphan_tmp(".lease-tmp-42-0"));
        assert!(is_orphan_tmp(".axpy_n96-c2-baseline.tmp-42-7"));
        assert!(is_orphan_tmp(".config.tmp-1-1"));
        for live in [
            "config.toml",
            "axpy_n96-c2-baseline.json",
            "shard-0-of-2.lease",
            "cancel",
            "demo.merged.jsonl",
            ".hidden",
        ] {
            assert!(!is_orphan_tmp(live), "{live}");
        }
        assert!(is_fingerprint_name("0123456789abcdef"));
        for not_fp in [
            "fleet",
            "0123456789ABCDEF",
            "0123456789abcde",
            "0123456789abcdef0",
            "xyz3456789abcdef",
        ] {
            assert!(!is_fingerprint_name(not_fp), "{not_fp}");
        }
    }

    #[test]
    fn gc_refuses_a_missing_root() {
        let root = temp_root("missing").join("nope");
        let err = run(&root, &eager()).unwrap_err().to_string();
        assert!(err.contains("does not exist"), "{err}");
    }

    /// A tiny 4-point campaign with a unique timing override so the
    /// process-wide cache namespace stays disjoint per test.
    fn prune_spec(name: &str, gap: u64) -> CampaignSpec {
        CampaignSpec::parse(&format!(
            "[campaign]\nname = \"{name}\"\n[grid]\nkernels = [\"axpy:96\"]\nclusters = [1, 2]\n\
             routines = [\"baseline\", \"ideal\"]\n[timing]\nhost_ipi_issue_gap = {gap}\n"
        ))
        .unwrap()
    }

    #[test]
    fn prune_merged_deletes_shards_only_after_reverifying() {
        let out = temp_root("prune-merged");
        let spec = prune_spec("pm-demo", 9401);
        let shard0 = out.join(stream::shard_file_name(&spec.name, Shard::new(0, 2).unwrap()));
        let shard1 = out.join(stream::shard_file_name(&spec.name, Shard::new(1, 2).unwrap()));
        for i in 0..2 {
            crate::campaign::run_shard(&spec, Shard::new(i, 2).unwrap(), &out, None).unwrap();
        }
        // No merge yet: nothing to verify against, nothing deleted.
        let err = prune_merged(&spec, &out, false).unwrap_err().to_string();
        assert!(err.contains("no merged file"), "{err}");
        assert!(shard0.exists() && shard1.exists());

        crate::campaign::merge(&spec, 2, &out).unwrap();
        // Dry run: reports both shards as prunable, touches neither.
        let dry = prune_merged(&spec, &out, true).unwrap();
        assert_eq!(dry.pruned_shards.len(), 2, "{dry:?}");
        assert!(shard0.exists() && shard1.exists());
        assert!(dry.to_string().contains("2 would prune, 0 kept"), "{dry}");

        let report = prune_merged(&spec, &out, false).unwrap();
        assert_eq!(report.pruned_shards.len(), 2, "{report:?}");
        assert!(report.kept_shards.is_empty(), "{report:?}");
        assert_eq!(report.points, 4);
        assert!(!shard0.exists() && !shard1.exists());
        assert!(out.join(stream::merged_file_name(&spec.name)).exists(), "merged file survives");
        assert!(report.to_string().contains("2 pruned, 0 kept"), "{report}");

        // A second pass still verifies but has nothing left to prune.
        let again = prune_merged(&spec, &out, false).unwrap();
        assert!(again.pruned_shards.is_empty() && again.kept_shards.is_empty(), "{again:?}");
    }

    #[test]
    fn torn_or_incomplete_merges_prune_nothing() {
        let out = temp_root("prune-torn");
        let spec = prune_spec("pm-torn", 9403);
        crate::campaign::run_shard(&spec, Shard::SINGLE, &out, None).unwrap();
        crate::campaign::merge(&spec, 1, &out).unwrap();
        let merged = out.join(stream::merged_file_name(&spec.name));
        let shard = out.join(stream::shard_file_name(&spec.name, Shard::SINGLE));
        let intact = std::fs::read_to_string(&merged).unwrap();

        // Torn tail (killed writer, truncated copy): refuse.
        std::fs::write(&merged, format!("{intact}{{\"config\":\"torn")).unwrap();
        let err = prune_merged(&spec, &out, false).unwrap_err().to_string();
        assert!(err.contains("pruning nothing"), "{err}");
        assert!(shard.exists(), "a torn merge must not cost the shards");

        // Incomplete (missing point): refuse.
        let lines: Vec<&str> = intact.lines().collect();
        std::fs::write(&merged, format!("{}\n", lines[..lines.len() - 1].join("\n"))).unwrap();
        let err = prune_merged(&spec, &out, false).unwrap_err().to_string();
        assert!(err.contains("merge incomplete"), "{err}");
        assert!(shard.exists());

        // Intact again: now the shard is redundant and goes.
        std::fs::write(&merged, &intact).unwrap();
        let report = prune_merged(&spec, &out, false).unwrap();
        assert_eq!(report.pruned_shards, vec![shard.clone()]);
        assert!(!shard.exists());
    }

    #[test]
    fn foreign_and_mismatched_shards_are_kept_with_reasons() {
        let out = temp_root("prune-foreign");
        let spec = prune_spec("pm-foreign", 9405);
        crate::campaign::run_shard(&spec, Shard::SINGLE, &out, None).unwrap();
        crate::campaign::merge(&spec, 1, &out).unwrap();
        let fp = fingerprint(&spec.config);
        let real_shard = out.join(stream::shard_file_name(&spec.name, Shard::SINGLE));
        let first_line = {
            let text = std::fs::read_to_string(&real_shard).unwrap();
            text.lines().next().unwrap().to_string()
        };

        // A full, parsable record under a different config fingerprint:
        // read_shard hard-errors, so the file is kept with the reason.
        let foreign = out.join(format!("{}.shard-2-of-3.jsonl", spec.name));
        std::fs::write(&foreign, format!("{}\n", first_line.replace(&fp, "ffffffffffffffff"))).unwrap();
        // A record claiming an index whose merged content differs.
        let swapped = out.join(format!("{}.shard-1-of-3.jsonl", spec.name));
        let retargeted = first_line.replace("\"index\":0", "\"index\":3");
        assert_ne!(retargeted, first_line, "line surgery must hit the index field");
        std::fs::write(&swapped, format!("{retargeted}\n")).unwrap();

        let report = prune_merged(&spec, &out, false).unwrap();
        assert_eq!(report.pruned_shards, vec![real_shard.clone()], "{report:?}");
        assert_eq!(report.kept_shards.len(), 2, "{report:?}");
        assert!(!real_shard.exists());
        assert!(foreign.exists() && swapped.exists(), "suspect shards must survive");
        let text = report.to_string();
        assert!(text.contains("1 pruned, 2 kept"), "{text}");
        assert!(text.contains("kept:"), "{text}");
    }
}
