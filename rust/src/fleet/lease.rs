//! Heartbeat lease files: how a fleet scheduler sees worker liveness
//! through nothing but a shared filesystem.
//!
//! Each worker owns one lease file,
//! `<store root>/fleet/<run-id>/shard-<i>-of-<N>.lease`, and refreshes
//! it (atomic temp-file + rename, like `campaign::store`) every quarter
//! of its TTL, bumping a monotonic `seq` counter. The scheduler never
//! compares clocks across hosts: it watches the *content* change and
//! declares a shard stale when `seq` has not advanced for a TTL on its
//! own monotonic clock. One-shot status displays, which have no history
//! to difference, fall back to the file's mtime age — good enough for a
//! human-facing staleness hint.
//!
//! A worker that finishes its shard rewrites the lease in the `done`
//! state; a worker that dies simply stops writing, and its lease goes
//! stale. Either way the file is the complete protocol — there is no
//! side channel, which is what makes the `Launcher` seam host-agnostic.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::campaign::Shard;
use crate::runtime::json::Json;

/// Lifecycle state recorded in a lease file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeaseState {
    /// The worker is (or was, if the lease is stale) executing points.
    Running,
    /// The worker confirmed every owned point is in the output file.
    Done,
}

impl LeaseState {
    pub fn name(self) -> &'static str {
        match self {
            LeaseState::Running => "running",
            LeaseState::Done => "done",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "running" => Some(LeaseState::Running),
            "done" => Some(LeaseState::Done),
            _ => None,
        }
    }
}

/// One worker's lease: identity, heartbeat counter and TTL.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lease {
    /// The fleet run this lease belongs to (leases from another run in
    /// the same directory are a configuration error, not a heartbeat).
    pub run_id: String,
    pub shard: Shard,
    /// 0 for the initial launch, +1 per relaunch.
    pub attempt: usize,
    /// Process id of the writer (diagnostics only — pids are not
    /// comparable across hosts).
    pub pid: u32,
    /// Monotonic heartbeat counter; staleness = no advance for a TTL.
    pub seq: u64,
    /// The TTL the writer was told to honour, so one-shot status
    /// readers know the threshold without the fleet options in hand.
    pub ttl_secs: u64,
    pub state: LeaseState,
}

impl Lease {
    /// A fresh `Running` lease for this process.
    pub fn new(run_id: impl Into<String>, shard: Shard, attempt: usize, ttl_secs: u64) -> Self {
        Self {
            run_id: run_id.into(),
            shard,
            attempt,
            pid: std::process::id(),
            seq: 0,
            ttl_secs,
            state: LeaseState::Running,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            [
                ("run".to_string(), Json::Str(self.run_id.clone())),
                ("shard".to_string(), Json::Str(self.shard.to_string())),
                ("attempt".to_string(), Json::Num(self.attempt as f64)),
                ("pid".to_string(), Json::Num(self.pid as f64)),
                ("seq".to_string(), Json::Num(self.seq as f64)),
                ("ttl_secs".to_string(), Json::Num(self.ttl_secs as f64)),
                ("state".to_string(), Json::Str(self.state.name().to_string())),
            ]
            .into_iter()
            .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let str_field = |key: &str| -> Result<&str, String> {
            j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("missing or invalid {key:?}"))
        };
        let num_field = |key: &str| -> Result<u64, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| format!("missing or invalid {key:?}"))
        };
        let state = str_field("state")?;
        Ok(Self {
            run_id: str_field("run")?.to_string(),
            shard: Shard::parse(str_field("shard")?).map_err(|e| e.to_string())?,
            attempt: num_field("attempt")? as usize,
            pid: num_field("pid")? as u32,
            seq: num_field("seq")?,
            ttl_secs: num_field("ttl_secs")?,
            state: LeaseState::parse(state).ok_or_else(|| format!("unknown state {state:?}"))?,
        })
    }
}

/// Lease file name of one shard: `shard-<i>-of-<N>.lease`.
pub fn file_name(shard: Shard) -> String {
    format!("shard-{}-of-{}.lease", shard.index, shard.count)
}

/// Atomically (re)write a lease: temp file in the same directory, then
/// rename over the target, so a reader never observes a torn lease.
/// Uses the shared `campaign::store` publication idiom (one temp-name
/// family, one unlink-on-failure cleanup path, swept by `fleet gc` when
/// a writer dies between write and rename).
pub fn write(path: &Path, lease: &Lease) -> anyhow::Result<()> {
    let dir = path
        .parent()
        .ok_or_else(|| anyhow::anyhow!("lease path {} has no parent directory", path.display()))?;
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("create lease dir {}: {e}", dir.display()))?;
    crate::campaign::store::atomic_write(dir, path, "lease", &lease.to_json().to_string())
}

/// Read a lease; `None` for an absent or unparsable file. Unparsable is
/// deliberately soft: on a network filesystem without atomic rename a
/// torn read is indistinguishable from "no heartbeat observed yet", and
/// the staleness clock handles both.
pub fn read(path: &Path) -> Option<Lease> {
    let text = std::fs::read_to_string(path).ok()?;
    Json::parse(&text).ok().and_then(|j| Lease::from_json(&j).ok())
}

/// Wall-clock age of the lease file, from its mtime. Only the one-shot
/// status views use this (the scheduler differences `seq` on a
/// monotonic clock instead); `None` when the file is absent or the
/// filesystem reports no usable mtime. A *future* mtime — routine on
/// NFS when the writing host's clock runs ahead — clamps to zero age
/// rather than `None`: the old `elapsed().ok()` turned skew into a
/// missing staleness hint for exactly the hosts most likely wedged.
pub fn age(path: &Path) -> Option<Duration> {
    age_at(path, std::time::SystemTime::now())
}

/// [`age`] against an explicit "now" — the testable seam for the
/// cross-host clock-skew clamp.
pub fn age_at(path: &Path, now: std::time::SystemTime) -> Option<Duration> {
    let mtime = std::fs::metadata(path).ok()?.modified().ok()?;
    Some(now.duration_since(mtime).unwrap_or(Duration::ZERO))
}

/// A background thread refreshing one lease every TTL/4 (min 25 ms)
/// until stopped. Dropping it stops the refresh and *leaves the last
/// `Running` lease in place* — exactly what a crash would do, so the
/// scheduler path for "worker vanished" and "worker dropped its
/// heartbeat" is one and the same. Call [`Heartbeat::finish`] instead
/// when the shard completed.
pub struct Heartbeat {
    path: PathBuf,
    lease: Lease,
    seq: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    /// Write the initial lease and start refreshing it.
    pub fn start(path: PathBuf, lease: Lease) -> anyhow::Result<Self> {
        write(&path, &lease)?;
        let stop = Arc::new(AtomicBool::new(false));
        let seq = Arc::new(AtomicU64::new(lease.seq));
        let period = Duration::from_millis(lease.ttl_secs.saturating_mul(250).clamp(25, 10_000));
        let thread = {
            let (path, lease) = (path.clone(), lease.clone());
            let (stop, seq) = (Arc::clone(&stop), Arc::clone(&seq));
            std::thread::spawn(move || {
                // ordering: Relaxed — stop is an advisory quit flag; halt
                // joins the thread, and the join itself orders everything
                // the beater wrote before any post-halt reads.
                while !stop.load(Ordering::Relaxed) {
                    // Sleep in small slices so finish()/drop return
                    // promptly even with a long TTL.
                    let mut slept = Duration::ZERO;
                    // ordering: Relaxed — same advisory stop flag.
                    while slept < period && !stop.load(Ordering::Relaxed) {
                        let slice = (period - slept).min(Duration::from_millis(20));
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                    // ordering: Relaxed — same advisory stop flag.
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let mut beat = lease.clone();
                    // ordering: Relaxed — seq is only a beat counter; the
                    // lease itself is published via the file write.
                    beat.seq = seq.fetch_add(1, Ordering::Relaxed) + 1;
                    // A transiently unwritable shared directory must not
                    // kill the worker; a few missed beats only risk one
                    // spurious (and resume-safe) relaunch.
                    let _ = write(&path, &beat);
                }
            })
        };
        Ok(Self {
            path,
            lease,
            seq,
            stop,
            thread: Some(thread),
        })
    }

    /// Heartbeats written so far (the initial write is seq 0).
    pub fn seq(&self) -> u64 {
        // ordering: Relaxed — diagnostic beat count, no payload behind it.
        self.seq.load(Ordering::Relaxed)
    }

    fn halt(&mut self) {
        // ordering: Relaxed — advisory quit flag; the join below is the
        // real synchronization point with the beater thread.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    /// Stop refreshing and mark the lease `Done` — the worker verified
    /// that every owned point is in the shard's output file.
    pub fn finish(mut self) -> anyhow::Result<()> {
        self.halt();
        let mut fin = self.lease.clone();
        // ordering: Relaxed — halt() joined the beater, so this read is
        // already ordered after its last fetch_add.
        fin.seq = self.seq.load(Ordering::Relaxed) + 1;
        fin.state = LeaseState::Done;
        write(&self.path, &fin)
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("occamy-lease-test-{}-{tag}", std::process::id()))
            .join("shard-0-of-2.lease")
    }

    #[test]
    fn lease_round_trips_through_json() {
        let lease = Lease {
            run_id: "demo".into(),
            shard: Shard::new(1, 3).unwrap(),
            attempt: 2,
            pid: 4242,
            seq: 17,
            ttl_secs: 30,
            state: LeaseState::Done,
        };
        let text = lease.to_json().to_string();
        assert!(!text.contains('\n'));
        let back = Lease::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, lease);
    }

    #[test]
    fn write_read_round_trips_and_tolerates_garbage() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        assert_eq!(read(&path), None, "absent lease reads as None");
        assert_eq!(age(&path), None);
        let lease = Lease::new("rt", Shard::new(0, 2).unwrap(), 0, 5);
        write(&path, &lease).unwrap();
        assert_eq!(read(&path), Some(lease.clone()));
        assert!(age(&path).is_some());
        // Corruption (torn write on a non-atomic FS) degrades to None.
        for bad in ["", "{", "not json", "{\"run\":\"rt\"}", "{\"run\":1}"] {
            std::fs::write(&path, bad).unwrap();
            assert_eq!(read(&path), None, "{bad:?}");
        }
        // Bad field values are rejected, not coerced.
        let mut torn = lease.clone();
        torn.seq = 9;
        let text = torn.to_json().to_string().replace("\"0/2\"", "\"2/2\"");
        std::fs::write(&path, text).unwrap();
        assert_eq!(read(&path), None, "out-of-range shard is corruption");
    }

    #[test]
    fn heartbeat_advances_seq_and_finish_marks_done() {
        let path = temp_path("heartbeat");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        // ttl 1s => 250 ms period.
        let hb = Heartbeat::start(path.clone(), Lease::new("hb", Shard::SINGLE, 1, 1)).unwrap();
        let initial = read(&path).expect("initial lease written synchronously");
        assert_eq!(initial.state, LeaseState::Running);
        assert_eq!(initial.seq, 0);
        assert_eq!(initial.attempt, 1);
        // Wait for at least one refresh (generous margin for slow CI).
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while hb.seq() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(25));
        }
        assert!(hb.seq() >= 1, "heartbeat thread never refreshed the lease");
        let beating = read(&path).unwrap();
        assert_eq!(beating.state, LeaseState::Running);
        hb.finish().unwrap();
        let done = read(&path).unwrap();
        assert_eq!(done.state, LeaseState::Done);
        assert!(done.seq >= 1);
    }

    #[test]
    fn dropping_a_heartbeat_leaves_the_running_lease() {
        let path = temp_path("dropped");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        let hb = Heartbeat::start(path.clone(), Lease::new("drop", Shard::SINGLE, 0, 5)).unwrap();
        drop(hb);
        // The lease is still there, still Running: to any scheduler it
        // is indistinguishable from a crash, and goes stale.
        assert_eq!(read(&path).unwrap().state, LeaseState::Running);
    }

    #[test]
    fn a_future_mtime_clamps_age_to_zero() {
        let path = temp_path("skew");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
        write(&path, &Lease::new("skew", Shard::SINGLE, 0, 5)).unwrap();
        let mtime = std::fs::metadata(&path).unwrap().modified().unwrap();
        // A reader whose clock runs *behind* the writer's (cross-host
        // skew over NFS) sees a future mtime; the age must clamp to
        // zero, not vanish.
        let behind = mtime - Duration::from_secs(120);
        assert_eq!(age_at(&path, behind), Some(Duration::ZERO));
        // A reader ahead of the writer sees the true age.
        let ahead = mtime + Duration::from_secs(120);
        assert_eq!(age_at(&path, ahead), Some(Duration::from_secs(120)));
        // The wall-clock entry point agrees with the seam (fresh file,
        // so both are near zero — and crucially Some, not None).
        assert!(age(&path).unwrap() < Duration::from_secs(60));

        // Belt and braces: physically stamp a future mtime and read it
        // back through the production path.
        let file = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        if file
            .set_modified(std::time::SystemTime::now() + Duration::from_secs(3600))
            .is_ok()
        {
            assert_eq!(age(&path), Some(Duration::ZERO), "future mtime hides staleness");
        }
    }

    #[test]
    fn a_failed_lease_rename_does_not_leak_the_temp_file() {
        let path = temp_path("rename-fail");
        let dir = path.parent().unwrap().to_path_buf();
        let _ = std::fs::remove_dir_all(&dir);
        // Occupy the lease path with a directory so the rename fails.
        std::fs::create_dir_all(&path).unwrap();
        let err = write(&path, &Lease::new("leak", Shard::SINGLE, 0, 5)).unwrap_err().to_string();
        assert!(err.contains("rename"), "{err}");
        let leaked: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(Result::ok)
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with('.'))
            .collect();
        assert!(leaked.is_empty(), "temp files leaked: {leaked:?}");
        // Clearing the obstruction lets the same write succeed.
        std::fs::remove_dir(&path).unwrap();
        write(&path, &Lease::new("leak", Shard::SINGLE, 0, 5)).unwrap();
        assert!(read(&path).is_some());
    }

    #[test]
    fn file_names_embed_the_split() {
        assert_eq!(file_name(Shard::new(2, 5).unwrap()), "shard-2-of-5.lease");
        assert_eq!(file_name(Shard::SINGLE), "shard-0-of-1.lease");
    }
}
