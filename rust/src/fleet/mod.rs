//! # Multi-host campaign scheduling (`fleet`)
//!
//! [`crate::campaign`] made sweeps sharded, resumable and
//! bit-identically mergeable — but a human still had to start
//! `campaign run --shard i/N` on every host and run `merge` at the end.
//! This subsystem closes that loop: a campaign spec plus a worker count
//! becomes one fully automatic run.
//!
//! * [`launcher`] — the placement seam. The scheduler talks only to the
//!   [`Launcher`]/[`WorkerHandle`] traits; [`LocalLauncher`] implements
//!   them with local `occamy campaign run` subprocesses, and
//!   [`SshLauncher`] fans the same workers out as
//!   `ssh <host> <remote-occamy> campaign run ...` against a shared
//!   mount (round-robin host placement, pid captured from the remote
//!   shell, kill via `ssh <host> kill <pid>`) — the scheduler is
//!   untouched, because all shared state (streamed JSONL results,
//!   heartbeat leases, the trace store) lives on the filesystem.
//! * [`lease`] — liveness through the shared filesystem alone: each
//!   worker refreshes `<store>/fleet/<run-id>/shard-<i>-of-<N>.lease`
//!   (atomic rename, monotonic `seq`); the scheduler declares a shard
//!   stale when its `seq` stops advancing for a TTL and reassigns it.
//! * [`run`] — the scheduler: plan shards, launch workers, poll exits
//!   and leases, relaunch dead or stalled shards (resume-after-kill
//!   makes reassignment safe — finished points are never redone), honor
//!   a `cancel` marker file, and auto-merge into [`SweepResults`]
//!   **bit-identical** to a single-process run when the last shard
//!   lands.
//! * [`status`]/[`StatusView`] — one renderer for per-shard progress
//!   (points done/total, fresh-simulation vs. store/cache-hit counts
//!   from the streamed JSONL, lease state/staleness), shared by
//!   `occamy campaign status` and `occamy fleet status`.
//! * [`gc`] — compaction for long-lived shared stores: sweep the
//!   `.tmp-*`/`.lease-tmp-*` orphans of killed writers, remove lease
//!   directories of completed runs past a retention window, and prune
//!   config directories no known spec references
//!   (`occamy fleet gc --store ROOT [--dry-run] [SPEC..]`).
//!
//! Quickstart (spec in `examples/fleet.toml`, `[fleet]` table holds the
//! defaults):
//!
//! ```text
//! occamy fleet run    --spec examples/fleet.toml --workers 3
//! occamy fleet status --spec examples/fleet.toml --workers 3
//! occamy fleet watch  --spec examples/fleet.toml --workers 3
//! occamy fleet cancel --spec examples/fleet.toml
//! occamy fleet gc     --store campaign-out/fleet-demo/store --dry-run
//! ```
//!
//! Multi-host: list hosts in the spec's `[fleet]` table (or `--hosts`)
//! and every path — spec, out dir, store — on a shared mount; the same
//! scheduler then drives the shards over SSH:
//!
//! ```toml
//! [fleet]
//! workers    = 4
//! hosts      = ["node-a", "node-b bin=/opt/occamy root=/data/shared"]
//! remote_bin = "/shared/bin/occamy"   # default for hosts without bin=
//! local_root = "/mnt/shared"          # prefix the per-host root= replaces
//! ```

pub mod gc;
pub mod launcher;
pub mod lease;

pub use gc::{GcOptions, GcReport};
pub use launcher::{Launcher, LocalLauncher, SshLauncher, WorkerHandle, WorkerState, WorkerTask};
pub use lease::{Heartbeat, Lease, LeaseState};

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::campaign::{self, store, stream, CampaignSpec, CampaignStatus, Shard};
use crate::obs::log::{self as obslog, Event, Level};
use crate::obs::metrics::Registry;
use crate::obs::span::{self, TraceContext};
use crate::sweep::SweepResults;

/// Scheduler parameters for one fleet run. [`FleetOptions::new`] seeds
/// them from the spec's `[fleet]` table (or [`campaign::FleetSpec`]
/// defaults); the CLI layers flag overrides on top.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Shard count — one worker per shard.
    pub workers: usize,
    /// No heartbeat for this long ⇒ the shard is stale and reassigned.
    /// The lease protocol's granularity is whole seconds, so this is
    /// rounded *up* to seconds (min 1 s) before use.
    pub lease_ttl: Duration,
    /// Relaunches allowed per shard before the fleet run fails.
    pub max_restarts: usize,
    /// Scheduler poll interval.
    pub poll: Duration,
    /// Names the lease directory; defaults to the campaign name.
    pub run_id: String,
    pub out_dir: PathBuf,
    /// Shared trace store root (`None` disables the store, and leases
    /// fall back to living under the output directory).
    pub store: Option<PathBuf>,
    /// Chaos injection: this shard's first attempt runs with
    /// `--max-points 1`, so it dies mid-shard and exercises the
    /// recovery path (CI smoke tests; `--chaos-kill` on the CLI).
    pub chaos_kill: Option<usize>,
}

impl FleetOptions {
    pub fn new(spec: &CampaignSpec, out_dir: PathBuf) -> Self {
        let defaults = spec.fleet.clone().unwrap_or_default();
        Self {
            workers: defaults.workers,
            lease_ttl: Duration::from_secs(defaults.lease_ttl_secs),
            max_restarts: defaults.max_restarts,
            poll: Duration::from_millis(200),
            run_id: spec.name.clone(),
            store: Some(out_dir.join("store")),
            out_dir,
            chaos_kill: None,
        }
    }

    /// Where this run's leases (and cancel marker) live.
    pub fn lease_dir(&self) -> PathBuf {
        lease_dir_of(&self.out_dir, self.store.as_deref(), &self.run_id)
    }
}

/// Lease directory of a run: `<store root>/fleet/<run-id>` (falling
/// back to the output dir without a store — both are shared across the
/// fleet's hosts, which is all that matters).
pub fn lease_dir_of(out_dir: &Path, store: Option<&Path>, run_id: &str) -> PathBuf {
    store.unwrap_or(out_dir).join("fleet").join(run_id)
}

/// The cancel marker inside a lease directory: `occamy fleet cancel`
/// creates it, a running scheduler stops (and kills its workers) at the
/// next poll, and a fresh `fleet run` clears it on startup.
pub fn cancel_path(lease_dir: &Path) -> PathBuf {
    lease_dir.join("cancel")
}

/// How one shard fared across the whole run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardOutcome {
    pub shard: Shard,
    /// Relaunches this shard needed (0 = first worker finished it).
    pub restarts: usize,
}

/// Outcome of a completed [`run`]: merged results plus provenance.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub run_id: String,
    pub shards: Vec<ShardOutcome>,
    /// Merged, input-ordered results — bit-identical to
    /// [`campaign::run_single`].
    pub results: SweepResults,
    /// The merged JSONL stream on disk.
    pub merged: PathBuf,
    /// Points the streamed lines label as freshly simulated, across
    /// every attempt of every shard.
    pub sims: usize,
    /// Points labelled as store/cache hits.
    pub hits: usize,
}

impl FleetReport {
    pub fn restarts(&self) -> usize {
        self.shards.iter().map(|s| s.restarts).sum()
    }
}

impl std::fmt::Display for FleetReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "fleet {:?}: {} shard(s) complete, {} restart(s)",
            self.run_id,
            self.shards.len(),
            self.restarts()
        )?;
        write!(
            f,
            "merged {} point(s) ({} fresh simulation(s), {} store/cache hit(s)) -> {}",
            self.results.len(),
            self.sims,
            self.hits,
            self.merged.display()
        )
    }
}

enum Slot {
    Running {
        handle: Box<dyn WorkerHandle>,
        attempt: usize,
        /// Last lease `seq` observed, if any.
        last_seq: Option<u64>,
        /// When the lease last advanced (or the worker launched) — on
        /// the *scheduler's* monotonic clock, so multi-host clock skew
        /// cannot fake liveness.
        last_advance: Instant,
    },
    Done {
        restarts: usize,
    },
}

enum Verdict {
    Keep,
    Exited { success: bool },
    Stale { silent_for: Duration },
    Foreign { other_run: String },
}

struct Scheduler<'a> {
    spec: &'a CampaignSpec,
    spec_path: &'a Path,
    launcher: &'a dyn Launcher,
    opts: &'a FleetOptions,
    fp: String,
    total: usize,
    lease_dir: PathBuf,
    cancel: PathBuf,
    shards: Vec<Shard>,
    slots: Vec<Slot>,
}

impl Scheduler<'_> {
    /// The staleness window, rounded *up* to whole seconds — the same
    /// value workers receive as their lease TTL, so the heartbeat
    /// period (TTL/4) always fits inside the window with 4x margin no
    /// matter what sub-second `FleetOptions.lease_ttl` a caller picks.
    fn staleness_ttl(&self) -> Duration {
        let ttl = self.opts.lease_ttl;
        Duration::from_secs((ttl.as_secs() + u64::from(ttl.subsec_nanos() > 0)).max(1))
    }

    fn task(&self, shard: Shard, attempt: usize) -> WorkerTask {
        let ttl_secs = self.staleness_ttl().as_secs();
        WorkerTask {
            spec_path: self.spec_path.to_path_buf(),
            shard,
            out_dir: self.opts.out_dir.clone(),
            store: self.opts.store.clone(),
            lease_path: self.lease_dir.join(lease::file_name(shard)),
            lease_ttl_secs: ttl_secs,
            run_id: self.opts.run_id.clone(),
            attempt,
            max_points: (self.opts.chaos_kill == Some(shard.index) && attempt == 0).then_some(1),
            // Every worker inherits the run's root trace context, so
            // shard spans from every host stitch under one fleet tree.
            trace_parent: Some(TraceContext::root(&self.opts.run_id).render()),
        }
    }

    fn drive(&mut self) -> anyhow::Result<()> {
        let tasks: Vec<WorkerTask> = self.shards.iter().map(|&s| self.task(s, 0)).collect();
        for task in tasks {
            let handle = self.launcher.launch(&task)?;
            if obslog::enabled() {
                obslog::emit(
                    &Event::wall("fleet", "worker_launch")
                        .str("run_id", &self.opts.run_id)
                        .str("shard", &task.shard.to_string())
                        .u64("attempt", 0),
                );
            }
            self.slots.push(Slot::Running {
                handle,
                attempt: 0,
                last_seq: None,
                last_advance: Instant::now(),
            });
        }
        loop {
            anyhow::ensure!(
                !self.cancel.exists(),
                "fleet {:?} cancelled via {} (workers stopped; remove the marker or start a new `fleet run` to continue)",
                self.opts.run_id,
                self.cancel.display()
            );
            if self.slots.iter().all(|s| matches!(s, Slot::Done { .. })) {
                return Ok(());
            }
            for i in 0..self.slots.len() {
                self.step(i)?;
            }
            std::thread::sleep(self.opts.poll);
        }
    }

    /// Poll one shard's worker and apply the resulting transition.
    fn step(&mut self, i: usize) -> anyhow::Result<()> {
        let shard = self.shards[i];
        let lease_path = self.lease_dir.join(lease::file_name(shard));
        let ttl = self.staleness_ttl();
        let run_id = self.opts.run_id.clone();
        let verdict = match &mut self.slots[i] {
            Slot::Done { .. } => Verdict::Keep,
            Slot::Running {
                handle,
                attempt,
                last_seq,
                last_advance,
            } => match handle.poll()? {
                WorkerState::Exited { success } => Verdict::Exited { success },
                WorkerState::Running => {
                    match lease::read(&lease_path) {
                        Some(l) if l.run_id != run_id => Verdict::Foreign { other_run: l.run_id },
                        observed => {
                            // Only a *changing* seq from the attempt we
                            // are tracking proves liveness: a predecessor
                            // attempt that survived kill() (possible
                            // behind a remote launcher) must not fake a
                            // heartbeat for its dead replacement. None
                            // (not written yet / torn read) never counts.
                            let seq = observed.filter(|l| l.attempt == *attempt).map(|l| l.seq);
                            if seq.is_some() && seq != *last_seq {
                                *last_seq = seq;
                                *last_advance = Instant::now();
                            }
                            let silent_for = last_advance.elapsed();
                            if silent_for >= ttl {
                                Verdict::Stale { silent_for }
                            } else {
                                Verdict::Keep
                            }
                        }
                    }
                }
            },
        };
        match verdict {
            Verdict::Keep => Ok(()),
            Verdict::Foreign { other_run } => anyhow::bail!(
                "lease {} belongs to fleet run {other_run:?}, this run is {:?} — two fleets are sharing one lease directory; pick distinct --run-id values",
                lease_path.display(),
                self.opts.run_id
            ),
            Verdict::Exited { success } => {
                let done = self.done_points(shard)?;
                let owned = shard.indices(self.total).len();
                if success && done >= owned {
                    self.finish_slot(i);
                    Ok(())
                } else {
                    self.restart(
                        i,
                        &format!(
                            "worker exited {} with {done}/{owned} points done",
                            if success { "cleanly" } else { "with failure" }
                        ),
                    )
                }
            }
            Verdict::Stale { silent_for } => self.restart(
                i,
                &format!(
                    "no heartbeat for {}ms (lease ttl {}ms)",
                    silent_for.as_millis(),
                    ttl.as_millis()
                ),
            ),
        }
    }

    /// Points of `shard` currently in its output file.
    fn done_points(&self, shard: Shard) -> anyhow::Result<usize> {
        let path = self.opts.out_dir.join(stream::shard_file_name(&self.spec.name, shard));
        Ok(stream::read_shard(&path, &self.fp)?.records.len())
    }

    fn finish_slot(&mut self, i: usize) {
        let slot = std::mem::replace(&mut self.slots[i], Slot::Done { restarts: 0 });
        let Slot::Running { mut handle, attempt, .. } = slot else {
            return;
        };
        // Reaps the exited local child; a no-op for remote handles.
        handle.kill();
        self.slots[i] = Slot::Done { restarts: attempt };
        if obslog::enabled() {
            obslog::emit(
                &Event::wall("fleet", "shard_complete")
                    .str("run_id", &self.opts.run_id)
                    .str("shard", &self.shards[i].to_string())
                    .u64("restarts", attempt as u64),
            );
        }
        println!(
            "fleet: shard {} complete{}",
            self.shards[i],
            if attempt > 0 {
                format!(" (after {attempt} restart(s))")
            } else {
                String::new()
            }
        );
    }

    /// Kill shard `i`'s worker and relaunch it — or fail the whole run
    /// once the shard's restart budget is spent.
    fn restart(&mut self, i: usize, reason: &str) -> anyhow::Result<()> {
        let shard = self.shards[i];
        let slot = std::mem::replace(&mut self.slots[i], Slot::Done { restarts: 0 });
        let Slot::Running { mut handle, attempt, .. } = slot else {
            unreachable!("restart is only reached from a running slot");
        };
        handle.kill();
        anyhow::ensure!(
            attempt < self.opts.max_restarts,
            "shard {shard} ({}): {reason}, restart budget exhausted ({} restart(s))",
            handle.describe(),
            self.opts.max_restarts
        );
        if obslog::enabled() {
            obslog::emit(
                &Event::wall("fleet", "shard_restart")
                    .level(Level::Warn)
                    .str("run_id", &self.opts.run_id)
                    .str("shard", &shard.to_string())
                    .str("reason", reason)
                    .u64("attempt", (attempt + 1) as u64),
            );
        }
        println!(
            "fleet: shard {shard} ({}) {reason}; relaunching (restart {}/{})",
            handle.describe(),
            attempt + 1,
            self.opts.max_restarts
        );
        let task = self.task(shard, attempt + 1);
        self.slots[i] = Slot::Running {
            handle: self.launcher.launch(&task)?,
            attempt: attempt + 1,
            last_seq: None,
            last_advance: Instant::now(),
        };
        Ok(())
    }

    fn kill_all(&mut self) {
        for slot in &mut self.slots {
            if let Slot::Running { handle, .. } = slot {
                handle.kill();
            }
        }
    }
}

/// Run a whole campaign automatically: plan `opts.workers` shards,
/// launch a worker per shard through `launcher`, restart dead or
/// stalled workers (up to `opts.max_restarts` each), and auto-merge
/// when the last shard completes. The merged [`SweepResults`] are
/// bit-identical to [`campaign::run_single`] — crash recovery included,
/// because workers resume from their streamed output and merge
/// deduplicates deterministically.
///
/// On any failure (restart budget exhausted, cancel marker, launcher
/// error) every still-running worker is killed before the error
/// returns; completed points stay on disk, so a later run resumes
/// instead of re-simulating.
pub fn run(
    spec: &CampaignSpec,
    spec_path: &Path,
    launcher: &dyn Launcher,
    opts: &FleetOptions,
) -> anyhow::Result<FleetReport> {
    anyhow::ensure!(opts.workers > 0, "a fleet needs at least one worker");
    let lease_dir = opts.lease_dir();
    std::fs::create_dir_all(&lease_dir)
        .map_err(|e| anyhow::anyhow!("create lease dir {}: {e}", lease_dir.display()))?;
    let cancel = cancel_path(&lease_dir);
    // Starting a new run is fresh consent: clear a leftover marker.
    let _ = std::fs::remove_file(&cancel);
    // The run's root span: every worker's shard span (and, through the
    // serve path, every request span) parents back to this context.
    let root = TraceContext::root(&opts.run_id);
    if obslog::enabled() {
        obslog::emit(
            &span::wall_span("fleet_run", root, None)
                .str("run_id", &opts.run_id)
                .u64("workers", opts.workers as u64),
        );
    }
    let shards: Vec<Shard> = (0..opts.workers)
        .map(|i| Shard::new(i, opts.workers))
        .collect::<anyhow::Result<_>>()?;
    let mut sched = Scheduler {
        spec,
        spec_path,
        launcher,
        opts,
        fp: store::fingerprint(&spec.config),
        total: spec.expand().len(),
        lease_dir,
        cancel,
        shards,
        slots: Vec::new(),
    };
    let driven = sched.drive();
    if driven.is_err() {
        sched.kill_all();
    }
    driven?;

    // One pass serves both the merge and the summary tallies — the
    // shard files are trace-heavy, re-reading them would double the
    // end-of-run cost.
    let merged = campaign::merge_report(spec, opts.workers, &opts.out_dir)?;
    if obslog::enabled() {
        obslog::emit(
            &Event::wall("fleet", "merge")
                .str("run_id", &opts.run_id)
                .u64("points", merged.results.len() as u64)
                .u64("sims", merged.sims as u64)
                .u64("hits", merged.hits as u64),
        );
    }
    let shards = sched
        .shards
        .iter()
        .zip(&sched.slots)
        .map(|(&shard, slot)| ShardOutcome {
            shard,
            restarts: match slot {
                Slot::Done { restarts } => *restarts,
                Slot::Running { .. } => 0,
            },
        })
        .collect();
    Ok(FleetReport {
        run_id: opts.run_id.clone(),
        shards,
        merged: opts.out_dir.join(stream::merged_file_name(&spec.name)),
        results: merged.results,
        sims: merged.sims,
        hits: merged.hits,
    })
}

/// One shard's lease as seen right now.
#[derive(Debug, Clone)]
pub struct ShardLease {
    pub lease: Option<Lease>,
    /// Wall-clock age of the lease file (mtime-based — a display hint,
    /// not the scheduler's staleness source).
    pub age: Option<Duration>,
}

impl ShardLease {
    /// A running lease older than its own TTL. Done leases never go
    /// stale.
    pub fn is_stale(&self) -> bool {
        match (&self.lease, self.age) {
            (Some(l), Some(age)) => l.state == LeaseState::Running && age.as_secs() > l.ttl_secs,
            _ => false,
        }
    }
}

/// Per-shard progress plus lease/staleness view — the one renderer
/// behind both `occamy campaign status` and `occamy fleet status`.
#[derive(Debug, Clone)]
pub struct StatusView {
    pub run_id: String,
    pub campaign: CampaignStatus,
    /// Parallel to `campaign.shards`.
    pub leases: Vec<ShardLease>,
    /// Traces persisted in the shared store for this config, when a
    /// store root was given and exists.
    pub traces_on_disk: Option<usize>,
    /// A cancel marker is present in the lease directory.
    pub cancel_requested: bool,
}

impl StatusView {
    pub fn is_complete(&self) -> bool {
        self.campaign.is_complete()
    }

    pub fn stale_shards(&self) -> usize {
        self.leases.iter().filter(|l| l.is_stale()).count()
    }

    /// Register the fleet's progress as gauges — `occamy fleet status
    /// --metrics` renders them so a long campaign can be scraped from
    /// cron instead of parsed out of the text view.
    pub fn register_metrics(&self, r: &mut Registry) {
        r.gauge(
            "occamy_fleet_points_total",
            "Points in the campaign grid",
            &[],
            self.campaign.total_points as f64,
        );
        r.gauge(
            "occamy_fleet_points_done",
            "Points present in the shard output files",
            &[],
            self.campaign.done() as f64,
        );
        let (mut done, mut alive, mut stale, mut unleased) = (0u64, 0u64, 0u64, 0u64);
        for sl in &self.leases {
            match &sl.lease {
                None => unleased += 1,
                Some(l) if l.run_id != self.run_id => unleased += 1,
                Some(l) if l.state == LeaseState::Done => done += 1,
                Some(_) if sl.is_stale() => stale += 1,
                Some(_) => alive += 1,
            }
        }
        let help = "Shards by lease state";
        r.gauge("occamy_fleet_shards", help, &[("state", "done")], done as f64);
        r.gauge("occamy_fleet_shards", help, &[("state", "alive")], alive as f64);
        r.gauge("occamy_fleet_shards", help, &[("state", "stale")], stale as f64);
        r.gauge("occamy_fleet_shards", help, &[("state", "unleased")], unleased as f64);
        r.gauge(
            "occamy_fleet_cancel_requested",
            "1 when a cancel marker is present in the lease directory",
            &[],
            if self.cancel_requested { 1.0 } else { 0.0 },
        );
        if let Some(n) = self.traces_on_disk {
            r.gauge(
                "occamy_fleet_store_traces",
                "Traces persisted in the shared store for this config",
                &[],
                n as f64,
            );
        }
    }
}

impl std::fmt::Display for StatusView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "{} of {} points complete{}",
            self.campaign.done(),
            self.campaign.total_points,
            if self.is_complete() { " — ready to merge" } else { "" }
        )?;
        for (s, sl) in self.campaign.shards.iter().zip(&self.leases) {
            write!(f, "  {}", s.summary())?;
            match &sl.lease {
                None => {}
                Some(l) if l.run_id != self.run_id => {
                    write!(f, " [lease: foreign run {:?}]", l.run_id)?;
                }
                Some(l) => match l.state {
                    LeaseState::Done => write!(f, " [lease: done, attempt {}]", l.attempt)?,
                    LeaseState::Running if sl.is_stale() => write!(
                        f,
                        " [lease: STALE — last heartbeat {}s ago, ttl {}s, attempt {}]",
                        sl.age.map(|a| a.as_secs()).unwrap_or(0),
                        l.ttl_secs,
                        l.attempt
                    )?,
                    LeaseState::Running => write!(f, " [lease: alive, attempt {}]", l.attempt)?,
                },
            }
            writeln!(f)?;
        }
        if let Some(n) = self.traces_on_disk {
            writeln!(f, "  store: {n} trace(s) on disk")?;
        }
        if self.cancel_requested {
            writeln!(f, "  cancel requested — a running scheduler stops at its next poll")?;
        }
        Ok(())
    }
}

/// Assemble the shared status view: campaign progress (per-shard
/// done/sims/hits from the streamed JSONL) plus each shard's lease.
pub fn status(
    spec: &CampaignSpec,
    workers: usize,
    out_dir: &Path,
    store_root: Option<&Path>,
    run_id: &str,
) -> anyhow::Result<StatusView> {
    let campaign_status = campaign::status(spec, workers, out_dir)?;
    let dir = lease_dir_of(out_dir, store_root, run_id);
    let leases = campaign_status
        .shards
        .iter()
        .map(|s| {
            let path = dir.join(lease::file_name(s.shard));
            ShardLease {
                lease: lease::read(&path),
                age: lease::age(&path),
            }
        })
        .collect();
    let traces_on_disk = store_root
        .filter(|root| root.exists())
        .map(|root| store::traces_in(root, &store::fingerprint(&spec.config)));
    Ok(StatusView {
        run_id: run_id.to_string(),
        campaign: campaign_status,
        leases,
        traces_on_disk,
        cancel_requested: cancel_path(&dir).exists(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn temp_out(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("occamy-fleet-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn spec(name: &str, gap: u64) -> CampaignSpec {
        CampaignSpec::parse(&format!(
            "[campaign]\nname = \"{name}\"\n[grid]\nkernels = [\"axpy:96\", \"atax:16\"]\nclusters = [1, 4]\n\
             routines = [\"baseline\", \"ideal\"]\n[timing]\nhost_ipi_issue_gap = {gap}\n"
        ))
        .unwrap()
    }

    fn opts(spec: &CampaignSpec, out: PathBuf) -> FleetOptions {
        let mut o = FleetOptions::new(spec, out);
        o.poll = Duration::from_millis(10);
        o.store = None; // cache-only: keep unit tests off the disk store
        o
    }

    /// Runs shards in-process (via `campaign::run_shard`) instead of
    /// spawning subprocesses; optionally fails a shard's first attempt.
    struct InProcess {
        spec: CampaignSpec,
        fail_first_attempt_of: Option<usize>,
        launches: Arc<AtomicUsize>,
    }

    struct InProcessWorker {
        spec: CampaignSpec,
        shard: Shard,
        out: PathBuf,
        fail: bool,
        ran: bool,
    }

    impl WorkerHandle for InProcessWorker {
        fn poll(&mut self) -> anyhow::Result<WorkerState> {
            if self.fail {
                return Ok(WorkerState::Exited { success: false });
            }
            if !self.ran {
                campaign::run_shard(&self.spec, self.shard, &self.out, None)?;
                self.ran = true;
            }
            Ok(WorkerState::Exited { success: true })
        }

        fn kill(&mut self) {}

        fn describe(&self) -> String {
            "in-process".into()
        }
    }

    impl Launcher for InProcess {
        fn launch(&self, task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
            // ordering: Relaxed — test-only launch tally.
            self.launches.fetch_add(1, Ordering::Relaxed);
            Ok(Box::new(InProcessWorker {
                spec: self.spec.clone(),
                shard: task.shard,
                out: task.out_dir.clone(),
                fail: self.fail_first_attempt_of == Some(task.shard.index) && task.attempt == 0,
                ran: false,
            }))
        }
    }

    /// A worker that never exits and never heartbeats.
    struct NeverExits {
        launches: Arc<AtomicUsize>,
    }

    struct Immortal;

    impl WorkerHandle for Immortal {
        fn poll(&mut self) -> anyhow::Result<WorkerState> {
            Ok(WorkerState::Running)
        }
        fn kill(&mut self) {}
        fn describe(&self) -> String {
            "immortal".into()
        }
    }

    impl Launcher for NeverExits {
        fn launch(&self, _task: &WorkerTask) -> anyhow::Result<Box<dyn WorkerHandle>> {
            // ordering: Relaxed — test-only launch tally.
            self.launches.fetch_add(1, Ordering::Relaxed);
            Ok(Box::new(Immortal))
        }
    }

    #[test]
    fn fleet_completes_and_merges_bit_identically_despite_a_failed_attempt() {
        let spec = spec("fleet-unit-restart", 7001);
        let out = temp_out("restart");
        let mut o = opts(&spec, out);
        o.workers = 2;
        o.max_restarts = 1;
        let launcher = InProcess {
            spec: spec.clone(),
            fail_first_attempt_of: Some(1),
            launches: Arc::new(AtomicUsize::new(0)),
        };
        let report = run(&spec, Path::new("unused.toml"), &launcher, &o).unwrap();
        assert_eq!(report.results, campaign::run_single(&spec));
        assert_eq!(report.shards.len(), 2);
        assert_eq!(report.shards[0].restarts, 0);
        assert_eq!(report.shards[1].restarts, 1, "the failed attempt was relaunched");
        assert_eq!(report.restarts(), 1);
        // ordering: Relaxed — test-only tally; the run has joined.
        assert_eq!(launcher.launches.load(Ordering::Relaxed), 3);
        assert!(report.merged.exists());
        // Cache-only run: every line is labelled, nothing read from disk.
        assert_eq!(report.sims + report.hits, report.results.len());
        // The shared renderer sees completion (no store, no leases —
        // the in-process workers never wrote any).
        let view = status(&spec, 2, &o.out_dir, None, &o.run_id).unwrap();
        assert!(view.is_complete());
        assert_eq!(view.stale_shards(), 0);
        assert!(view.to_string().contains("ready to merge"));
        // The same view registers as Prometheus gauges.
        let mut reg = Registry::new();
        view.register_metrics(&mut reg);
        let text = reg.render();
        let total = view.campaign.total_points as f64;
        assert!(text.contains(&format!("occamy_fleet_points_total {}", total)));
        assert!(text.contains(&format!("occamy_fleet_points_done {}", total)));
        // In-process workers never wrote leases, so every shard is unleased.
        assert!(text.contains("occamy_fleet_shards{state=\"unleased\"} 2"));
        assert!(text.contains("occamy_fleet_shards{state=\"alive\"} 0"));
        assert!(text.contains("occamy_fleet_cancel_requested 0"));
        assert!(!text.contains("occamy_fleet_store_traces"), "no store was attached");
    }

    #[test]
    fn more_workers_than_points_still_merges() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"fleet-unit-tiny\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [2]\n\
             routines = [\"ideal\"]\n[timing]\nhost_ipi_issue_gap = 7002\n",
        )
        .unwrap();
        assert_eq!(spec.expand().len(), 1);
        let out = temp_out("tiny");
        let mut o = opts(&spec, out);
        o.workers = 3;
        let launcher = InProcess {
            spec: spec.clone(),
            fail_first_attempt_of: None,
            launches: Arc::new(AtomicUsize::new(0)),
        };
        let report = run(&spec, Path::new("unused.toml"), &launcher, &o).unwrap();
        assert_eq!(report.results, campaign::run_single(&spec));
        assert_eq!(report.restarts(), 0);
    }

    #[test]
    fn a_shard_that_keeps_failing_exhausts_its_restart_budget() {
        let spec = spec("fleet-unit-budget", 7003);
        let out = temp_out("budget");
        let mut o = opts(&spec, out);
        o.workers = 2;
        o.max_restarts = 0;
        let launcher = InProcess {
            spec: spec.clone(),
            fail_first_attempt_of: Some(0),
            launches: Arc::new(AtomicUsize::new(0)),
        };
        let err = run(&spec, Path::new("unused.toml"), &launcher, &o).unwrap_err().to_string();
        assert!(err.contains("restart budget exhausted"), "{err}");
        assert!(err.contains("shard 0/2"), "{err}");
    }

    #[test]
    fn a_silent_worker_goes_stale_after_the_ttl() {
        let spec = spec("fleet-unit-stale", 7004);
        let out = temp_out("stale");
        let mut o = opts(&spec, out);
        o.workers = 1;
        o.max_restarts = 0;
        o.lease_ttl = Duration::from_millis(150);
        let launcher = NeverExits {
            launches: Arc::new(AtomicUsize::new(0)),
        };
        let err = run(&spec, Path::new("unused.toml"), &launcher, &o).unwrap_err().to_string();
        assert!(err.contains("no heartbeat"), "{err}");
    }

    #[test]
    fn a_heartbeating_worker_survives_the_ttl_and_cancel_stops_the_run() {
        let spec = spec("fleet-unit-cancel", 7005);
        let out = temp_out("cancel");
        let mut o = opts(&spec, out);
        o.workers = 1;
        o.max_restarts = 0;
        o.lease_ttl = Duration::from_millis(900);
        let launches = Arc::new(AtomicUsize::new(0));
        let launcher = NeverExits {
            launches: Arc::clone(&launches),
        };
        // Heartbeat the worker's lease ourselves (ttl_secs 1 => 250 ms
        // period, well under the scheduler's staleness window — 900 ms
        // rounds up to 1 s).
        let lease_path = o.lease_dir().join(lease::file_name(Shard::SINGLE));
        let lease = Lease::new(o.run_id.clone(), Shard::SINGLE, 0, 1);
        let hb = Heartbeat::start(lease_path, lease).unwrap();
        let err = std::thread::scope(|s| {
            let worker = s.spawn(|| run(&spec, Path::new("unused.toml"), &launcher, &o));
            // Wait until the scheduler is live (it has launched), then
            // outlast several TTLs to prove heartbeats keep it alive.
            let deadline = Instant::now() + Duration::from_secs(30);
            // ordering: Relaxed — test-only poll of the launch tally.
            while launches.load(Ordering::Relaxed) == 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            // ordering: Relaxed — same test-only tally.
            assert!(launches.load(Ordering::Relaxed) >= 1, "scheduler never launched");
            std::thread::sleep(Duration::from_millis(2500));
            std::fs::write(cancel_path(&o.lease_dir()), "cancel\n").unwrap();
            worker.join().unwrap().unwrap_err().to_string()
        });
        drop(hb);
        assert!(err.contains("cancelled"), "stale instead of cancelled? {err}");
    }
}
