//! Deterministic PRNG for workload generation (no external deps).
//!
//! SplitMix64 (Steele et al., "Fast splittable pseudorandom number
//! generators", OOPSLA 2014): tiny, full-period over 2^64 seeds, and —
//! crucial for reproducible experiments — identical across platforms and
//! toolchain versions.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi > lo);
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform usize in [lo, hi) (modulo bias negligible for our ranges).
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(42);
        let mut b = Rng64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(43);
        assert_ne!(Rng64::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn known_vector() {
        // Reference values of SplitMix64 with seed 1234567 (checked
        // against the published C implementation).
        let mut r = Rng64::seed_from_u64(1234567);
        let v = r.next_u64();
        let mut r2 = Rng64::seed_from_u64(1234567);
        assert_eq!(v, r2.next_u64());
        assert_ne!(v, r.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng64::seed_from_u64(9);
        for _ in 0..10_000 {
            let x = r.gen_range_f64(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
            let i = r.gen_range_usize(5, 10);
            assert!((5..10).contains(&i));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng64::seed_from_u64(11);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[(r.next_f64() * 10.0) as usize] += 1;
        }
        for b in buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }
}
