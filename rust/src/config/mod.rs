//! SoC geometry and timing configuration.
//!
//! Defaults reproduce the Occamy configuration of the paper (§3.1): one CVA6
//! host, 8 quadrants x 4 clusters x (8 compute cores + 1 DMA core) = 288
//! accelerator cores, 128 KiB TCDM per cluster, a 64-bit narrow and a
//! 512-bit wide NoC, each a two-level crossbar tree. Timing constants are
//! calibrated to the paper's cycle-accurate RTL measurements (§5.5); every
//! constant cites its source. All values are overridable from TOML so the
//! experiment harness can run ablations.


mod timing;
pub use timing::TimingConfig;

/// Geometry of the simulated SoC.
#[derive(Debug, Clone, PartialEq)]
pub struct SocConfig {
    /// Number of quadrants (paper: 8).
    pub n_quadrants: usize,
    /// Clusters per quadrant (paper: 4).
    pub clusters_per_quadrant: usize,
    /// Compute cores per cluster, excluding the DMA core (paper: 8).
    pub compute_cores_per_cluster: usize,
    /// TCDM bytes per cluster (paper: 128 KiB).
    pub tcdm_bytes: u64,
    /// Per-cluster address-space stride (paper §4.2: 0x40000).
    pub cluster_stride: u64,
    /// Base address of cluster 0's TCDM.
    pub cluster_base: u64,
    /// Wide SPM size in bytes (paper: 1 MiB).
    pub wide_spm_bytes: u64,
    /// Narrow SPM size in bytes (paper: 512 KiB).
    pub narrow_spm_bytes: u64,
    /// Wide network bus width in bytes (paper: 512 bit = 64 B).
    pub wide_bus_bytes: u64,
    /// Narrow network bus width in bytes (paper: 64 bit = 8 B).
    pub narrow_bus_bytes: u64,
    /// Wide-SPM port arbitration: false = transfer-granular round-robin
    /// (the Occamy interconnect, default), true = fluid processor sharing
    /// (ablation; see `sim::server`).
    pub wide_port_fluid: bool,
}

impl Default for SocConfig {
    fn default() -> Self {
        Self {
            n_quadrants: 8,
            clusters_per_quadrant: 4,
            compute_cores_per_cluster: 8,
            tcdm_bytes: 128 * 1024,
            cluster_stride: 0x40000,
            // Matches the encoding example of Fig. 5: bits [0,17] offset,
            // [18,19] cluster, [20,22] quadrant.
            cluster_base: 0x0,
            wide_spm_bytes: 1024 * 1024,
            narrow_spm_bytes: 512 * 1024,
            wide_bus_bytes: 64,
            narrow_bus_bytes: 8,
            wide_port_fluid: false,
        }
    }
}

impl SocConfig {
    /// Total number of clusters in the accelerator.
    pub fn n_clusters(&self) -> usize {
        self.n_quadrants * self.clusters_per_quadrant
    }

    /// Total accelerator cores (compute + DMA).
    pub fn n_accel_cores(&self) -> usize {
        self.n_clusters() * (self.compute_cores_per_cluster + 1)
    }

    /// Quadrant index of a cluster.
    pub fn quadrant_of(&self, cluster: usize) -> usize {
        cluster / self.clusters_per_quadrant
    }

    /// Base address of a cluster's TCDM.
    pub fn cluster_addr(&self, cluster: usize) -> u64 {
        self.cluster_base + cluster as u64 * self.cluster_stride
    }
}

/// Complete simulator configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub soc: SocConfig,
    pub timing: TimingConfig,
}

impl Config {
    /// Parse from the flat-TOML subset emitted by [`Config::to_toml`]:
    /// `[soc]` / `[timing]` sections of `key = integer` lines, `#`
    /// comments. Unknown keys are errors (typos must not silently fall
    /// back to defaults); missing keys keep their default value.
    pub fn from_toml(text: &str) -> anyhow::Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section != "soc" && section != "timing" {
                    anyhow::bail!("line {}: unknown section [{section}]", lineno + 1);
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let value = value.trim();
            let v: u64 = if let Some(hex) = value.strip_prefix("0x") {
                u64::from_str_radix(hex, 16)
            } else {
                value.parse()
            }
            .map_err(|e| anyhow::anyhow!("line {}: bad integer {value:?}: {e}", lineno + 1))?;
            match section.as_str() {
                "soc" => cfg.soc.set_field(key, v)?,
                "timing" => cfg.timing.set_field(key, v)?,
                _ => anyhow::bail!("line {}: key outside a section", lineno + 1),
            }
        }
        Ok(cfg)
    }

    /// Serialize to the same flat-TOML subset (complete: every field is
    /// written, so experiment configs are fully reproducible).
    pub fn to_toml(&self) -> String {
        let mut out = String::from("[soc]\n");
        for (k, v) in self.soc.fields() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out.push_str("\n[timing]\n");
        for (k, v) in self.timing.fields() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out
    }

    /// Load from a file path.
    pub fn from_path(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }
}

impl SocConfig {
    /// (name, value) pairs of every field, in declaration order.
    pub fn fields(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("n_quadrants", self.n_quadrants as u64),
            ("clusters_per_quadrant", self.clusters_per_quadrant as u64),
            (
                "compute_cores_per_cluster",
                self.compute_cores_per_cluster as u64,
            ),
            ("tcdm_bytes", self.tcdm_bytes),
            ("cluster_stride", self.cluster_stride),
            ("cluster_base", self.cluster_base),
            ("wide_spm_bytes", self.wide_spm_bytes),
            ("narrow_spm_bytes", self.narrow_spm_bytes),
            ("wide_bus_bytes", self.wide_bus_bytes),
            ("narrow_bus_bytes", self.narrow_bus_bytes),
            ("wide_port_fluid", self.wide_port_fluid as u64),
        ]
    }

    /// Set a field by name (config parsing).
    pub fn set_field(&mut self, key: &str, v: u64) -> anyhow::Result<()> {
        match key {
            "n_quadrants" => self.n_quadrants = v as usize,
            "clusters_per_quadrant" => self.clusters_per_quadrant = v as usize,
            "compute_cores_per_cluster" => self.compute_cores_per_cluster = v as usize,
            "tcdm_bytes" => self.tcdm_bytes = v,
            "cluster_stride" => self.cluster_stride = v,
            "cluster_base" => self.cluster_base = v,
            "wide_spm_bytes" => self.wide_spm_bytes = v,
            "narrow_spm_bytes" => self.narrow_spm_bytes = v,
            "wide_bus_bytes" => self.wide_bus_bytes = v,
            "narrow_bus_bytes" => self.narrow_bus_bytes = v,
            "wide_port_fluid" => self.wide_port_fluid = v != 0,
            _ => anyhow::bail!("unknown [soc] key {key:?}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_geometry_matches_paper() {
        let c = SocConfig::default();
        assert_eq!(c.n_clusters(), 32);
        // 32 clusters x 9 cores = 288 Snitch cores (paper §3.1).
        assert_eq!(c.n_accel_cores(), 288);
    }

    #[test]
    fn quadrant_mapping() {
        let c = SocConfig::default();
        assert_eq!(c.quadrant_of(0), 0);
        assert_eq!(c.quadrant_of(3), 0);
        assert_eq!(c.quadrant_of(4), 1);
        assert_eq!(c.quadrant_of(31), 7);
    }

    #[test]
    fn cluster_addresses_are_stride_apart() {
        let c = SocConfig::default();
        assert_eq!(c.cluster_addr(0), 0x0);
        assert_eq!(c.cluster_addr(1), 0x40000);
        assert_eq!(c.cluster_addr(9), 9 * 0x40000);
    }

    #[test]
    fn toml_roundtrip() {
        let c = Config::default();
        let txt = c.to_toml();
        let back = Config::from_toml(&txt).unwrap();
        assert_eq!(c, back);
    }
}
