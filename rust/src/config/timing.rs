//! Timing constants of the cycle-level model.
//!
//! Every constant is either taken verbatim from the paper's RTL
//! measurements (§5.5, cited per field) or calibrated so that the composed
//! phase timings reproduce the paper's published aggregates (242±65-cycle
//! single-cluster overhead, 47-cycle multicast wakeup of which 39 in
//! hardware, 185±18-cycle residual overhead with extensions, Eq. 5's
//! 400-cycle constant). All times are in cycles of the 1 GHz system clock,
//! so 1 cycle == 1 ns (§5.1).


#[derive(Debug, Clone, PartialEq)]
pub struct TimingConfig {
    // ----------------------------------------------------------- narrow NoC
    /// Cycles for a request to traverse CVA6 LSU -> top-level narrow XBAR.
    pub narrow_host_to_top: u64,
    /// Top-level narrow XBAR -> quadrant XBAR.
    pub narrow_top_to_quad: u64,
    /// Quadrant XBAR -> cluster input port.
    pub narrow_quad_to_cluster: u64,
    /// Cluster input port -> TCDM/MCIP register (local decode).
    pub narrow_cluster_ingress: u64,
    /// Top-level narrow XBAR -> peripherals (CLINT) port.
    pub narrow_top_to_periph: u64,
    /// TCDM service time for one narrow access (bank arbitration + SRAM).
    pub tcdm_service: u64,
    /// Local (same-cluster) load latency, issue to use.
    pub tcdm_local_load: u64,

    // ------------------------------------------------------------- wide NoC
    /// Lumped DMA round-trip latency: AR to SPM, first R beat back, AW+W
    /// forward to TCDM, B response (paper §5.5.E: 55 cycles).
    pub dma_roundtrip: u64,
    /// Cycles of DM-core instructions to program one DMA transfer
    /// (paper §5.5.G: 21 cycles for the single writeback transfer;
    /// §5.5.E measures 53 for the two operand transfers of AXPY).
    pub dma_setup_per_transfer: u64,
    /// Extra setup cycles for the first transfer of a phase (loop entry,
    /// argument unpacking). 53 = 2*21 + 11 for AXPY's phase E.
    pub dma_setup_phase_entry: u64,

    // ------------------------------------------------------------ host CVA6
    /// Phase A: CVA6 writes job pointer + arguments (baseline, to cluster 0).
    /// Calibrated: includes LSU issue of ptr + args stores.
    pub host_send_info: u64,
    /// Extra cycles in phase A for the multicast build: enable + disable
    /// the multicast mask CSR ("only introduces two additional
    /// instructions", §5.5.A).
    pub host_mcast_csr: u64,
    /// Per-target cycles of the baseline IPI loop on CVA6 (address
    /// generation + store; limited outstanding writes on CVA6's LSU,
    /// §4.2). Calibrated against Fig. 7's 32-cluster overheads.
    pub host_ipi_issue_gap: u64,
    /// Cycles from CLINT MSIP set to CVA6 resuming after WFI (interrupt
    /// propagation + pipeline restart).
    pub host_wake: u64,
    /// Phase I: CVA6 clears the interrupt and returns to the workload.
    pub host_resume: u64,

    // ----------------------------------------------------- cluster / Snitch
    /// Cycles from MCIP write arriving at the cluster to the Snitch cores
    /// leaving WFI and reaching the dispatch loop (paper §5.5.B: of the 47
    /// multicast wakeup cycles, 39 arise in hardware; the remaining 8 are
    /// the CVA6-side store issue).
    pub cluster_wake: u64,
    /// Cycles for a core to clear its own MCIP bit (local register).
    pub mcip_clear: u64,
    /// Instruction cycles in the dispatch loop to load the job pointer
    /// (address setup + load issue).
    pub dispatch_load_ptr: u64,
    /// Hardware cluster barrier latency (DM core <-> compute cores).
    pub cluster_barrier: u64,
    /// AMO (atomic increment) service time at a TCDM bank.
    pub amo_service: u64,
    /// Instruction cycles for one software-barrier participant
    /// (address setup + AMO issue + branch).
    pub barrier_instr: u64,
    /// Instruction cycles for the last barrier participant to send the IPI
    /// to CVA6 (check + store to CLINT MSIP).
    pub barrier_notify_instr: u64,
    /// Instruction cycles for a cluster to write the JCU arrivals register.
    pub jcu_notify_instr: u64,
    /// JCU internal latency from last arrival to MSIP set (Fig. 6 logic).
    pub jcu_fire: u64,

    // -------------------------------------------------------------- kernels
    /// Phase-F init: configure + initialize the computation
    /// (paper §5.5.F: 55 cycles for AXPY).
    pub compute_init: u64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self {
            // Narrow NoC hop latencies: calibrated so the one-way
            // CVA6->cluster latency is 13 cycles and, with cluster_wake,
            // the multicast wakeup totals 47 cycles (39 in hardware),
            // matching §5.5.B.
            narrow_host_to_top: 4,
            narrow_top_to_quad: 4,
            narrow_quad_to_cluster: 3,
            narrow_cluster_ingress: 2,
            narrow_top_to_periph: 3,
            tcdm_service: 2,
            tcdm_local_load: 3,

            dma_roundtrip: 55,          // §5.5.E
            dma_setup_per_transfer: 21, // §5.5.G
            dma_setup_phase_entry: 11,  // 53 = 2*21 + 11 for AXPY phase E (§5.5.E)

            host_send_info: 45,
            host_mcast_csr: 2, // §5.5.A: "two additional instructions"
            host_ipi_issue_gap: 30,
            host_wake: 30,
            host_resume: 45,

            cluster_wake: 26, // 13 (one-way, incl. ingress) + 26 = 39 HW cycles (§5.5.B)
            mcip_clear: 2,
            dispatch_load_ptr: 4,
            cluster_barrier: 6,
            amo_service: 2,
            barrier_instr: 8,
            barrier_notify_instr: 6,
            jcu_notify_instr: 4,
            jcu_fire: 2,

            compute_init: 55, // §5.5.F
        }
    }
}

impl TimingConfig {
    /// (name, value) pairs of every field, in declaration order.
    pub fn fields(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("narrow_host_to_top", self.narrow_host_to_top),
            ("narrow_top_to_quad", self.narrow_top_to_quad),
            ("narrow_quad_to_cluster", self.narrow_quad_to_cluster),
            ("narrow_cluster_ingress", self.narrow_cluster_ingress),
            ("narrow_top_to_periph", self.narrow_top_to_periph),
            ("tcdm_service", self.tcdm_service),
            ("tcdm_local_load", self.tcdm_local_load),
            ("dma_roundtrip", self.dma_roundtrip),
            ("dma_setup_per_transfer", self.dma_setup_per_transfer),
            ("dma_setup_phase_entry", self.dma_setup_phase_entry),
            ("host_send_info", self.host_send_info),
            ("host_mcast_csr", self.host_mcast_csr),
            ("host_ipi_issue_gap", self.host_ipi_issue_gap),
            ("host_wake", self.host_wake),
            ("host_resume", self.host_resume),
            ("cluster_wake", self.cluster_wake),
            ("mcip_clear", self.mcip_clear),
            ("dispatch_load_ptr", self.dispatch_load_ptr),
            ("cluster_barrier", self.cluster_barrier),
            ("amo_service", self.amo_service),
            ("barrier_instr", self.barrier_instr),
            ("barrier_notify_instr", self.barrier_notify_instr),
            ("jcu_notify_instr", self.jcu_notify_instr),
            ("jcu_fire", self.jcu_fire),
            ("compute_init", self.compute_init),
        ]
    }

    /// Set a field by name (config parsing).
    pub fn set_field(&mut self, key: &str, v: u64) -> anyhow::Result<()> {
        match key {
            "narrow_host_to_top" => self.narrow_host_to_top = v,
            "narrow_top_to_quad" => self.narrow_top_to_quad = v,
            "narrow_quad_to_cluster" => self.narrow_quad_to_cluster = v,
            "narrow_cluster_ingress" => self.narrow_cluster_ingress = v,
            "narrow_top_to_periph" => self.narrow_top_to_periph = v,
            "tcdm_service" => self.tcdm_service = v,
            "tcdm_local_load" => self.tcdm_local_load = v,
            "dma_roundtrip" => self.dma_roundtrip = v,
            "dma_setup_per_transfer" => self.dma_setup_per_transfer = v,
            "dma_setup_phase_entry" => self.dma_setup_phase_entry = v,
            "host_send_info" => self.host_send_info = v,
            "host_mcast_csr" => self.host_mcast_csr = v,
            "host_ipi_issue_gap" => self.host_ipi_issue_gap = v,
            "host_wake" => self.host_wake = v,
            "host_resume" => self.host_resume = v,
            "cluster_wake" => self.cluster_wake = v,
            "mcip_clear" => self.mcip_clear = v,
            "dispatch_load_ptr" => self.dispatch_load_ptr = v,
            "cluster_barrier" => self.cluster_barrier = v,
            "amo_service" => self.amo_service = v,
            "barrier_instr" => self.barrier_instr = v,
            "barrier_notify_instr" => self.barrier_notify_instr = v,
            "jcu_notify_instr" => self.jcu_notify_instr = v,
            "jcu_fire" => self.jcu_fire = v,
            "compute_init" => self.compute_init = v,
            _ => anyhow::bail!("unknown [timing] key {key:?}"),
        }
        Ok(())
    }

    /// One-way narrow-network latency from CVA6 to a cluster's registers.
    pub fn host_to_cluster_oneway(&self) -> u64 {
        self.narrow_host_to_top
            + self.narrow_top_to_quad
            + self.narrow_quad_to_cluster
            + self.narrow_cluster_ingress
    }

    /// One-way narrow latency between two clusters (same or cross quadrant).
    pub fn cluster_to_cluster_oneway(&self, same_quadrant: bool) -> u64 {
        if same_quadrant {
            self.narrow_quad_to_cluster * 2 + self.narrow_cluster_ingress
        } else {
            self.narrow_quad_to_cluster * 2
                + self.narrow_top_to_quad * 2
                + self.narrow_cluster_ingress
        }
    }

    /// One-way narrow latency from a cluster to the CLINT peripherals.
    pub fn cluster_to_clint_oneway(&self) -> u64 {
        self.narrow_quad_to_cluster + self.narrow_top_to_quad + self.narrow_top_to_periph
    }

    /// Hardware component of the wakeup: store exits CVA6, propagates to
    /// the cluster, wakes the cores (paper: 39 of the 47 multicast cycles).
    pub fn wakeup_hw(&self) -> u64 {
        self.host_to_cluster_oneway() + self.cluster_wake
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wakeup_hw_matches_paper() {
        // §5.5.B: "Of the 47 cycles payed with multicast, 39 arise in the
        // hardware".
        let t = TimingConfig::default();
        assert_eq!(t.wakeup_hw(), 39);
    }

    #[test]
    fn remote_latency_ordering() {
        // Local < same-quadrant < cross-quadrant < via-CLINT-style paths;
        // §2.3: MCIP access latency "is in any case lower than the latency
        // to go through the centralized CLINT".
        let t = TimingConfig::default();
        assert!(t.tcdm_local_load < t.cluster_to_cluster_oneway(true));
        assert!(t.cluster_to_cluster_oneway(true) < t.cluster_to_cluster_oneway(false));
    }

    #[test]
    fn phase_e_setup_matches_paper() {
        // §5.5.E: "Around 53 cycles are paid in instructions to setup the
        // transfers of the x and y vectors".
        let t = TimingConfig::default();
        assert_eq!(t.dma_setup_phase_entry + 2 * t.dma_setup_per_transfer, 53);
    }
}
