//! Snitch cluster model (§3.1): 8 compute cores + 1 DMA-capable data-mover
//! core, a banked TCDM, the MCIP wakeup register and the hardware cluster
//! barrier. Functional state used by the coordinator; phase *timing* is
//! produced by `offload::executor`.

use crate::interrupt::McipReg;
use crate::mem::Tcdm;

/// Power state of a core (§3.2: cores default to WFI between jobs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    /// Waiting for interrupt (clock-gated pipeline).
    Wfi,
    /// Executing.
    Active,
}

/// Hardware barrier inside a cluster (single-cycle-ish synchronization
/// between the DM core and the compute cores).
#[derive(Debug, Clone, Default)]
pub struct HwBarrier {
    arrived: u32,
    expected: u32,
    generations: u64,
}

impl HwBarrier {
    pub fn reset(&mut self, expected: u32) {
        assert!(expected >= 1);
        self.arrived = 0;
        self.expected = expected;
    }

    /// Returns true for the arrival that releases the barrier.
    pub fn arrive(&mut self) -> bool {
        assert!(self.expected > 0, "barrier used before reset");
        self.arrived += 1;
        assert!(self.arrived <= self.expected, "barrier over-subscribed");
        if self.arrived == self.expected {
            self.arrived = 0;
            self.generations += 1;
            true
        } else {
            false
        }
    }

    pub fn generations(&self) -> u64 {
        self.generations
    }
}

/// One Snitch cluster.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub index: usize,
    pub tcdm: Tcdm,
    pub mcip: McipReg,
    pub barrier: HwBarrier,
    pub cores: Vec<CoreState>,
}

impl Cluster {
    pub fn new(index: usize, n_compute_cores: usize, tcdm_bytes: u64) -> Self {
        let n_cores = n_compute_cores + 1; // + DM core
        Self {
            index,
            tcdm: Tcdm::new(tcdm_bytes, 32),
            mcip: McipReg::new(n_cores),
            barrier: HwBarrier::default(),
            cores: vec![CoreState::Wfi; n_cores],
        }
    }

    pub fn occamy(index: usize) -> Self {
        Self::new(index, 8, 128 * 1024)
    }

    /// Index of the DM core (by convention the last).
    pub fn dm_core(&self) -> usize {
        self.cores.len() - 1
    }

    /// Deliver a wakeup: set all MCIP bits, move cores out of WFI.
    /// Returns how many cores actually woke (rising edges).
    pub fn wake_all(&mut self) -> usize {
        let woken = self.mcip.set_all();
        for &c in &woken {
            self.cores[c] = CoreState::Active;
        }
        woken.len()
    }

    /// A core clears its MCIP bit and goes back to sleep.
    pub fn sleep(&mut self, core: usize) {
        self.mcip.clear(core);
        self.cores[core] = CoreState::Wfi;
    }

    pub fn all_asleep(&self) -> bool {
        self.cores.iter().all(|c| *c == CoreState::Wfi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occamy_cluster_has_nine_cores() {
        let c = Cluster::occamy(0);
        assert_eq!(c.cores.len(), 9);
        assert_eq!(c.dm_core(), 8);
        assert!(c.all_asleep());
    }

    #[test]
    fn wake_sleep_cycle() {
        let mut c = Cluster::occamy(3);
        assert_eq!(c.wake_all(), 9);
        assert!(!c.all_asleep());
        // Second wakeup is not a rising edge.
        assert_eq!(c.wake_all(), 0);
        for core in 0..9 {
            c.sleep(core);
        }
        assert!(c.all_asleep());
        // After clearing, wakeup works again.
        assert_eq!(c.wake_all(), 9);
    }

    #[test]
    fn barrier_releases_on_last() {
        let mut b = HwBarrier::default();
        b.reset(3);
        assert!(!b.arrive());
        assert!(!b.arrive());
        assert!(b.arrive());
        assert_eq!(b.generations(), 1);
        // Auto-rearmed.
        b.reset(2);
        assert!(!b.arrive());
        assert!(b.arrive());
    }

    #[test]
    #[should_panic(expected = "before reset")]
    fn barrier_use_before_reset_panics() {
        let mut b = HwBarrier::default();
        b.arrive();
    }

    #[test]
    fn barrier_auto_rearms_after_release() {
        // The HW barrier self-resets on release (arrive after a release
        // starts the next generation rather than over-subscribing).
        let mut b = HwBarrier::default();
        b.reset(1);
        assert!(b.arrive());
        assert!(b.arrive());
        assert_eq!(b.generations(), 2);
    }
}
