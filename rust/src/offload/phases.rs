//! Offload routine variants and run-level result types.


use crate::sim::Time;

/// Which implementation of the offload process to execute (§4.1/§4.2).
/// `Ord` so requests can key ordered (`BTreeMap`) containers — sim-domain
/// code must never iterate hash order into its output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RoutineKind {
    /// The bare-metal baseline: job info to cluster 0, sequential IPIs,
    /// remote pointer/argument retrieval, central-counter software
    /// barrier (§4.1).
    Baseline,
    /// The co-designed routines: multicast job-info + wakeup writes
    /// (phases C/D collapse to local accesses) and JCU-based completion
    /// notification (§4.2, §4.3).
    Multicast,
    /// Ablation: multicast interconnect only — completion notification
    /// still uses the central-counter software barrier (§4.2 without
    /// §4.3).
    McastOnly,
    /// Ablation: JCU only — job distribution and wakeup remain the
    /// baseline's sequential writes (§4.3 without §4.2).
    JcuOnly,
    /// The paper's "ideal runtime": the application started directly on
    /// the device — phases E/F/G only, all clusters starting at t=0
    /// (§5.2).
    Ideal,
}

impl RoutineKind {
    pub const ALL: [RoutineKind; 5] = [
        RoutineKind::Baseline,
        RoutineKind::Multicast,
        RoutineKind::McastOnly,
        RoutineKind::JcuOnly,
        RoutineKind::Ideal,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoutineKind::Baseline => "baseline",
            RoutineKind::Multicast => "multicast",
            RoutineKind::McastOnly => "mcast-only",
            RoutineKind::JcuOnly => "jcu-only",
            RoutineKind::Ideal => "ideal",
        }
    }

    /// Inverse of [`RoutineKind::name`] — used by the CLI and the
    /// campaign spec/stream codecs.
    pub fn parse(name: &str) -> Option<RoutineKind> {
        RoutineKind::ALL.iter().copied().find(|r| r.name() == name)
    }

    /// True for routines that include the host-side phases (A, B, ..., I).
    pub fn is_offloaded(&self) -> bool {
        !matches!(self, RoutineKind::Ideal)
    }

    /// Whether job-info distribution and wakeup use the multicast
    /// interconnect (§4.2).
    pub fn uses_multicast(&self) -> bool {
        matches!(self, RoutineKind::Multicast | RoutineKind::McastOnly)
    }

    /// Whether completion notification uses the job completion unit
    /// (§4.3) instead of the software barrier.
    pub fn uses_jcu(&self) -> bool {
        matches!(self, RoutineKind::Multicast | RoutineKind::JcuOnly)
    }
}

/// Base/ideal/improved runtimes of one (job, n_clusters) configuration —
/// the triple behind Figs. 7-10.
#[derive(Debug, Clone)]
pub struct RunTriple {
    pub n_clusters: usize,
    pub base: Time,
    pub ideal: Time,
    pub improved: Time,
}

impl RunTriple {
    /// Offload overhead as defined in §5.2: base − ideal.
    pub fn overhead(&self) -> i64 {
        self.base as i64 - self.ideal as i64
    }

    /// Residual overhead with the extensions: improved − ideal.
    pub fn residual_overhead(&self) -> i64 {
        self.improved as i64 - self.ideal as i64
    }

    /// Ideal speedup if overheads vanished (Fig. 8 white bars).
    pub fn ideal_speedup(&self) -> f64 {
        self.base as f64 / self.ideal as f64
    }

    /// Achieved speedup with the extensions (Fig. 8 fill levels).
    pub fn achieved_speedup(&self) -> f64 {
        self.base as f64 / self.improved as f64
    }

    /// Fraction of the ideally attainable speedup restored (§5.4: "we
    /// measure speedups within 70% and 90% of the ideally attainable
    /// speedups"): achieved_speedup / ideal_speedup.
    pub fn restored_fraction(&self) -> f64 {
        self.achieved_speedup() / self.ideal_speedup()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_metrics() {
        let t = RunTriple {
            n_clusters: 8,
            base: 1200,
            ideal: 600,
            improved: 750,
        };
        assert_eq!(t.overhead(), 600);
        assert_eq!(t.residual_overhead(), 150);
        assert!((t.ideal_speedup() - 2.0).abs() < 1e-12);
        assert!((t.achieved_speedup() - 1.6).abs() < 1e-12);
        // restored = 1.6 / 2.0
        assert!((t.restored_fraction() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn routine_names() {
        assert_eq!(RoutineKind::Baseline.name(), "baseline");
        assert!(RoutineKind::Ideal.name() == "ideal");
        assert!(!RoutineKind::Ideal.is_offloaded());
    }

    #[test]
    fn parse_inverts_name() {
        for r in RoutineKind::ALL {
            assert_eq!(RoutineKind::parse(r.name()), Some(r));
        }
        assert_eq!(RoutineKind::parse("warp-drive"), None);
    }
}
