//! Multicast offload routine details (§4.2).
//!
//! With the multicast-capable narrow interconnect, phases A and B become
//! one (set of) masked write(s) reaching all selected clusters
//! simultaneously, and phases C/D collapse into local TCDM accesses. The
//! plan below captures how many masked transactions a given cluster
//! selection costs — 1 for any power-of-two prefix, popcount(n) in
//! general — and verifies against the two-level XBAR decode that the
//! writes reach exactly the intended clusters.

use crate::config::Config;
use crate::noc::{MaskedAddr, NarrowNoc};

/// A validated multicast write plan for one offload.
#[derive(Debug, Clone)]
pub struct McastPlan {
    /// The masked write transactions (one per subcube).
    pub txns: Vec<MaskedAddr>,
    /// Clusters reached (sorted, deduplicated) — always `0..n`.
    pub clusters: Vec<usize>,
}

impl McastPlan {
    /// Build and validate the plan for offloading to the first `n`
    /// clusters, writing at in-cluster offset `offset` (job-info slot or
    /// the MCIP register).
    pub fn first_n(cfg: &Config, noc: &NarrowNoc, n: usize, offset: u64) -> Self {
        let txns = noc.encode_first_n(n, offset);
        let mut clusters = Vec::new();
        for t in &txns {
            clusters.extend(noc.route_clusters(*t).expect("multicast plan decodes"));
        }
        clusters.sort_unstable();
        clusters.dedup();
        assert_eq!(
            clusters,
            (0..n).collect::<Vec<_>>(),
            "multicast plan must reach exactly the first {n} clusters"
        );
        debug_assert!(n <= cfg.soc.n_clusters());
        Self { txns, clusters }
    }

    /// Number of narrow-network transactions this plan costs.
    pub fn n_transactions(&self) -> usize {
        self.txns.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_of_two_prefixes_are_single_transactions() {
        let cfg = Config::default();
        let noc = NarrowNoc::new(&cfg, true);
        for n in [1usize, 2, 4, 8, 16, 32] {
            let p = McastPlan::first_n(&cfg, &noc, n, 0x8);
            assert_eq!(p.n_transactions(), 1, "n={n}");
            assert_eq!(p.clusters.len(), n);
        }
    }

    #[test]
    fn general_prefix_costs_popcount() {
        let cfg = Config::default();
        let noc = NarrowNoc::new(&cfg, true);
        for n in 1..=32usize {
            let p = McastPlan::first_n(&cfg, &noc, n, 0x0);
            assert_eq!(p.n_transactions() as u32, n.count_ones(), "n={n}");
        }
    }
}
