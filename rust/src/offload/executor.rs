//! Event-driven execution of one offloaded job — the cycle-level heart of
//! the reproduction.
//!
//! Implements the nine phases of §4.1 (Fig. 3) on the simulated SoC:
//! host-side phase costs from `TimingConfig`, narrow-NoC hop latencies for
//! IPIs and remote loads, a FIFO server for cluster 0's TCDM port (phases
//! C/D and the software barrier's AMO serialization), and the fluid
//! processor-sharing wide-SPM port shared by every cluster's phase E/G
//! DMA traffic — the resource whose contention produces the paper's
//! second-order effects (§5.2: offload-phase offsets are partially repaid
//! as reduced interconnect stalls; §5.5.G: phase E/G overlap across
//! clusters).

use std::sync::Arc;

use crate::config::Config;
use crate::dma::{dma_timing, DmaTiming, DmaTransfer};
use crate::kernels::JobSpec;
use crate::noc::NarrowNoc;
use crate::sim::{fast, Backend, Phase, PhaseSpan, PsPort, RrPort, SimProfile, Time, Trace};

use super::phases::RoutineKind;

/// Cycles the DM core spends polling/observing a completed DMA.
const DMA_POLL: u64 = 2;
/// Cycles to issue a single uncached store on CVA6 (IPI or JCU program).
const HOST_STORE_ISSUE: u64 = 8;
/// Extra cycles per additional multicast transaction when the cluster set
/// is not a single subcube (popcount(n) masked writes, see
/// `NarrowNoc::encode_first_n`).
const HOST_EXTRA_TXN: u64 = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// Wakeup write arrives at cluster `c` (cores leave WFI afterwards).
    Wake { c: usize },
    /// Job-pointer load response received by cluster `c`.
    PtrDone { c: usize },
    /// Job-arguments retrieval finished on cluster `c`.
    ArgsDone { c: usize },
    /// Cluster `c`'s coalesced operand DMA joins the wide-SPM port.
    OperandJoin { c: usize, beats: u64 },
    /// Periodic check of the fluid PS port (stale generations dropped).
    PortCheck { generation: u64 },
    /// An RR-port grant finished its beats.
    PortDone { id: u64 },
    /// Cluster `c` finished phase F.
    ComputeDone { c: usize },
    /// Cluster `c`'s writeback DMA joins the wide-SPM port.
    WritebackJoin { c: usize, beats: u64 },
    /// Cluster `c`'s barrier AMO arrives at cluster 0's TCDM port.
    BarrierArrive { c: usize },
    /// Cluster `c` observes its AMO response (software barrier) or has
    /// sent its JCU arrival (fire-and-forget).
    NotifyDone { c: usize },
    /// Cluster `c`'s arrival write reaches the JCU.
    JcuArrive { c: usize },
    /// CVA6 wakes up from the completion interrupt.
    HostWake,
}

#[derive(Debug, Clone, Copy)]
struct PortJob {
    cluster: usize,
    writeback: bool,
}

/// Wide-SPM port arbitration (config-selected; RR is the Occamy model).
enum WidePort {
    Rr(RrPort),
    Fluid(PsPort),
}

/// Per-cluster phase bookkeeping.
#[derive(Debug, Clone, Default)]
struct ClusterRun {
    wake_at: Time,
    e_start: Time,
    e_end: Time,
    g_start: Time,
    done: bool,
}

pub struct Executor<'a> {
    cfg: &'a Config,
    spec: &'a JobSpec,
    n_clusters: usize,
    routine: RoutineKind,
    profile: SimProfile,
    q: Backend<Ev>,
    trace: Trace,
    /// Built lazily: only the multicast routine routes masked writes
    /// (perf: baseline/ideal runs skip constructing the 9-XBAR tree).
    noc: Option<NarrowNoc>,
    port: WidePort,
    /// Transfer bookkeeping, indexed by the ports' sequential ids
    /// (perf: replaces a HashMap on the hot path).
    port_jobs: Vec<Option<PortJob>>,
    dma: DmaTiming,
    clusters: Vec<ClusterRun>,
    /// FIFO watermark of cluster 0's TCDM port (phases C/D).
    tcdm0_free: Time,
    /// FIFO watermark of the barrier counter's bank (AMO serialization).
    amo_free: Time,
    barrier_count: usize,
    jcu_count: usize,
    finished_clusters: usize,
    a_end: Time,
}

impl<'a> Executor<'a> {
    pub fn new(
        cfg: &'a Config,
        spec: &'a JobSpec,
        n_clusters: usize,
        routine: RoutineKind,
    ) -> Self {
        Self::with_profile(cfg, spec, n_clusters, routine, SimProfile::Reference)
    }

    /// Like [`Executor::new`] but with an explicit engine profile. The
    /// fast profile is bit-identical to the reference (enforced by
    /// `tests/integration_profiles.rs`); the reference stays the default
    /// everywhere a profile is not explicitly requested.
    pub fn with_profile(
        cfg: &'a Config,
        spec: &'a JobSpec,
        n_clusters: usize,
        routine: RoutineKind,
        profile: SimProfile,
    ) -> Self {
        assert!(n_clusters >= 1 && n_clusters <= cfg.soc.n_clusters());
        let multicast_noc = routine.uses_multicast();
        Self {
            cfg,
            spec,
            n_clusters,
            routine,
            profile,
            q: Backend::new(profile),
            trace: Trace::new(n_clusters),
            noc: multicast_noc.then(|| NarrowNoc::new(cfg, true)),
            port: if cfg.soc.wide_port_fluid {
                WidePort::Fluid(PsPort::new())
            } else {
                WidePort::Rr(RrPort::new(n_clusters))
            },
            port_jobs: Vec::with_capacity(2 * n_clusters),
            dma: dma_timing(&cfg.timing),
            clusters: vec![ClusterRun::default(); n_clusters],
            tcdm0_free: 0,
            amo_free: 0,
            barrier_count: 0,
            jcu_count: 0,
            finished_clusters: 0,
            a_end: 0,
        }
    }

    /// One-way narrow latency from cluster `c` to cluster 0 (or local).
    fn to_cluster0(&self, c: usize) -> u64 {
        let t = &self.cfg.timing;
        if c == 0 {
            // Local TCDM access path.
            0
        } else {
            let same_quad = self.cfg.soc.quadrant_of(c) == self.cfg.soc.quadrant_of(0);
            t.cluster_to_cluster_oneway(same_quad)
        }
    }

    /// Run the job to completion and return the trace. Under the fast
    /// profile, a previously simulated identical (config, job) pair
    /// replays its memoized timeline instead of simulating at all — the
    /// DES is deterministic, so the replay is byte-equal by definition.
    pub fn run(self) -> Trace {
        if self.profile == SimProfile::Fast {
            let key = fast::timeline_key(
                &self.cfg.to_toml(),
                &super::request_key(self.spec, self.n_clusters, self.routine),
            );
            if let Some(t) = fast::timeline_lookup(&key) {
                return (*t).clone();
            }
            let trace = self.run_des();
            return (*fast::timeline_insert(key, Arc::new(trace))).clone();
        }
        self.run_des()
    }

    /// Simulate the timeline event by event (both profiles share this
    /// loop; only the backing queue differs).
    fn run_des(mut self) -> Trace {
        match self.routine {
            RoutineKind::Ideal => self.start_ideal(),
            r => {
                let mcast = r.uses_multicast();
                self.start_offload(mcast)
            }
        }
        while let Some((t, ev)) = self.q.pop() {
            self.handle(t, ev);
        }
        assert_eq!(
            self.finished_clusters, self.n_clusters,
            "simulation drained with unfinished clusters"
        );
        self.q.flush_counters();
        self.trace.events = self.q.dispatched();
        self.trace
    }

    // ------------------------------------------------------------- phase A/B

    fn start_ideal(&mut self) {
        for c in 0..self.n_clusters {
            self.clusters[c].wake_at = 0;
            self.q.schedule(0, Ev::ArgsDone { c }); // jump straight to E
        }
    }

    fn start_offload(&mut self, multicast: bool) {
        let t = &self.cfg.timing;
        // Phase A: send job information.
        let (a_dur, txns) = if multicast {
            // One masked write per subcube of the selected cluster range;
            // validate through the two-level XBAR decode that the writes
            // reach exactly clusters [0, n).
            let noc = self.noc.as_ref().expect("multicast routine builds the NoC");
            let msgs = noc.encode_first_n(self.n_clusters, 0x0);
            let mut reached = Vec::new();
            for m in &msgs {
                reached.extend(noc.route_clusters(*m).expect("multicast decodes"));
            }
            reached.sort_unstable();
            assert_eq!(reached, (0..self.n_clusters).collect::<Vec<_>>());
            (
                t.host_send_info + t.host_mcast_csr + (msgs.len() as u64 - 1) * HOST_EXTRA_TXN,
                msgs.len() as u64,
            )
        } else {
            (t.host_send_info, 1)
        };
        self.a_end = a_dur;
        self.trace.record_host(Phase::SendInfo, PhaseSpan::new(0, a_dur));

        // Phase B: wakeup.
        if multicast {
            let issue = self.a_end + HOST_STORE_ISSUE + (txns - 1) * HOST_EXTRA_TXN;
            let wake = issue + t.wakeup_hw();
            for c in 0..self.n_clusters {
                self.q.schedule(wake, Ev::Wake { c });
            }
        } else {
            // Sequential IPIs, highest cluster index first so cluster 0
            // (holding the barrier counter) arrives last (§5.5.H).
            for (k, c) in (0..self.n_clusters).rev().enumerate() {
                let issue =
                    self.a_end + HOST_STORE_ISSUE + k as u64 * t.host_ipi_issue_gap;
                let wake = issue + t.wakeup_hw();
                self.q.schedule(wake, Ev::Wake { c });
            }
        }
    }

    // ---------------------------------------------------------- event handler

    fn handle(&mut self, now: Time, ev: Ev) {
        // `self.cfg` is an &'a reference: copying the reference out lets
        // the timing constants be read without re-borrowing self (perf:
        // this used to clone the whole TimingConfig per event).
        let t: &'a crate::config::TimingConfig = &self.cfg.timing;
        match ev {
            Ev::Wake { c } => {
                let end = now + t.mcip_clear;
                self.clusters[c].wake_at = end;
                self.trace
                    .record(c, Phase::Wakeup, PhaseSpan::new(self.a_end, end));
                // Phase C: retrieve job pointer.
                match self.routine.uses_multicast() {
                    true => {
                        // Job info was multicast into the local TCDM.
                        let done = end + t.dispatch_load_ptr + t.tcdm_local_load;
                        self.q.schedule(done, Ev::PtrDone { c });
                    }
                    false => {
                        if c == 0 {
                            let done = end + t.dispatch_load_ptr + t.tcdm_local_load;
                            self.q.schedule(done, Ev::PtrDone { c });
                        } else {
                            // Remote load from cluster 0 through the
                            // narrow NoC; serialized at its TCDM port.
                            let arrive = end + t.dispatch_load_ptr + self.to_cluster0(c);
                            let served =
                                self.fifo_tcdm0(arrive, t.tcdm_service);
                            let done = served + self.to_cluster0(c);
                            self.q.schedule(done, Ev::PtrDone { c });
                        }
                    }
                }
            }
            Ev::PtrDone { c } => {
                let start = self.trace.cluster_spans[c][&Phase::Wakeup].end;
                self.trace
                    .record(c, Phase::RetrievePtr, PhaseSpan::new(start, now));
                // Phase D: retrieve job arguments.
                match self.routine.uses_multicast() {
                    true => {
                        // Arguments arrived with the multicast write:
                        // zero-length phase (eliminated, §4.2).
                        self.q.schedule(now, Ev::ArgsDone { c });
                    }
                    false => {
                        if c == 0 {
                            let done = now + t.dispatch_load_ptr;
                            self.q.schedule(done, Ev::ArgsDone { c });
                        } else {
                            let beats = DmaTransfer {
                                bytes: self.spec.args_bytes(),
                                into_tcdm: true,
                            }
                            .beats(self.cfg.soc.wide_bus_bytes);
                            let issue = now + t.dma_setup_per_transfer;
                            let arrive = issue + self.to_cluster0(c);
                            let served = self.fifo_tcdm0(arrive, beats.max(1));
                            let done = served + self.to_cluster0(c) + DMA_POLL;
                            self.q.schedule(done, Ev::ArgsDone { c });
                        }
                    }
                }
            }
            Ev::ArgsDone { c } => {
                if self.routine.is_offloaded() {
                    let start = self.trace.cluster_spans[c][&Phase::RetrievePtr].end;
                    self.trace
                        .record(c, Phase::RetrieveArgs, PhaseSpan::new(start, now));
                }
                // Phase E: retrieve job operands.
                self.clusters[c].e_start = now;
                let transfers = self.spec.operand_transfers(self.n_clusters, c);
                if transfers.is_empty() {
                    self.clusters[c].e_end = now;
                    self.trace
                        .record(c, Phase::RetrieveOperands, PhaseSpan::new(now, now));
                    self.schedule_compute(c, now);
                } else {
                    let beats: u64 = transfers
                        .iter()
                        .map(|&b| {
                            DmaTransfer {
                                bytes: b,
                                into_tcdm: true,
                            }
                            .beats(self.cfg.soc.wide_bus_bytes)
                        })
                        .sum();
                    let setup = t.dma_setup_phase_entry
                        + transfers.len() as u64 * self.dma.setup;
                    let join = now + setup + self.dma.request_latency;
                    self.q.schedule(join, Ev::OperandJoin { c, beats });
                }
            }
            Ev::OperandJoin { c, beats } => {
                self.port_submit(now, c, beats, false);
            }
            Ev::PortCheck { generation } => {
                let finished: Vec<u64> = match &mut self.port {
                    WidePort::Fluid(p) => {
                        if !p.is_current(generation) {
                            return; // stale
                        }
                        p.collect_finished(now)
                    }
                    WidePort::Rr(_) => unreachable!("PortCheck on RR port"),
                };
                for id in finished {
                    self.port_transfer_done(now, id);
                }
                self.reschedule_port_check(now);
            }
            Ev::PortDone { id } => {
                match &mut self.port {
                    WidePort::Rr(p) => p.complete(),
                    WidePort::Fluid(_) => unreachable!("PortDone on fluid port"),
                }
                self.port_transfer_done(now, id);
                self.rr_dispatch(now);
            }
            Ev::ComputeDone { c } => {
                let e_end = self.clusters[c].e_end;
                self.trace
                    .record(c, Phase::Execute, PhaseSpan::new(e_end, now));
                // Phase G: writeback.
                let wb = self.spec.writeback_bytes(self.n_clusters, c);
                self.clusters[c].g_start = now;
                if wb == 0 {
                    self.trace
                        .record(c, Phase::Writeback, PhaseSpan::new(now, now));
                    self.q.schedule(now, Ev::NotifyDone { c });
                } else {
                    let beats = DmaTransfer {
                        bytes: wb,
                        into_tcdm: false,
                    }
                    .beats(self.cfg.soc.wide_bus_bytes);
                    let join = now
                        + t.cluster_barrier
                        + self.dma.setup
                        + self.dma.request_latency;
                    self.q.schedule(join, Ev::WritebackJoin { c, beats });
                }
            }
            Ev::WritebackJoin { c, beats } => {
                self.port_submit(now, c, beats, true);
            }
            Ev::NotifyDone { c } => {
                // Phase H entry for this cluster (or terminal state for
                // the ideal routine).
                match self.routine {
                    RoutineKind::Ideal => {
                        self.cluster_finished(c);
                        if self.finished_clusters == self.n_clusters {
                            self.trace.total = now;
                        }
                    }
                    r if !r.uses_jcu() => {
                        let arrive = now + t.barrier_instr + self.to_cluster0(c).max(
                            // local participants still traverse the TCDM
                            // interconnect inside the cluster
                            t.tcdm_local_load,
                        );
                        self.clusters[c].g_start = now; // reuse: H start
                        self.q.schedule(arrive, Ev::BarrierArrive { c });
                    }
                    _ => {
                        let arrive =
                            now + t.jcu_notify_instr + t.cluster_to_clint_oneway();
                        self.clusters[c].g_start = now; // H start
                        self.trace.record(
                            c,
                            Phase::Notify,
                            PhaseSpan::new(now, now + t.jcu_notify_instr),
                        );
                        self.q.schedule(arrive, Ev::JcuArrive { c });
                        self.cluster_finished(c);
                    }
                }
            }
            Ev::BarrierArrive { c } => {
                // AMO increment serialized at the counter's TCDM bank.
                let served = self.fifo_amo(now, t.amo_service);
                let back = served + self.to_cluster0(c).max(t.tcdm_local_load);
                self.barrier_count += 1;
                let h_start = self.clusters[c].g_start;
                self.trace
                    .record(c, Phase::Notify, PhaseSpan::new(h_start, back));
                self.cluster_finished(c);
                if self.barrier_count == self.n_clusters {
                    // The releasing participant observes the full count
                    // and fires the IPI to CVA6.
                    let wake = back
                        + t.barrier_notify_instr
                        + t.cluster_to_clint_oneway()
                        + t.host_wake;
                    self.q.schedule(wake, Ev::HostWake);
                }
            }
            Ev::JcuArrive { c } => {
                let _ = c;
                self.jcu_count += 1;
                if self.jcu_count == self.n_clusters {
                    let wake = now + t.jcu_fire + t.host_wake;
                    self.q.schedule(wake, Ev::HostWake);
                }
            }
            Ev::HostWake => {
                let end = now + t.host_resume;
                self.trace.record_host(Phase::Resume, PhaseSpan::new(now, end));
                self.trace.total = end;
            }
        }
    }

    fn schedule_compute(&mut self, c: usize, at: Time) {
        let t = &self.cfg.timing;
        let cycles = self.spec.compute_cycles(self.n_clusters, c, t);
        // DM core / compute cores handshake through the HW barrier on
        // both sides of the computation (§4.1.F/G).
        self.q
            .schedule(at + t.cluster_barrier + cycles, Ev::ComputeDone { c });
    }

    /// Submit a coalesced DMA transfer to the wide-SPM port.
    fn port_submit(&mut self, now: Time, cluster: usize, beats: u64, writeback: bool) {
        let id = match &mut self.port {
            WidePort::Rr(p) => p.submit(cluster, beats),
            WidePort::Fluid(p) => p.join(now, beats).0,
        } as usize;
        if self.port_jobs.len() <= id {
            self.port_jobs.resize(id + 1, None);
        }
        self.port_jobs[id] = Some(PortJob { cluster, writeback });
        match &self.port {
            WidePort::Rr(_) => self.rr_dispatch(now),
            WidePort::Fluid(_) => self.reschedule_port_check(now),
        }
    }

    /// A transfer's last beat left the port: completion becomes visible
    /// at the owning cluster after the response latency.
    fn port_transfer_done(&mut self, now: Time, id: u64) {
        let job = self.port_jobs[id as usize]
            .take()
            .expect("unknown port job");
        let visible = now + self.dma.response_latency + DMA_POLL;
        if job.writeback {
            let start = self.clusters[job.cluster].g_start;
            self.trace
                .record(job.cluster, Phase::Writeback, PhaseSpan::new(start, visible));
            self.q.schedule(visible, Ev::NotifyDone { c: job.cluster });
        } else {
            self.clusters[job.cluster].e_end = visible;
            let start = self.clusters[job.cluster].e_start;
            self.trace.record(
                job.cluster,
                Phase::RetrieveOperands,
                PhaseSpan::new(start, visible),
            );
            self.schedule_compute(job.cluster, visible);
        }
    }

    fn rr_dispatch(&mut self, now: Time) {
        if let WidePort::Rr(p) = &mut self.port {
            if let Some((id, beats)) = p.try_grant() {
                self.q.schedule(now + beats, Ev::PortDone { id });
            }
        }
    }

    fn reschedule_port_check(&mut self, now: Time) {
        if let WidePort::Fluid(p) = &self.port {
            if let Some((at, generation)) = p.next_completion(now) {
                // At most one PortCheck is ever live: `join` and
                // `collect_finished` bump the port generation, so any
                // previously scheduled check is a guaranteed no-op pop.
                // The fast profile's replaceable slot exploits exactly
                // this invariant.
                self.q.schedule_replaceable(at, Ev::PortCheck { generation });
            }
        }
    }

    fn fifo_tcdm0(&mut self, arrive: Time, service: u64) -> Time {
        let start = self.tcdm0_free.max(arrive);
        self.tcdm0_free = start + service;
        self.tcdm0_free
    }

    fn fifo_amo(&mut self, arrive: Time, service: u64) -> Time {
        let start = self.amo_free.max(arrive);
        self.amo_free = start + service;
        self.amo_free
    }

    fn cluster_finished(&mut self, c: usize) {
        assert!(!self.clusters[c].done, "cluster {c} finished twice");
        self.clusters[c].done = true;
        self.finished_clusters += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Direct, uncached executor runs — these tests exercise the engine
    /// itself, below the `sweep` layer.
    fn run_offload(cfg: &Config, spec: &JobSpec, n: usize, routine: RoutineKind) -> Trace {
        Executor::new(cfg, spec, n, routine).run()
    }

    fn cfg() -> Config {
        Config::default()
    }

    #[test]
    fn ideal_runs_only_efg() {
        let c = cfg();
        let spec = JobSpec::Axpy { n: 1024 };
        let tr = run_offload(&c, &spec, 4, RoutineKind::Ideal);
        assert!(tr.stats(Phase::Wakeup).is_none());
        assert!(tr.stats(Phase::RetrieveOperands).is_some());
        assert!(tr.stats(Phase::Execute).is_some());
        assert!(tr.stats(Phase::Writeback).is_some());
        assert!(tr.host_duration(Phase::Resume).is_none());
        assert!(tr.total > 0);
    }

    #[test]
    fn baseline_records_all_phases() {
        let c = cfg();
        let spec = JobSpec::Axpy { n: 1024 };
        let tr = run_offload(&c, &spec, 8, RoutineKind::Baseline);
        for p in Phase::ALL {
            if p.is_host_phase() {
                assert!(tr.host_duration(p).is_some(), "missing host {p:?}");
            } else {
                assert!(tr.stats(p).is_some(), "missing {p:?}");
            }
        }
    }

    #[test]
    fn multicast_wakeup_is_47_cycles() {
        // §5.5.B: 47-cycle wakeup with multicast (8 issue + 39 hardware),
        // plus the local MCIP clear.
        let c = cfg();
        let spec = JobSpec::Axpy { n: 256 };
        let tr = run_offload(&c, &spec, 32, RoutineKind::Multicast);
        let b = tr.stats(Phase::Wakeup).unwrap();
        assert_eq!(b.min, b.max, "multicast wakeup is uniform");
        assert_eq!(b.min, 47 + c.timing.mcip_clear);
    }

    #[test]
    fn baseline_wakeup_grows_linearly() {
        let c = cfg();
        let spec = JobSpec::Axpy { n: 256 };
        let tr = run_offload(&c, &spec, 32, RoutineKind::Baseline);
        let b = tr.stats(Phase::Wakeup).unwrap();
        assert!(b.max > b.min);
        assert_eq!(
            b.max - b.min,
            31 * c.timing.host_ipi_issue_gap,
            "spread = (n-1) issue gaps"
        );
    }

    #[test]
    fn baseline_ptr_retrieval_steps_with_distance() {
        // §5.5.C: min (cluster 0, local) near-constant; max steps up when
        // crossing cluster and quadrant boundaries.
        let c = cfg();
        let spec = JobSpec::Axpy { n: 256 };
        let t1 = run_offload(&c, &spec, 1, RoutineKind::Baseline);
        let t4 = run_offload(&c, &spec, 4, RoutineKind::Baseline);
        let t8 = run_offload(&c, &spec, 8, RoutineKind::Baseline);
        let c1 = t1.stats(Phase::RetrievePtr).unwrap();
        let c4 = t4.stats(Phase::RetrievePtr).unwrap();
        let c8 = t8.stats(Phase::RetrievePtr).unwrap();
        assert_eq!(c1.min, c4.min, "cluster 0 is local in both");
        assert!(c4.max > c4.min, "remote same-quadrant loads cost more");
        assert!(c8.max > c4.max, "cross-quadrant loads cost more still");
    }

    #[test]
    fn multicast_ptr_retrieval_is_local_everywhere() {
        let c = cfg();
        let spec = JobSpec::Axpy { n: 256 };
        let tr = run_offload(&c, &spec, 32, RoutineKind::Multicast);
        let s = tr.stats(Phase::RetrievePtr).unwrap();
        assert_eq!(s.min, s.max);
        assert_eq!(s.min, c.timing.dispatch_load_ptr + c.timing.tcdm_local_load);
        // And phase D is eliminated (zero duration).
        let d = tr.stats(Phase::RetrieveArgs).unwrap();
        assert_eq!(d.max, 0);
    }

    #[test]
    fn phase_e_eq1_multicast_axpy() {
        // Eq. 1: max runtime of phase E = t_setup + t_latency + 2N*8/bw.
        let c = cfg();
        let n = 1024u64;
        let spec = JobSpec::Axpy { n };
        let tr = run_offload(&c, &spec, 8, RoutineKind::Multicast);
        let e = tr.stats(Phase::RetrieveOperands).unwrap();
        let expect = 53 + 55 + 2 * n * 8 / 64 + DMA_POLL;
        // All clusters join the port within a cycle of each other, so the
        // slowest one sees the full combined-length transfer.
        assert!(
            (e.max as i64 - expect as i64).abs() <= 2,
            "e.max={} expect={}",
            e.max,
            expect
        );
    }

    #[test]
    fn total_runtime_ordering() {
        // ideal <= multicast <= baseline for every config.
        let c = cfg();
        for spec in [
            JobSpec::Axpy { n: 1024 },
            JobSpec::Atax { m: 64, n: 64 },
            JobSpec::MonteCarlo { samples: 2048 },
        ] {
            for n in [1usize, 2, 8, 32] {
                let b = run_offload(&c, &spec, n, RoutineKind::Baseline).total;
                let m = run_offload(&c, &spec, n, RoutineKind::Multicast).total;
                let i = run_offload(&c, &spec, n, RoutineKind::Ideal).total;
                assert!(i <= m, "{spec:?} n={n}: ideal {i} > improved {m}");
                assert!(m <= b, "{spec:?} n={n}: improved {m} > base {b}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let c = cfg();
        let spec = JobSpec::Covariance { m: 32, n: 64 };
        let a = run_offload(&c, &spec, 16, RoutineKind::Baseline);
        let b = run_offload(&c, &spec, 16, RoutineKind::Baseline);
        assert_eq!(a.total, b.total);
        assert_eq!(a.events, b.events);
    }
}
