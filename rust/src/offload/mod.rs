//! The host-centric offload framework (§4): phases A-I executed on the
//! simulated SoC, in the baseline (§4.1) and multicast/JCU-optimized
//! (§4.2/§4.3) variants, plus the "ideal" direct-on-device execution the
//! paper compares against (§5.2).

pub mod baseline;
pub mod executor;
pub mod multicast;
pub mod phases;

pub use executor::Executor;
pub use phases::{RoutineKind, RunTriple, TraceTriple};

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::sim::Trace;

/// Run one job with one routine; returns the full phase trace.
pub fn run_offload(
    cfg: &Config,
    spec: &JobSpec,
    n_clusters: usize,
    routine: RoutineKind,
) -> Trace {
    Executor::new(cfg, spec, n_clusters, routine).run()
}

/// Run the base/ideal/improved triple for one configuration (the unit of
/// every figure in §5).
pub fn run_triple(cfg: &Config, spec: &JobSpec, n_clusters: usize) -> TraceTriple {
    TraceTriple {
        base: run_offload(cfg, spec, n_clusters, RoutineKind::Baseline),
        ideal: run_offload(cfg, spec, n_clusters, RoutineKind::Ideal),
        improved: run_offload(cfg, spec, n_clusters, RoutineKind::Multicast),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_is_consistent() {
        let cfg = Config::default();
        let spec = JobSpec::Axpy { n: 1024 };
        let t = run_triple(&cfg, &spec, 8);
        let r = t.runtimes(8);
        assert!(r.overhead() > 0);
        assert!(r.residual_overhead() > 0);
        assert!(r.residual_overhead() < r.overhead());
        assert!(r.ideal_speedup() > 1.0);
        assert!(r.achieved_speedup() > 1.0);
        let f = r.restored_fraction();
        assert!(f > 0.0 && f <= 1.0, "restored fraction {f}");
    }
}
