//! The host-centric offload framework (§4): phases A-I executed on the
//! simulated SoC, in the baseline (§4.1) and multicast/JCU-optimized
//! (§4.2/§4.3) variants, plus the "ideal" direct-on-device execution the
//! paper compares against (§5.2).
//!
//! Experiment campaigns over these routines go through [`crate::sweep`]
//! (single process) and [`crate::campaign`] (sharded, resumable); the
//! raw uncached entry point is [`Executor`] via
//! `sweep::OffloadRequest::run`. The deprecated positional free
//! functions `run_offload`/`run_triple` were removed in 0.3.0.
//!
//! Every timeline runs under an engine profile
//! ([`crate::sim::SimProfile`]): [`Executor::new`] is always the
//! reference event-heap DES; [`Executor::with_profile`] selects the
//! `fast` engine, which elides heap work and memoizes whole timelines
//! keyed by [`request_key`] + config — bit-identical to the reference
//! by construction and enforced by `tests/integration_profiles.rs`.

pub mod baseline;
pub mod executor;
pub mod multicast;
pub mod phases;

pub use executor::Executor;
pub use phases::{RoutineKind, RunTriple};

use crate::kernels::JobSpec;

/// The canonical request-key grammar — `<spec>-c<clusters>-<routine>`
/// with [`JobSpec::store_id`] spelling out every spec parameter. Shared
/// by the campaign store's on-disk filenames, `obs::report`'s parser,
/// and the fast profile's timeline memoizer, so the three can never
/// drift apart.
pub fn request_key(spec: &JobSpec, n_clusters: usize, routine: RoutineKind) -> String {
    format!("{}-c{}-{}", spec.store_id(), n_clusters, routine.name())
}

#[cfg(test)]
mod tests {
    use crate::config::Config;
    use crate::kernels::JobSpec;
    use crate::sweep;

    #[test]
    fn executor_matches_the_sweep_api() {
        // The raw executor is the uncached reference implementation the
        // sweep layer must agree with.
        let cfg = Config::default();
        let spec = JobSpec::Axpy { n: 512 };
        let new = sweep::triple(&cfg, &spec, 4);
        let direct = |routine| {
            super::Executor::new(&cfg, &spec, 4, routine)
                .run()
                .total
        };
        assert_eq!(direct(super::RoutineKind::Baseline), new.base);
        assert_eq!(direct(super::RoutineKind::Ideal), new.ideal);
        assert_eq!(direct(super::RoutineKind::Multicast), new.improved);
    }
}
