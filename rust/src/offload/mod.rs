//! The host-centric offload framework (§4): phases A-I executed on the
//! simulated SoC, in the baseline (§4.1) and multicast/JCU-optimized
//! (§4.2/§4.3) variants, plus the "ideal" direct-on-device execution the
//! paper compares against (§5.2).
//!
//! Experiment campaigns over these routines go through [`crate::sweep`];
//! the positional free functions below are deprecated shims kept for one
//! release.

pub mod baseline;
pub mod executor;
pub mod multicast;
pub mod phases;

pub use executor::Executor;
pub use phases::{RoutineKind, RunTriple, TraceTriple};

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::sim::Trace;

/// Run one job with one routine; returns the full phase trace.
#[deprecated(
    since = "0.2.0",
    note = "use `sweep::run_one` with a typed `sweep::OffloadRequest` (cached, parallel-ready)"
)]
pub fn run_offload(
    cfg: &Config,
    spec: &JobSpec,
    n_clusters: usize,
    routine: RoutineKind,
) -> Trace {
    Executor::new(cfg, spec, n_clusters, routine).run()
}

/// Run the base/ideal/improved triple for one configuration (the unit of
/// every figure in §5).
#[deprecated(
    since = "0.2.0",
    note = "use `sweep::triple` or a `sweep::Sweep` campaign"
)]
pub fn run_triple(cfg: &Config, spec: &JobSpec, n_clusters: usize) -> TraceTriple {
    TraceTriple {
        base: Executor::new(cfg, spec, n_clusters, RoutineKind::Baseline).run(),
        ideal: Executor::new(cfg, spec, n_clusters, RoutineKind::Ideal).run(),
        improved: Executor::new(cfg, spec, n_clusters, RoutineKind::Multicast).run(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep;

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_the_sweep_api() {
        let cfg = Config::default();
        let spec = JobSpec::Axpy { n: 512 };
        let legacy = run_triple(&cfg, &spec, 4).runtimes(4);
        let new = sweep::triple(&cfg, &spec, 4);
        assert_eq!(legacy.base, new.base);
        assert_eq!(legacy.ideal, new.ideal);
        assert_eq!(legacy.improved, new.improved);
        let t = run_offload(&cfg, &spec, 4, RoutineKind::Baseline);
        assert_eq!(t.total, new.base);
    }
}
