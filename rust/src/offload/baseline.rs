//! Baseline offload routine details (§4.1).
//!
//! The pieces specific to the *unoptimized* implementation: the
//! sequential IPI schedule CVA6 issues in phase B, and the
//! central-counter software barrier of phase H.

use crate::sim::Time;

/// Phase-B IPI issue schedule: one store per target cluster, highest
//  index first so cluster 0 — which hosts the barrier counter — wakes
/// last and arrives at the barrier last, overlapping the remote clusters'
/// longer counter-increment latencies with the wakeup offsets (§5.5.H).
pub fn ipi_schedule(
    n_clusters: usize,
    start: Time,
    first_issue: u64,
    gap: u64,
) -> Vec<(usize, Time)> {
    (0..n_clusters)
        .rev()
        .enumerate()
        .map(|(k, c)| (c, start + first_issue + k as u64 * gap))
        .collect()
}

/// Central-counter software barrier (phase H): participants atomically
/// increment a counter in cluster 0's TCDM; the participant that observes
/// the full count notifies CVA6. This is the *functional* model (used by
/// the coordinator); the cycle-level serialization happens in the
/// executor's AMO FIFO.
#[derive(Debug, Clone)]
pub struct CentralCounterBarrier {
    count: u32,
    expected: u32,
}

impl CentralCounterBarrier {
    pub fn new(expected: u32) -> Self {
        assert!(expected >= 1);
        Self { count: 0, expected }
    }

    /// Atomic increment; returns the post-increment value. The caller
    /// that sees `== expected` is the releaser.
    pub fn amo_increment(&mut self) -> u32 {
        self.count += 1;
        assert!(
            self.count <= self.expected,
            "barrier over-subscribed: {} > {}",
            self.count,
            self.expected
        );
        self.count
    }

    pub fn is_released(&self) -> bool {
        self.count == self.expected
    }

    /// Reset for the next offload (done by the releaser).
    pub fn reset(&mut self) {
        assert!(self.is_released(), "reset before release");
        self.count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipi_schedule_is_reverse_order() {
        let s = ipi_schedule(4, 100, 8, 28);
        assert_eq!(s[0], (3, 108));
        assert_eq!(s[1], (2, 136));
        assert_eq!(s[3], (0, 192)); // cluster 0 last
    }

    #[test]
    fn single_cluster_schedule() {
        let s = ipi_schedule(1, 0, 8, 28);
        assert_eq!(s, vec![(0, 8)]);
    }

    #[test]
    fn barrier_release_and_reuse() {
        let mut b = CentralCounterBarrier::new(3);
        assert_eq!(b.amo_increment(), 1);
        assert_eq!(b.amo_increment(), 2);
        assert!(!b.is_released());
        assert_eq!(b.amo_increment(), 3);
        assert!(b.is_released());
        b.reset();
        assert!(!b.is_released());
    }

    #[test]
    #[should_panic(expected = "over-subscribed")]
    fn oversubscribed_barrier_panics() {
        let mut b = CentralCounterBarrier::new(1);
        b.amo_increment();
        b.amo_increment();
    }
}
