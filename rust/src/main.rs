//! `occamy` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   experiment <fig7|fig8|fig9|fig10|fig11|fig12|interference|all> [--csv] [--config F] [--profile P]
//!   campaign <run|merge|status|validate> --spec F [--shard i/N] [--out DIR]
//!   fleet <run|status|watch|cancel|gc> --spec F [--workers N] [--out DIR]
//!   trace <export|report|flight|serve-report> (Perfetto export; store/flight/span reports)
//!   sim --kernel K --size N [--clusters C] [--routine R] [--config F]
//!   interfere --kernel K --size N [--clusters C] [--inflight LIST] [--jobs N] [--gap G]
//!   serve --listen ADDR [--spec F] [--inflight W] [--queue-factor Q] [--slo CYC] [--store DIR]
//!   serve [--oneshot] --jobs N [--artifacts DIR] [--timing-only] [--seed S] [--inflight W]
//!   loadgen --connect ADDR [--requests N] [--seed S] [--process poisson|bursty|diurnal]
//!   bench <serve|des> [--requests N] [--inflight W] [--reps R] [--out FILE] [--baseline FILE]
//!   audit [--json] [--deny] [--manifest F] [PATHS..]
//!   validate-artifacts [--artifacts DIR]
//!   model --kernel K --size N [--config F]
//!   config-dump
//!
//! Unknown flags are rejected per subcommand — a typo'd `--flag` fails
//! fast instead of silently no-opping.
//!
//! The binary is self-contained after `make artifacts`: python never runs
//! on the request path.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::time::Duration;

use occamy_offload::analysis;
use occamy_offload::bench::Bench;
use occamy_offload::campaign::{self, CampaignSpec, HostSpec, Shard, TraceStore};
use occamy_offload::config::Config;
use occamy_offload::coordinator::{
    Coordinator, CoordinatorConfig, JobRequest, OccupancyModel, OccupancyParams, Planner,
    JCU_SLOTS,
};
use occamy_offload::exp::{self, Table};
use occamy_offload::fleet::{
    self, FleetOptions, GcOptions, Heartbeat, Lease, LocalLauncher, SshLauncher,
};
use occamy_offload::kernels::JobSpec;
use occamy_offload::model::OffloadModel;
use occamy_offload::obs;
use occamy_offload::offload::RoutineKind;
use occamy_offload::runtime::json::Json;
use occamy_offload::runtime::{default_artifacts_dir, run_and_verify, PjrtRuntime};
use occamy_offload::serve::{
    self, ArrivalKind, ArrivalProcess, Engine, EngineOptions, LoadgenOptions, Request, ServeSpec,
    Server, Submit,
};
use occamy_offload::sim::{fast, Phase, SimProfile};
use occamy_offload::sweep::{self, OffloadRequest, SweepResults};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Tiny flag parser: positionals + `--key value` + `--flag`.
struct Args {
    positional: Vec<String>,
    // Ordered so diagnostics that list flags (reject_unknown) render in a
    // deterministic order without an explicit sort.
    flags: BTreeMap<String, String>,
}

/// Flags that never take a value, across every subcommand: a bare token
/// following one of these is a positional, not the flag's value
/// (`fleet gc --dry-run spec.toml` must not swallow the spec).
const BOOLEAN_FLAGS: &[&str] = &[
    "csv",
    "deny",
    "dry-run",
    "help",
    "json",
    "local",
    "metrics",
    "no-stats",
    "no-store",
    "oneshot",
    "phases",
    "prune-merged",
    "shutdown",
    "timing-only",
    "verify",
];

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(name) = args[i].strip_prefix("--") {
                let has_value = i + 1 < args.len()
                    && !args[i + 1].starts_with("--")
                    && !BOOLEAN_FLAGS.contains(&name);
                if has_value {
                    flags.insert(name.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), String::from("true"));
                    i += 1;
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn u64_flag(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// Strict per-subcommand validation: every given `--flag` must be in
    /// `allowed`, and at most `max_positional` bare arguments may
    /// appear. A typo'd flag fails fast with the usage text instead of
    /// silently no-opping.
    fn reject_unknown(
        &self,
        what: &str,
        allowed: &[&str],
        max_positional: usize,
    ) -> anyhow::Result<()> {
        if self.has("help") {
            anyhow::bail!("{USAGE}");
        }
        // BTreeMap keys iterate sorted, so the message is deterministic.
        let unknown: Vec<&str> = self
            .flags
            .keys()
            .map(String::as_str)
            .filter(|f| !allowed.contains(f))
            .collect();
        if !unknown.is_empty() {
            let unknown: Vec<String> = unknown.iter().map(|f| format!("--{f}")).collect();
            let allowed: Vec<String> = allowed.iter().map(|f| format!("--{f}")).collect();
            anyhow::bail!(
                "unknown flag(s) for `{what}`: {}\nallowed: {}\n{USAGE}",
                unknown.join(", "),
                if allowed.is_empty() { "(none)".to_string() } else { allowed.join(", ") }
            );
        }
        if self.positional.len() > max_positional {
            anyhow::bail!(
                "unexpected argument {:?} for `{what}`\n{USAGE}",
                self.positional[max_positional]
            );
        }
        Ok(())
    }
}

fn load_config(a: &Args) -> anyhow::Result<Config> {
    match a.flag("config") {
        None => Ok(Config::default()),
        Some(path) => Config::from_path(&PathBuf::from(path)),
    }
}

fn artifacts_dir(a: &Args) -> PathBuf {
    a.flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// One resolution of the shared store root for every campaign/fleet
/// subcommand: `--no-store` disables it, `--store` overrides it, and the
/// default is `<out>/store` — the same root the fleet's lease directory
/// hangs off, so run/status/fleet always look at the same place.
fn resolve_store_root(a: &Args, out_dir: &Path) -> Option<PathBuf> {
    if a.has("no-store") {
        None
    } else {
        let root = a
            .flag("store")
            .map(PathBuf::from)
            .unwrap_or_else(|| out_dir.join("store"));
        Some(root)
    }
}

/// Parse `--profile` into an engine profile; `None` when the flag is
/// absent, so callers fall back to their spec's choice or the reference
/// default. Both profiles produce bit-identical results — `fast` only
/// changes how much work the DES does to get there.
fn profile_flag(a: &Args) -> anyhow::Result<Option<SimProfile>> {
    match a.flag("profile") {
        None => Ok(None),
        Some(v) => SimProfile::parse(v).map(Some).ok_or_else(|| {
            anyhow::anyhow!("unknown profile {v:?} (expected \"reference\" or \"fast\")")
        }),
    }
}

/// Kernel family + single size, via the campaign token grammar (one
/// mapping for the CLI and campaign specs; `matmul:S` is a cube,
/// `atax:S` square, `covariance:S` is m=S n=2S, `bfs:S` 4 levels).
fn job_spec(kernel: &str, size: u64) -> anyhow::Result<JobSpec> {
    occamy_offload::campaign::spec::parse_kernel(&format!("{kernel}:{size}"))
        .map_err(|e| anyhow::anyhow!("{e}"))
}

/// `fleet::status` with the view parameters a [`FleetOptions`] carries.
fn fleet_status_of(spec: &CampaignSpec, opts: &FleetOptions) -> anyhow::Result<fleet::StatusView> {
    fleet::status(spec, opts.workers, &opts.out_dir, opts.store.as_deref(), &opts.run_id)
}

fn emit(table: Table, csv: bool) {
    if csv {
        print!("{}", table.to_csv());
    } else {
        println!("{}", table.render());
    }
}

const USAGE: &str = "usage: occamy <experiment|campaign|fleet|trace|sim|interfere|serve|loadgen|bench|audit|validate-artifacts|model|config-dump> [options]
  experiment <fig7|fig8|fig9|fig10|fig11|fig12|ablation|interference|all> [--csv] [--config F]
             [--profile reference|fast]   (fast = elision engine, bit-identical results)
  campaign run      --spec F [--shard i/N] [--out DIR] [--store DIR] [--no-store] [--max-points N]
                    [--lease FILE] [--lease-ttl SECS] [--run-id ID] [--attempt K] [--profile P]
                    [--trace-parent CTX]   (or OCCAMY_TRACE_PARENT; stitches shard spans under a fleet root)
  campaign merge    --spec F [--shards N] [--out DIR] [--verify] [--render FIG|interference] [--csv]
  campaign status   --spec F [--shards N] [--out DIR] [--store DIR] [--no-store] [--run-id ID]
  campaign validate --spec F
  fleet run    --spec F [--workers N] [--out DIR] [--store DIR] [--no-store] [--lease-ttl SECS]
               [--max-restarts K] [--poll-ms MS] [--run-id ID] [--chaos-kill SHARD]
               [--hosts H1,H2,..] [--remote-bin PATH] [--local-root DIR] [--ssh BIN] [--local]
  fleet gc     --store DIR [--dry-run] [--retention-secs S] [--tmp-grace-secs S] [SPEC..]
               [--prune-merged [--out DIR] SPEC..]   (delete shard files behind a re-verified merge)
  fleet status --spec F [--workers N] [--out DIR] [--store DIR] [--no-store] [--run-id ID] [--metrics]
  fleet watch  --spec F [--workers N] [--out DIR] [--store DIR] [--no-store] [--run-id ID] [--interval SECS]
  fleet cancel --spec F [--out DIR] [--store DIR] [--no-store] [--run-id ID]
  trace export --out FILE [--kernel K] [--size N] [--clusters C] [--routine R] [--config F]
               [--batch N [--inflight W] [--gap G]] [--spans LOG]   (Perfetto/Chrome trace-event JSON;
               --spans merges recorded request/client span lanes from an event log or --record file)
  trace report --store DIR [--phases] [--csv]         (offload-overhead decomposition of a store)
  trace flight (--dump FILE | --store DIR)            (render flight-recorder dumps from <store>/flight)
  trace serve-report --log FILE [--csv]               (interference curves from recorded serve spans)
  sim --kernel K --size N [--clusters C] [--routine baseline|multicast|mcast-only|jcu-only|ideal]
  interfere --kernel K --size N [--clusters C] [--routine R] [--inflight 1,2,4,8] [--jobs 16] [--gap 0] [--csv]
  serve --listen ADDR [--spec F] [--inflight W] [--queue-factor Q] [--gap G] [--slo CYC]
        [--summary-every N] [--store DIR] [--config F] [--log FILE] [--profile P]
  serve [--oneshot] --jobs N [--artifacts DIR] [--timing-only] [--seed S] [--clusters C] [--inflight W] [--gap G]
  loadgen --connect ADDR [--spec F] [--requests N] [--seed S] [--process poisson|bursty|diurnal|fixed]
          [--mean-gap G] [--burst B] [--period P] [--mix K1,K2,..] [--clusters C] [--routine R]
          [--no-stats] [--shutdown] [--metrics] [--record FILE]   (client-side span log)
  bench serve [--requests N] [--inflight W] [--seed S] [--mean-gap G] [--out FILE] [--config F]
              [--profile P] [--baseline FILE [--max-regress-pct P]]
              (exit nonzero on p99-latency or jobs/sim-s regression)
  bench des   [--reps R] [--clusters C] [--out FILE] [--config F]
              [--baseline FILE [--max-regress-pct P]]   (fast-engine event-elision benchmark)
  audit [--json] [--deny] [--manifest F] [PATHS..]
        (determinism-domain static analysis of the repo's own sources against rust/analysis.toml;
        default path rust/src, --deny exits nonzero on any finding, --json is byte-deterministic)
  validate-artifacts [--artifacts DIR]
  model --kernel K --size N [--config F]
  config-dump";

fn run(raw: &[String]) -> anyhow::Result<()> {
    if raw.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = raw[0].as_str();
    let a = Args::parse(&raw[1..]);
    match cmd {
        "experiment" => cmd_experiment(&a),
        "campaign" => cmd_campaign(&a),
        "fleet" => cmd_fleet(&a),
        "trace" => cmd_trace(&a),
        "sim" => cmd_sim(&a),
        "interfere" => cmd_interfere(&a),
        "serve" => cmd_serve(&a),
        "loadgen" => cmd_loadgen(&a),
        "bench" => cmd_bench(&a),
        "audit" => cmd_audit(&a),
        "validate-artifacts" => cmd_validate(&a),
        "model" => cmd_model(&a),
        "config-dump" => {
            a.reject_unknown("config-dump", &[], 0)?;
            print!("{}", Config::default().to_toml());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_experiment(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("experiment", &["csv", "config", "profile"], 1)?;
    let which = a.positional.first().map(String::as_str).unwrap_or("all");
    let cfg = load_config(a)?;
    let profile = profile_flag(a)?.unwrap_or_default();
    let csv = a.has("csv");
    let mut ran = false;
    if which == "ablation" || which == "all" {
        ran = true;
        let a = exp::ablation::run_with(&cfg, profile);
        emit(exp::ablation::render(&a), csv);
        emit(exp::ablation::render_port(&a), csv);
    }
    if which == "interference" || which == "all" {
        ran = true;
        emit(
            exp::interference::render(&exp::interference::run_with(&cfg, profile)),
            csv,
        );
    }
    for fig in ["fig7", "fig8", "fig9", "fig10", "fig11", "fig12"] {
        if which != "all" && which != fig {
            continue;
        }
        ran = true;
        let table = match fig {
            "fig7" => exp::fig7::render(&exp::fig7::run_with(&cfg, profile)),
            "fig8" => exp::fig8::render(&exp::fig8::run_with(&cfg, profile)),
            "fig9" => exp::fig9::render(&exp::fig9::run_with(&cfg, profile)),
            "fig10" => exp::fig10::render(&exp::fig10::run_with(&cfg, profile)),
            "fig11" => exp::fig11::render(&exp::fig11::run_with(&cfg, profile)),
            "fig12" => exp::fig12::render(&exp::fig12::run_with(&cfg, profile)),
            _ => unreachable!(),
        };
        emit(table, csv);
    }
    if !ran {
        anyhow::bail!("unknown experiment {which:?} (fig7..fig12, ablation, interference, or all)");
    }
    Ok(())
}

/// Render one figure from merged campaign results. The campaign must
/// cover the figure's grid (`exp::figN::sweep`) — checked up front so a
/// partial spec yields an error naming the missing points, not a panic
/// inside the render's lookups.
fn render_fig(which: &str, cfg: &Config, results: &SweepResults) -> anyhow::Result<Table> {
    let required = match which {
        "fig7" => exp::fig7::sweep(),
        "fig8" => exp::fig8::sweep(),
        "fig9" => exp::fig9::sweep(),
        "fig10" => exp::fig10::sweep(),
        "fig11" => exp::fig11::sweep(),
        "fig12" => exp::fig12::sweep(),
        other => anyhow::bail!("unknown figure {other:?} (fig7..fig12)"),
    }
    .expand();
    let missing = required
        .iter()
        .filter(|p| results.records().iter().all(|r| r.point != **p))
        .count();
    anyhow::ensure!(
        missing == 0,
        "campaign does not cover {which}: {missing} of its {} grid points are absent \
         (the spec must be a superset of exp::{which}::sweep)",
        required.len()
    );
    Ok(match which {
        "fig7" => exp::fig7::render(&exp::fig7::from_results(results)),
        "fig8" => exp::fig8::render(&exp::fig8::from_results(results)),
        "fig9" => exp::fig9::render(&exp::fig9::from_results(results)),
        "fig10" => exp::fig10::render(&exp::fig10::from_results(results)),
        "fig11" => exp::fig11::render(&exp::fig11::from_results(results)),
        "fig12" => exp::fig12::render(&exp::fig12::from_results(cfg, results)),
        _ => unreachable!("figure names validated above"),
    })
}

fn cmd_campaign(a: &Args) -> anyhow::Result<()> {
    let action = a
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: occamy campaign <run|merge|status|validate> --spec FILE"))?;
    // Flags are validated before anything touches the filesystem, so a
    // typo fails fast even when --spec is wrong too.
    const RUN_FLAGS: &[&str] = &[
        "spec",
        "shard",
        "out",
        "store",
        "no-store",
        "max-points",
        "lease",
        "lease-ttl",
        "run-id",
        "attempt",
        "profile",
        "trace-parent",
    ];
    let allowed: &[&str] = match action {
        "validate" => &["spec"],
        "run" => RUN_FLAGS,
        "status" => &["spec", "shards", "out", "store", "no-store", "run-id"],
        "merge" => &["spec", "shards", "out", "verify", "render", "csv"],
        other => anyhow::bail!("unknown campaign action {other:?} (run, merge, status or validate)"),
    };
    a.reject_unknown(&format!("campaign {action}"), allowed, 1)?;
    let spec_path = a
        .flag("spec")
        .ok_or_else(|| anyhow::anyhow!("campaign {action} requires --spec FILE"))?;
    let mut spec = CampaignSpec::from_path(&PathBuf::from(spec_path))?;
    // `--profile` (run only) beats the spec's `profile` key. Results are
    // bit-identical either way; only the cache key and DES effort differ.
    if let Some(p) = profile_flag(a)? {
        spec.profile = p;
    }
    let out_dir = a
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("campaign-out").join(&spec.name));
    match action {
        "validate" => {
            println!("{}", spec.report());
            println!("spec OK");
        }
        "run" => {
            // Structured events are opt-in via OCCAMY_LOG; never on by
            // default, never a change to simulation results.
            obs::log::init_from_env()?;
            let shard = match a.flag("shard") {
                Some(s) => Shard::parse(s)?,
                None => Shard::SINGLE,
            };
            let attempt = a.u64_flag("attempt", 0)?;
            // Deliberate-crash chaos hook: the flight-recorder
            // integration test sets OCCAMY_CHAOS_PANIC to prove a
            // panicking worker leaves a parseable dump behind.
            if std::env::var_os("OCCAMY_CHAOS_PANIC").is_some() {
                if let Some(root) = resolve_store_root(a, &out_dir) {
                    std::fs::create_dir_all(&root)?;
                    obs::flight::set_dump_dir(&root.join("flight"));
                }
                obs::flight::install_panic_hook();
                obs::flight::note(
                    &obs::Event::wall("campaign", "chaos_panic")
                        .str("shard", &shard.to_string())
                        .render(),
                );
                panic!("OCCAMY_CHAOS_PANIC set — deliberate crash for the flight-recorder test");
            }
            // One wall-domain span per shard attempt (the attempt keeps
            // span ids unique across relaunches), stitched under the
            // fleet-run root whenever the scheduler passed
            // --trace-parent / OCCAMY_TRACE_PARENT.
            if let Some(parent) = obs::span::init_ambient(a.flag("trace-parent")) {
                if obs::log::enabled() {
                    obs::log::emit(
                        &obs::span::wall_span(
                            "shard",
                            parent.child(&shard.to_string(), attempt),
                            Some(parent.span),
                        )
                        .str("campaign", &spec.name)
                        .str("shard", &shard.to_string())
                        .u64("attempt", attempt),
                    );
                }
            }
            let store = match resolve_store_root(a, &out_dir) {
                None => None,
                Some(root) => Some(TraceStore::open(root)?),
            };
            let max_points = match a.flag("max-points") {
                None => None,
                Some(v) => {
                    let n: usize = v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad --max-points {v:?}: {e}"))?;
                    anyhow::ensure!(n > 0, "--max-points must be >= 1");
                    Some(n)
                }
            };
            // Under a fleet scheduler the worker heartbeats its own
            // lease: liveness is observed purely through the shared
            // filesystem, so the scheduler needs no host access.
            let heartbeat = match a.flag("lease") {
                None => None,
                Some(path) => {
                    let ttl = a.u64_flag("lease-ttl", 30)?.max(1);
                    let run_id = a.flag("run-id").unwrap_or(&spec.name).to_string();
                    Some(Heartbeat::start(
                        PathBuf::from(path),
                        Lease::new(run_id, shard, attempt as usize, ttl),
                    )?)
                }
            };
            let report =
                campaign::run_shard_limited(&spec, shard, &out_dir, store.as_ref(), max_points)?;
            println!("{report}");
            if let Some(s) = &store {
                let st = s.stats();
                println!(
                    "store: {} memory hit(s), {} disk hit(s), {} simulation(s)",
                    st.memory_hits, st.disk_hits, st.simulations
                );
            }
            if report.is_complete() {
                if let Some(hb) = heartbeat {
                    hb.finish()?;
                }
            } else {
                // Dropping the heartbeat leaves a Running lease that
                // goes stale — to a fleet scheduler this exit is
                // indistinguishable from a mid-shard kill, which is the
                // point of --max-points chaos runs.
                drop(heartbeat);
                // A mid-shard bail is exactly what the flight recorder
                // exists for: leave the last-events ring on disk next to
                // the store the next attempt will resume from.
                if let Some(root) = resolve_store_root(a, &out_dir) {
                    obs::flight::set_dump_dir(&root.join("flight"));
                    obs::flight::note(
                        &obs::Event::wall("campaign", "shard_incomplete")
                            .str("shard", &report.shard.to_string())
                            .u64("resumed", report.resumed as u64)
                            .u64("executed", report.executed as u64)
                            .u64("owned", report.owned as u64)
                            .render(),
                    );
                    if let Some(path) = obs::flight::dump("incomplete") {
                        eprintln!("flight dump: {}", path.display());
                    }
                }
                anyhow::bail!(
                    "shard {} incomplete: --max-points stopped it at {} of {} owned points; re-run to resume",
                    report.shard,
                    report.resumed + report.executed,
                    report.owned
                );
            }
        }
        "status" => {
            let shards = a.u64_flag("shards", 1)? as usize;
            let store_root = resolve_store_root(a, &out_dir);
            let run_id = a.flag("run-id").unwrap_or(&spec.name);
            print!(
                "{}",
                fleet::status(&spec, shards, &out_dir, store_root.as_deref(), run_id)?
            );
        }
        "merge" => {
            let shards = a.u64_flag("shards", 1)? as usize;
            let results = campaign::merge(&spec, shards, &out_dir)?;
            println!(
                "merged {} points -> {}",
                results.len(),
                out_dir
                    .join(campaign::stream::merged_file_name(&spec.name))
                    .display()
            );
            if spec.interference.is_some() {
                println!(
                    "derived {} interference point(s) -> {}",
                    spec.interference_points().len(),
                    out_dir
                        .join(campaign::stream::interference_file_name(&spec.name))
                        .display()
                );
            }
            if a.has("verify") {
                let reference = campaign::run_single(&spec);
                anyhow::ensure!(
                    results == reference,
                    "merged results differ from single-process execution"
                );
                println!("verified: bit-identical to single-process execution");
            }
            if let Some(which) = a.flag("render") {
                if which == "interference" {
                    anyhow::ensure!(
                        spec.interference.is_some(),
                        "the spec has no [interference] section to render"
                    );
                    let samples: Vec<sweep::InterferenceSample> =
                        campaign::interference_records(&spec, &results)?
                            .into_iter()
                            .map(|(point, outcome)| sweep::InterferenceSample { point, outcome })
                            .collect();
                    emit(exp::interference::render(&samples), a.has("csv"));
                } else {
                    emit(render_fig(which, &spec.config, &results)?, a.has("csv"));
                }
            }
        }
        _ => unreachable!("actions validated above"),
    }
    Ok(())
}

/// `occamy fleet <run|status|watch|cancel>` — the multi-host campaign
/// scheduler. `run` is fully automatic: plan shards, launch local
/// workers, recover dead/stalled shards, auto-merge.
fn cmd_fleet(a: &Args) -> anyhow::Result<()> {
    let action = a.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow::anyhow!("usage: occamy fleet <run|status|watch|cancel|gc> --spec FILE")
    })?;
    if action == "gc" {
        return cmd_fleet_gc(a);
    }
    const RUN_FLAGS: &[&str] = &[
        "spec",
        "workers",
        "out",
        "store",
        "no-store",
        "lease-ttl",
        "max-restarts",
        "poll-ms",
        "run-id",
        "chaos-kill",
        "hosts",
        "remote-bin",
        "local-root",
        "ssh",
        "local",
    ];
    let allowed: &[&str] = match action {
        "run" => RUN_FLAGS,
        "status" => &["spec", "workers", "out", "store", "no-store", "run-id", "metrics"],
        "watch" => &["spec", "workers", "out", "store", "no-store", "run-id", "interval"],
        "cancel" => &["spec", "workers", "out", "store", "no-store", "run-id"],
        other => anyhow::bail!("unknown fleet action {other:?} (run, status, watch, cancel or gc)"),
    };
    a.reject_unknown(&format!("fleet {action}"), allowed, 1)?;
    let spec_path = PathBuf::from(
        a.flag("spec")
            .ok_or_else(|| anyhow::anyhow!("fleet {action} requires --spec FILE"))?,
    );
    let spec = CampaignSpec::from_path(&spec_path)?;
    let out_dir = a
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("campaign-out").join(&spec.name));
    // Seed every parameter from the spec's [fleet] table (or the
    // built-in defaults) exactly once, then layer flag overrides on top.
    let mut opts = FleetOptions::new(&spec, out_dir);
    opts.workers = a.u64_flag("workers", opts.workers as u64)? as usize;
    anyhow::ensure!(opts.workers > 0, "--workers must be >= 1");
    if let Some(id) = a.flag("run-id") {
        opts.run_id = id.to_string();
    }
    opts.store = resolve_store_root(a, &opts.out_dir);
    match action {
        "run" => {
            obs::log::init_from_env()?;
            opts.lease_ttl =
                Duration::from_secs(a.u64_flag("lease-ttl", opts.lease_ttl.as_secs())?.max(1));
            opts.max_restarts = a.u64_flag("max-restarts", opts.max_restarts as u64)? as usize;
            opts.poll =
                Duration::from_millis(a.u64_flag("poll-ms", opts.poll.as_millis() as u64)?.max(10));
            opts.chaos_kill = match a.flag("chaos-kill") {
                None => None,
                Some(v) => {
                    let i: usize = v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad --chaos-kill {v:?}: {e}"))?;
                    anyhow::ensure!(
                        i < opts.workers,
                        "--chaos-kill {i} out of range (0..{})",
                        opts.workers
                    );
                    Some(i)
                }
            };
            // Placement: non-empty hosts (spec [fleet] table, overridden
            // by --hosts) fan shards out over SSH against the shared
            // mount; --local forces local subprocesses regardless.
            let fleet_defaults = spec.fleet.clone().unwrap_or_default();
            let hosts: Vec<HostSpec> = if a.has("local") {
                Vec::new()
            } else {
                match a.flag("hosts") {
                    Some(list) => list
                        .split(',')
                        .map(|tok| {
                            HostSpec::parse(tok.trim())
                                .map_err(|e| anyhow::anyhow!("--hosts: {e}"))
                        })
                        .collect::<anyhow::Result<_>>()?,
                    None => fleet_defaults.hosts.clone(),
                }
            };
            let report = if hosts.is_empty() {
                let launcher = LocalLauncher::current_exe()?;
                fleet::run(&spec, &spec_path, &launcher, &opts)?
            } else {
                let launcher = SshLauncher {
                    hosts,
                    remote_bin: a
                        .flag("remote-bin")
                        .map(str::to_string)
                        .unwrap_or_else(|| fleet_defaults.remote_bin.clone()),
                    local_root: a
                        .flag("local-root")
                        .map(PathBuf::from)
                        .or_else(|| fleet_defaults.local_root.clone()),
                    ssh: a.flag("ssh").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("ssh")),
                    quiet: true,
                };
                launcher.validate()?;
                println!(
                    "fleet: ssh fan-out over {} host(s): {}",
                    launcher.hosts.len(),
                    launcher.hosts.iter().map(|h| h.name.as_str()).collect::<Vec<_>>().join(", ")
                );
                fleet::run(&spec, &spec_path, &launcher, &opts)?
            };
            println!("{report}");
        }
        "status" => {
            let view = fleet_status_of(&spec, &opts)?;
            if a.has("metrics") {
                let mut r = obs::Registry::new();
                view.register_metrics(&mut r);
                print!("{}", r.render());
            } else {
                print!("{view}");
            }
        }
        "watch" => {
            let interval = Duration::from_secs(a.u64_flag("interval", 2)?.max(1));
            loop {
                let view = fleet_status_of(&spec, &opts)?;
                print!("{view}");
                use std::io::Write as _;
                std::io::stdout().flush()?;
                if view.is_complete() {
                    break;
                }
                if view.cancel_requested {
                    println!("cancel requested — no scheduler will finish this run; stopping watch");
                    break;
                }
                std::thread::sleep(interval);
                println!("---");
            }
        }
        "cancel" => {
            let dir = opts.lease_dir();
            std::fs::create_dir_all(&dir)
                .map_err(|e| anyhow::anyhow!("create {}: {e}", dir.display()))?;
            let marker = fleet::cancel_path(&dir);
            std::fs::write(&marker, "cancelled\n")?;
            println!("cancel requested: {}", marker.display());
            println!(
                "a running scheduler kills its workers at the next poll; `fleet run` clears the marker on startup"
            );
        }
        _ => unreachable!("actions validated above"),
    }
    Ok(())
}

/// `occamy fleet gc --store ROOT [--dry-run] [SPEC..]` — compaction for
/// long-lived shared stores: sweep orphaned temp files, remove lease
/// directories of completed runs past retention, and (when spec files
/// are passed as positionals) prune config directories no spec
/// references.
fn cmd_fleet_gc(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "fleet gc",
        &["store", "dry-run", "retention-secs", "tmp-grace-secs", "prune-merged", "out"],
        64,
    )?;
    // --prune-merged: delete the shard JSONL files behind a completed
    // merge, after re-verifying the merged file from scratch. Specs name
    // the campaigns; shard/merged files live in the campaign out dir,
    // not the store, so this works with or without --store (when both
    // are given, the normal store sweep still runs below).
    if a.has("prune-merged") {
        let specs = &a.positional[1..];
        anyhow::ensure!(
            !specs.is_empty(),
            "fleet gc --prune-merged requires at least one SPEC positional (the campaign whose shards to prune)"
        );
        for path in specs {
            let spec = CampaignSpec::from_path(&PathBuf::from(path))?;
            let out_dir = a
                .flag("out")
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from("campaign-out").join(&spec.name));
            print!("{}", fleet::gc::prune_merged(&spec, &out_dir, a.has("dry-run"))?);
        }
        if !a.has("store") {
            return Ok(());
        }
    }
    let root = PathBuf::from(
        a.flag("store")
            .ok_or_else(|| anyhow::anyhow!("fleet gc requires --store DIR (the shared store root)"))?,
    );
    let mut opts = GcOptions {
        dry_run: a.has("dry-run"),
        ..GcOptions::default()
    };
    opts.retention = Duration::from_secs(a.u64_flag("retention-secs", opts.retention.as_secs())?);
    opts.tmp_grace = Duration::from_secs(a.u64_flag("tmp-grace-secs", opts.tmp_grace.as_secs())?);
    // Positionals after `gc` are the specs still in use; their config
    // fingerprints become the keep-set for pruning. No specs, no
    // pruning — "unreferenced" is unknowable without a reference list.
    let specs = &a.positional[1..];
    if !specs.is_empty() {
        let mut keep = HashSet::new();
        for path in specs {
            let spec = CampaignSpec::from_path(&PathBuf::from(path))?;
            keep.insert(campaign::store::fingerprint(&spec.config));
        }
        opts.keep_fingerprints = Some(keep);
    }
    print!("{}", fleet::gc::run(&root, &opts)?);
    Ok(())
}

/// `occamy trace <export|report|flight|serve-report>`: render recorded
/// simulation as a Perfetto/Chrome timeline, aggregate a trace store
/// into the paper's overhead decomposition, render flight-recorder
/// dumps, or rebuild interference curves from recorded serve spans —
/// no fresh measurement any way beyond the one deterministic job
/// `export` simulates.
fn cmd_trace(a: &Args) -> anyhow::Result<()> {
    let action = a.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: occamy trace <export|report|flight|serve-report> (--out FILE | --store DIR | --log FILE)"
        )
    })?;
    match action {
        "export" => cmd_trace_export(a),
        "report" => cmd_trace_report(a),
        "flight" => cmd_trace_flight(a),
        "serve-report" => cmd_trace_serve_report(a),
        other => {
            anyhow::bail!("unknown trace action {other:?} (export, report, flight or serve-report)")
        }
    }
}

/// `occamy trace export`: simulate one job and write its phase timeline
/// as Chrome trace-event JSON (host + cluster lanes); with `--batch N`,
/// add coordinator lanes — JCU slots and queue waits — for N identical
/// jobs pushed through the occupancy model.
fn cmd_trace_export(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "trace export",
        &[
            "kernel", "size", "clusters", "routine", "config", "out", "batch", "inflight", "gap",
            "spans",
        ],
        1,
    )?;
    let out = PathBuf::from(a.flag("out").ok_or_else(|| {
        anyhow::anyhow!("trace export requires --out FILE (where to write the timeline JSON)")
    })?);
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let n = a.u64_flag("clusters", 8)? as usize;
    let capacity = cfg.soc.n_clusters();
    anyhow::ensure!(
        (1..=capacity).contains(&n),
        "--clusters must be in 1..={capacity} (the SoC geometry), got {n}"
    );
    let routine = match a.flag("routine") {
        None => RoutineKind::Multicast,
        Some(r) => {
            RoutineKind::parse(r).ok_or_else(|| anyhow::anyhow!("unknown routine {r:?}"))?
        }
    };
    // Recorded spans (a serve event log or a loadgen --record file)
    // merge into the same timeline as extra lanes on the cycle axis.
    let spans = match a.flag("spans") {
        None => Vec::new(),
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read --spans {path}: {e}"))?;
            let spans = obs::span::parse_log(&text);
            anyhow::ensure!(!spans.is_empty(), "no span records in --spans {path}");
            spans
        }
    };
    let trace = sweep::run_one(&cfg, OffloadRequest::new(spec, n, routine));
    let label = format!("{kernel}:{size} c{n} {}", routine.name());
    let doc = match a.flag("batch") {
        None => obs::perfetto::job_timeline_with_spans(&label, &trace, &spans),
        Some(v) => {
            let jobs: u64 = v.parse().map_err(|e| anyhow::anyhow!("bad --batch {v:?}: {e}"))?;
            anyhow::ensure!(jobs >= 1, "--batch must be >= 1");
            let params = OccupancyParams {
                capacity,
                jcu_slots: JCU_SLOTS,
                inflight: a.u64_flag("inflight", 4)?.max(1) as usize,
                arrival_gap: a.u64_flag("gap", 0)?,
            };
            let mut model = OccupancyModel::new(params);
            let admissions: Vec<_> =
                (0..jobs).map(|_| model.admit_at(0, n, trace.total)).collect();
            model.finish();
            obs::perfetto::batch_timeline_with_spans(
                &format!("{label} x{jobs}"),
                &trace,
                &params,
                &admissions,
                &spans,
            )
        }
    };
    std::fs::write(&out, obs::perfetto::render(&doc))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", out.display()))?;
    println!(
        "trace export: {} span(s) -> {} (open in https://ui.perfetto.dev or chrome://tracing)",
        obs::perfetto::span_count(&doc),
        out.display()
    );
    Ok(())
}

/// `occamy trace report`: decode every trace a campaign/fleet/serve run
/// left in a store and print the offload-overhead decomposition
/// (end-to-end vs. critical-path execute); `--phases` adds the Fig.
/// 11-style per-phase min/avg/max bands, computed by the figure's own
/// band math.
fn cmd_trace_report(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("trace report", &["store", "phases", "csv"], 1)?;
    let root = PathBuf::from(a.flag("store").ok_or_else(|| {
        anyhow::anyhow!("trace report requires --store DIR (a campaign/serve trace store root)")
    })?);
    let entries = obs::report::scan(&root)?;
    if entries.is_empty() {
        // An empty or config-only store is a normal state (fresh daemon,
        // campaign that has not run yet) — report it, don't error.
        println!(
            "trace report: 0 traces under {} (store exists but holds no decodable request traces yet)",
            root.display()
        );
        return Ok(());
    }
    let csv = a.has("csv");
    let mut table = Table::new(
        "Offload overhead per stored request group (cycles)",
        &[
            "spec", "clusters", "routine", "traces", "total avg", "execute avg", "ovh min",
            "ovh avg", "ovh max", "ovh %",
        ],
    );
    for d in obs::report::decompose(&entries) {
        table.row(vec![
            d.spec_key.clone(),
            d.n_clusters.to_string(),
            d.routine.name().to_string(),
            d.traces.to_string(),
            format!("{:.1}", d.total_avg),
            format!("{:.1}", d.execute_avg),
            d.overhead_min.to_string(),
            format!("{:.1}", d.overhead_avg),
            d.overhead_max.to_string(),
            format!("{:.1}", d.overhead_pct()),
        ]);
    }
    emit(table, csv);
    if a.has("phases") {
        let mut bands = Table::new(
            "Per-phase cycle bands (fig11 math over the store)",
            &["spec", "clusters", "routine", "phase", "min", "avg", "max"],
        );
        for (spec_key, b) in obs::report::phase_bands(&entries) {
            bands.row(vec![
                spec_key,
                b.n_clusters.to_string(),
                b.routine.name().to_string(),
                format!("{} {}", b.phase.letter(), b.phase.name()),
                b.min.to_string(),
                format!("{:.1}", b.avg),
                b.max.to_string(),
            ]);
        }
        emit(bands, csv);
    }
    Ok(())
}

/// `occamy trace flight`: render flight-recorder dumps — either one
/// dump file (`--dump`) or every dump under a store's `flight/`
/// directory (`--store`), newest state of the last-events ring a
/// panicking or bailing process left behind.
fn cmd_trace_flight(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("trace flight", &["dump", "store"], 1)?;
    match (a.flag("dump"), a.flag("store")) {
        (Some(path), _) => {
            print!("{}", obs::flight::render_dump(Path::new(path))?);
        }
        (None, Some(root)) => {
            print!("{}", obs::flight::render_dir(&Path::new(root).join("flight"))?);
        }
        (None, None) => anyhow::bail!(
            "trace flight requires --dump FILE (one dump) or --store DIR (render <store>/flight)"
        ),
    }
    Ok(())
}

/// `occamy trace serve-report`: reassemble latency-vs-inflight
/// interference curves from a recorded serve span log. Pure
/// observation over recorded traffic — at matching (inflight, gap)
/// points the table is bit-identical to `occamy interfere`.
fn cmd_trace_serve_report(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("trace serve-report", &["log", "csv"], 1)?;
    let path = a.flag("log").ok_or_else(|| {
        anyhow::anyhow!("trace serve-report requires --log FILE (a serve event log with spans)")
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read --log {path}: {e}"))?;
    let samples = obs::curves::derive(&text)?;
    anyhow::ensure!(!samples.is_empty(), "no request spans in {path}");
    emit(exp::interference::render(&samples), a.has("csv"));
    Ok(())
}

fn cmd_sim(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("sim", &["kernel", "size", "clusters", "routine", "config"], 0)?;
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let n = a.u64_flag("clusters", 8)? as usize;
    match a.flag("routine") {
        Some(r) => {
            let routine = RoutineKind::parse(r)
                .ok_or_else(|| anyhow::anyhow!("unknown routine {r:?}"))?;
            let trace = sweep::run_one(&cfg, OffloadRequest::new(spec, n, routine));
            println!("{} {} on {n} clusters ({}):", kernel, size, routine.name());
            println!("  total: {} cycles ({} events)", trace.total, trace.events);
            for p in Phase::ALL {
                if p.is_host_phase() {
                    if let Some(d) = trace.host_duration(p) {
                        println!("  {} {:<28} {:>8} (host)", p.letter(), p.name(), d);
                    }
                } else if let Some(s) = trace.stats(p) {
                    println!(
                        "  {} {:<28} min {:>6} avg {:>8.1} max {:>6}",
                        p.letter(),
                        p.name(),
                        s.min,
                        s.avg,
                        s.max
                    );
                }
            }
        }
        None => {
            let t = sweep::triple(&cfg, &spec, n);
            println!("{kernel} {size} on {n} clusters:");
            println!("  base     : {:>8} cycles", t.base);
            println!("  ideal    : {:>8} cycles", t.ideal);
            println!("  improved : {:>8} cycles", t.improved);
            println!(
                "  overhead {} / residual {} / ideal speedup {:.2} / achieved {:.2} / restored {:.0}%",
                t.overhead(),
                t.residual_overhead(),
                t.ideal_speedup(),
                t.achieved_speedup(),
                t.restored_fraction() * 100.0
            );
        }
    }
    Ok(())
}

/// One kernel under contention: replay `--jobs` copies with the
/// jobs-in-flight window swept over `--inflight` (comma-separated), and
/// print the latency decomposition per window.
fn cmd_interfere(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "interfere",
        &["kernel", "size", "clusters", "routine", "inflight", "jobs", "gap", "csv", "config"],
        0,
    )?;
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let n = a.u64_flag("clusters", 16)? as usize;
    let capacity = cfg.soc.n_clusters();
    anyhow::ensure!(
        (1..=capacity).contains(&n),
        "--clusters must be in 1..={capacity} (the SoC geometry), got {n}"
    );
    let routine = match a.flag("routine") {
        None => RoutineKind::Multicast,
        Some(r) => {
            RoutineKind::parse(r).ok_or_else(|| anyhow::anyhow!("unknown routine {r:?}"))?
        }
    };
    let n_jobs = a.u64_flag("jobs", 16)? as usize;
    anyhow::ensure!(n_jobs >= 1, "--jobs must be >= 1");
    let gap = a.u64_flag("gap", 0)?;
    let windows: Vec<usize> = match a.flag("inflight") {
        None => vec![1, 2, 4, 8],
        Some(list) => list
            .split(',')
            .map(|w| {
                let w: usize = w
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad inflight {w:?}: {e}"))?;
                anyhow::ensure!(w >= 1, "inflight windows must be >= 1");
                Ok(w)
            })
            .collect::<anyhow::Result<_>>()?,
    };
    anyhow::ensure!(!windows.is_empty(), "--inflight must name at least one window");
    let grid = sweep::Sweep::new()
        .kernel(spec.kind().name(), spec)
        .clusters([n])
        .routines([routine])
        .inflight(windows);
    emit(
        exp::interference::render(&grid.run_interference(&cfg, n_jobs, gap)),
        a.has("csv"),
    );
    Ok(())
}

/// `occamy serve`: with `--listen`, the long-lived daemon; without it
/// (or with the explicit `--oneshot`), the original in-process batch
/// path, unchanged.
fn cmd_serve(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "serve",
        &[
            "jobs",
            "artifacts",
            "timing-only",
            "seed",
            "clusters",
            "inflight",
            "gap",
            "config",
            "listen",
            "oneshot",
            "spec",
            "queue-factor",
            "slo",
            "summary-every",
            "store",
            "log",
            "profile",
        ],
        0,
    )?;
    if let Some(listen) = a.flag("listen") {
        anyhow::ensure!(
            !a.has("oneshot"),
            "--listen and --oneshot are mutually exclusive (daemon vs batch)"
        );
        return cmd_serve_daemon(a, listen);
    }
    for f in ["spec", "queue-factor", "slo", "summary-every", "store", "log", "profile"] {
        anyhow::ensure!(!a.has(f), "--{f} applies to the daemon (`serve --listen ADDR`)");
    }
    let cfg = load_config(a)?;
    let n_jobs = a.u64_flag("jobs", 64)?;
    let seed = a.u64_flag("seed", 42)?;
    let timing_only = a.has("timing-only");
    let dir = artifacts_dir(a);
    let forced_clusters = a.flag("clusters").map(|v| v.parse::<usize>()).transpose()?;
    let inflight = a.u64_flag("inflight", 1)? as usize;
    let arrival_gap = a.u64_flag("gap", 0)?;

    let coord = Coordinator::start(
        CoordinatorConfig {
            cfg,
            timing_only,
            inflight,
            arrival_gap,
            ..Default::default()
        },
        if timing_only { None } else { Some(dir.as_path()) },
    )?;

    // A mixed trace across all six kernels at artifact-available sizes.
    let mix: Vec<JobSpec> = vec![
        JobSpec::Axpy { n: 1024 },
        JobSpec::Axpy { n: 256 },
        JobSpec::Matmul { m: 16, n: 16, k: 16 },
        JobSpec::Matmul { m: 32, n: 32, k: 32 },
        JobSpec::Atax { m: 64, n: 64 },
        JobSpec::Covariance { m: 32, n: 64 },
        JobSpec::MonteCarlo { samples: 4096 },
        JobSpec::MonteCarlo { samples: 16384 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
    ];
    let t0 = std::time::Instant::now();
    for i in 0..n_jobs {
        let spec = mix[(i as usize) % mix.len()];
        let mut req = JobRequest::new(i, spec);
        req.seed = seed.wrapping_add(i);
        if let Some(c) = forced_clusters {
            req = req.with_clusters(c);
        }
        coord.submit(req)?;
    }
    let mut failures = 0u64;
    let mut rejected = 0u64;
    for _ in 0..n_jobs {
        let r = coord
            .recv()
            .ok_or_else(|| anyhow::anyhow!("coordinator died"))?;
        if let Some(err) = &r.error {
            rejected += 1;
            eprintln!("job {} ({:?}) REJECTED: {err}", r.id, r.spec);
        } else if !r.verified {
            failures += 1;
            eprintln!("job {} ({:?}) FAILED verification", r.id, r.spec);
        }
    }
    let wall = t0.elapsed();
    let metrics = coord.shutdown();
    println!("{}", metrics.summary());
    println!(
        "wall: {:.2}s ({:.1} jobs/s), sim throughput {:.0} jobs/sim-s",
        wall.as_secs_f64(),
        n_jobs as f64 / wall.as_secs_f64(),
        metrics.jobs_per_sim_second()
    );
    anyhow::ensure!(
        failures == 0 && rejected == 0,
        "{failures} verification failure(s), {rejected} rejected job(s)"
    );
    Ok(())
}

/// The serve daemon: bind, serve sessions until a client sends
/// `shutdown`, then report final stats. Knob precedence is engine
/// defaults < `--spec serve.toml` < flags.
fn cmd_serve_daemon(a: &Args, listen: &str) -> anyhow::Result<()> {
    let spec = match a.flag("spec") {
        Some(p) => ServeSpec::load(Path::new(p))?,
        None => ServeSpec::default(),
    };
    let mut opts = spec.engine_options(EngineOptions {
        cfg: load_config(a)?,
        ..EngineOptions::default()
    });
    opts.inflight = a.u64_flag("inflight", opts.inflight as u64)? as usize;
    opts.queue_factor = a.u64_flag("queue-factor", opts.queue_factor as u64)? as usize;
    opts.default_gap = a.u64_flag("gap", opts.default_gap)?;
    opts.slo_cycles = a.u64_flag("slo", opts.slo_cycles)?;
    opts.summary_every = a.u64_flag("summary-every", opts.summary_every)?;
    if let Some(p) = profile_flag(a)? {
        opts.profile = p;
    }
    if let Some(p) = a.flag("store") {
        opts.store_root = Some(PathBuf::from(p));
    }
    // Structured event log: --log beats the spec's `log` key beats
    // OCCAMY_LOG; absent all three, logging stays off (the default).
    match a.flag("log").or(spec.serve.log.as_deref()) {
        Some(path) => obs::log::init_to_file(Path::new(path))?,
        None => obs::log::init_from_env()?,
    }
    let queue_bound = opts.inflight.saturating_mul(opts.queue_factor);
    let profile_name = opts.profile.name();
    let server = Server::start(opts, listen)?;
    println!(
        "serve: listening on {} (inflight bound {queue_bound}, profile {profile_name}; drive with `occamy loadgen --connect {}`)",
        server.addr(),
        server.addr()
    );
    let (stats, store_stats, summary) = server.wait();
    println!("{summary}");
    if let Some(st) = store_stats {
        println!(
            "store: {} memory hit(s), {} disk hit(s), {} simulation(s)",
            st.memory_hits, st.disk_hits, st.simulations
        );
    }
    println!(
        "serve: shut down after {} request(s)",
        stats.completed + stats.rejected + stats.errors
    );
    Ok(())
}

/// `occamy loadgen`: a seeded open-loop client for the serve daemon.
fn cmd_loadgen(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "loadgen",
        &[
            "connect",
            "requests",
            "seed",
            "process",
            "mean-gap",
            "burst",
            "period",
            "mix",
            "clusters",
            "routine",
            "no-stats",
            "shutdown",
            "spec",
            "metrics",
            "record",
        ],
        0,
    )?;
    let spec = match a.flag("spec") {
        Some(p) => ServeSpec::load(Path::new(p))?,
        None => ServeSpec::default(),
    };
    let mut opts = spec.loadgen_options(LoadgenOptions::default());
    if let Some(addr) = a.flag("connect") {
        opts.addr = addr.to_string();
    }
    opts.requests = a.u64_flag("requests", opts.requests)?;
    opts.seed = a.u64_flag("seed", opts.seed)?;
    if let Some(v) = a.flag("process") {
        opts.kind = ArrivalKind::parse(v).ok_or_else(|| {
            anyhow::anyhow!("unknown process {v:?} (poisson, bursty, diurnal or fixed)")
        })?;
    }
    opts.mean_gap = a.u64_flag("mean-gap", opts.mean_gap)?;
    opts.burst = a.u64_flag("burst", opts.burst)?;
    opts.period = a.u64_flag("period", opts.period)?;
    if let Some(list) = a.flag("mix") {
        opts.mix = list.split(',').map(|s| s.trim().to_string()).collect();
        for tok in &opts.mix {
            campaign::spec::parse_kernel(tok)
                .map_err(|e| anyhow::anyhow!("--mix entry {tok:?}: {e}"))?;
        }
    }
    if let Some(v) = a.flag("clusters") {
        opts.clusters = Some(v.parse()?);
    }
    if let Some(r) = a.flag("routine") {
        opts.routine =
            Some(RoutineKind::parse(r).ok_or_else(|| anyhow::anyhow!("unknown routine {r:?}"))?);
    }
    opts.fetch_stats = !a.has("no-stats");
    opts.fetch_metrics = a.has("metrics");
    if let Some(p) = a.flag("record") {
        opts.record = Some(PathBuf::from(p));
    }
    if a.has("shutdown") {
        opts.shutdown = true;
    }
    let report = serve::loadgen::run(&opts)?;
    print!("{}", report.render());
    anyhow::ensure!(report.failures == 0, "{} loadgen failure(s)", report.failures);
    Ok(())
}

/// `occamy bench <serve|des>`: the two regression benchmarks, each with
/// its own checked-in baseline JSON and `--baseline` gate.
fn cmd_bench(a: &Args) -> anyhow::Result<()> {
    let action = a.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow::anyhow!("usage: occamy bench <serve|des> [--out FILE] [--baseline FILE]")
    })?;
    match action {
        "serve" => cmd_bench_serve(a),
        "des" => cmd_bench_des(a),
        other => anyhow::bail!("unknown bench target {other:?} (expected: serve or des)"),
    }
}

/// `occamy bench serve`: benchmark the serve engine's service rate on a
/// fixed seeded burst and write `BENCH_serve.json` — the regression
/// baseline for later DES-speed work. The burst is generated once, a
/// warmup pass primes the process trace cache, and the timed iterations
/// then measure the request path (admission, scheduling, memoized
/// lookup) rather than first-run DES cost.
fn cmd_bench_serve(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "bench serve",
        &[
            "requests",
            "inflight",
            "seed",
            "mean-gap",
            "out",
            "config",
            "baseline",
            "max-regress-pct",
            "profile",
        ],
        1,
    )?;
    let cfg = load_config(a)?;
    let requests = a.u64_flag("requests", 256)?;
    anyhow::ensure!(requests >= 1, "--requests must be >= 1");
    let inflight = a.u64_flag("inflight", 4)? as usize;
    let seed = a.u64_flag("seed", 1)?;
    let mean_gap = a.u64_flag("mean-gap", 50_000)?;
    let out = a
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_serve.json"));

    // One fixed request sequence, replayed identically every iteration.
    let mix = LoadgenOptions::default().mix;
    let mut arrivals = ArrivalProcess::new(ArrivalKind::Poisson, mean_gap, 8, 4_000_000, seed);
    let submits: Vec<Submit> = (0..requests)
        .map(|id| Submit {
            id,
            kernel: mix[(id as usize) % mix.len()].clone(),
            clusters: None,
            routine: None,
            gap: Some(arrivals.next_gap()),
            seed: Some(seed.wrapping_add(id)),
            traceparent: None,
        })
        .collect();

    let opts = EngineOptions {
        cfg,
        inflight,
        profile: profile_flag(a)?.unwrap_or_default(),
        ..EngineOptions::default()
    };
    Engine::new(opts.clone())?; // validate the options once, loudly
    let mut stats = None;
    let mut bench = Bench::new();
    bench.run("serve_engine_burst", 1, 5, || {
        let mut e = Engine::new(opts.clone()).expect("options validated above");
        for s in &submits {
            occamy_offload::bench::black_box(e.handle(&Request::Submit(s.clone())));
        }
        stats = Some(e.stats());
    });
    let m = bench.results().last().expect("one measurement recorded").clone();
    let stats = stats.expect("bench ran at least once");

    let mut obj = std::collections::BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("serve".to_string()));
    obj.insert("requests".to_string(), Json::Num(requests as f64));
    obj.insert("inflight".to_string(), Json::Num(inflight as f64));
    obj.insert("seed".to_string(), Json::Num(seed as f64));
    obj.insert("mean_gap".to_string(), Json::Num(mean_gap as f64));
    obj.insert("wall_mean_s".to_string(), Json::Num(m.mean.as_secs_f64()));
    obj.insert("wall_min_s".to_string(), Json::Num(m.min.as_secs_f64()));
    obj.insert(
        "jobs_per_s".to_string(),
        Json::Num(requests as f64 / m.mean.as_secs_f64()),
    );
    obj.insert("latency_p50_cyc".to_string(), Json::Num(stats.latency.p50 as f64));
    obj.insert("latency_p99_cyc".to_string(), Json::Num(stats.latency.p99 as f64));
    obj.insert("queue_p99_cyc".to_string(), Json::Num(stats.queue.p99 as f64));
    obj.insert("completed".to_string(), Json::Num(stats.completed as f64));
    obj.insert("rejected".to_string(), Json::Num(stats.rejected as f64));
    obj.insert("profile".to_string(), Json::Str(stats.profile.clone()));
    // Simulated throughput is virtual-cycle (seed-deterministic, unlike
    // jobs_per_s); infinite throughput (all zero-cycle jobs) stays out of
    // the JSON the same way the wire protocol elides it.
    if let Some(v) = stats.jobs_per_sim_second.filter(|v| v.is_finite()) {
        obj.insert("jobs_per_sim_second".to_string(), Json::Num(v));
    }
    std::fs::write(&out, format!("{}\n", Json::Obj(obj)))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", out.display()))?;
    bench.finish("serve");
    println!("bench: wrote {}", out.display());

    // --baseline: regression gate against an earlier BENCH_serve.json.
    // p99 latency is virtual-cycle (deterministic for a fixed seed), so
    // any increase beyond the tolerance is a real scheduling/admission
    // change, not measurement noise.
    if let Some(base_path) = a.flag("baseline") {
        let max_pct: f64 = match a.flag("max-regress-pct") {
            None => 10.0,
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("bad --max-regress-pct {v:?}: {e}"))?,
        };
        anyhow::ensure!(max_pct >= 0.0, "--max-regress-pct must be >= 0");
        let text = std::fs::read_to_string(base_path)
            .map_err(|e| anyhow::anyhow!("read baseline {base_path}: {e}"))?;
        let base = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse baseline {base_path}: {e}"))?;
        let base_p99 = base
            .get("latency_p99_cyc")
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                anyhow::anyhow!("baseline {base_path} has no numeric latency_p99_cyc")
            })?;
        let now_p99 = stats.latency.p99 as f64;
        let regress_pct = if base_p99 > 0.0 {
            100.0 * (now_p99 - base_p99) / base_p99
        } else if now_p99 > 0.0 {
            f64::INFINITY
        } else {
            0.0
        };
        println!(
            "bench: p99 latency {now_p99} cyc vs baseline {base_p99} cyc ({regress_pct:+.1}%, tolerance {max_pct}%)"
        );
        anyhow::ensure!(
            regress_pct <= max_pct,
            "p99 latency regressed {regress_pct:.1}% over baseline {base_path} (tolerance {max_pct}%)"
        );
        // Simulated throughput gate: a *drop* in jobs/sim-s is the
        // regression here. Older baselines predate the key (and infinite
        // throughput is elided from the JSON) — both simply skip the gate.
        if let Some(base_tput) = base.get("jobs_per_sim_second").and_then(Json::as_f64) {
            if let Some(now_tput) = stats.jobs_per_sim_second.filter(|v| v.is_finite()) {
                let drop_pct = if base_tput > 0.0 {
                    100.0 * (base_tput - now_tput) / base_tput
                } else {
                    0.0
                };
                println!(
                    "bench: throughput {now_tput:.0} jobs/sim-s vs baseline {base_tput:.0} ({:+.1}%, tolerance {max_pct}%)",
                    -drop_pct
                );
                anyhow::ensure!(
                    drop_pct <= max_pct,
                    "jobs/sim-s dropped {drop_pct:.1}% under baseline {base_path} (tolerance {max_pct}%)"
                );
            }
        }
    }
    Ok(())
}

/// `occamy bench des`: measure the fast engine's event elision against
/// the reference DES and write `BENCH_des.json`. Each kernel of the
/// serve mix runs `--reps` times at one wide geometry: the reference
/// engine pays the full event-heap cost on every repetition, while the
/// fast engine simulates once and replays its memoized timeline, so the
/// elision speedup approaches the rep count. Every elision figure is a
/// virtual-event count — deterministic for a fixed config — and each
/// fast trace is asserted bit-identical to its reference twin before
/// anything is written; only the `*_per_s` rates are wall-clock.
fn cmd_bench_des(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown(
        "bench des",
        &["reps", "clusters", "out", "config", "baseline", "max-regress-pct"],
        1,
    )?;
    let cfg = load_config(a)?;
    let reps = a.u64_flag("reps", 8)?;
    anyhow::ensure!(reps >= 1, "--reps must be >= 1");
    let n_clusters = a.u64_flag("clusters", 32)? as usize;
    let out = a
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("BENCH_des.json"));

    // The six-kernel benchmark set at artifact-available sizes, widest
    // geometry: the configuration with the most heap traffic to elide.
    let kernels: [(&str, u64); 6] = [
        ("axpy", 1024),
        ("matmul", 32),
        ("atax", 64),
        ("covariance", 32),
        ("montecarlo", 16384),
        ("bfs", 64),
    ];
    let mut per_kernel = std::collections::BTreeMap::new();
    let mut events_reference_total = 0u64;
    let mut events_simulated_total = 0u64;
    let mut speedup_max = 0.0f64;
    let mut reference_wall = Duration::ZERO;
    let t0 = std::time::Instant::now();
    for (kernel, size) in kernels {
        let spec = job_spec(kernel, size)?;
        let req = OffloadRequest::new(spec, n_clusters, RoutineKind::Multicast);
        // Reference: the full event-heap DES, paid on every repetition.
        let t_ref = std::time::Instant::now();
        let mut reference_events = 0u64;
        let mut reference = None;
        for _ in 0..reps {
            let t = req.run_with(&cfg, SimProfile::Reference);
            reference_events += t.events;
            reference = Some(t);
        }
        reference_wall += t_ref.elapsed();
        let reference = reference.expect("reps >= 1");
        // Fast: one fresh profiled run, then memoized timeline replays.
        // The counter delta is this kernel's actual dispatch work.
        let before = fast::stats();
        let mut fast_trace = None;
        for _ in 0..reps {
            fast_trace = Some(req.run_with(&cfg, SimProfile::Fast));
        }
        let after = fast::stats();
        let fast_trace = fast_trace.expect("reps >= 1");
        anyhow::ensure!(
            fast_trace == reference,
            "fast trace diverged from reference for {kernel}:{size} at {n_clusters} clusters"
        );
        let simulated = after.events_popped - before.events_popped;
        let speedup = reference_events as f64 / simulated.max(1) as f64;
        events_reference_total += reference_events;
        events_simulated_total += simulated;
        speedup_max = speedup_max.max(speedup);
        let mut k = std::collections::BTreeMap::new();
        k.insert("cycles".to_string(), Json::Num(reference.total as f64));
        k.insert("events_reference".to_string(), Json::Num(reference_events as f64));
        k.insert("events_simulated".to_string(), Json::Num(simulated as f64));
        k.insert(
            "events_elided".to_string(),
            Json::Num(reference_events.saturating_sub(simulated) as f64),
        );
        k.insert("elision_speedup".to_string(), Json::Num(speedup));
        per_kernel.insert(kernel.to_string(), Json::Obj(k));
        println!(
            "bench: {kernel:<12} {reference_events:>8} reference events, {simulated:>6} simulated ({speedup:.1}x elided)"
        );
    }
    let wall = t0.elapsed();

    let mut obj = std::collections::BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("des".to_string()));
    obj.insert("reps".to_string(), Json::Num(reps as f64));
    obj.insert("clusters".to_string(), Json::Num(n_clusters as f64));
    obj.insert("kernels".to_string(), Json::Obj(per_kernel));
    obj.insert(
        "events_reference".to_string(),
        Json::Num(events_reference_total as f64),
    );
    obj.insert(
        "events_simulated".to_string(),
        Json::Num(events_simulated_total as f64),
    );
    obj.insert("elision_speedup_max".to_string(), Json::Num(speedup_max));
    obj.insert("wall_s".to_string(), Json::Num(wall.as_secs_f64()));
    obj.insert(
        "events_per_s".to_string(),
        Json::Num(events_reference_total as f64 / reference_wall.as_secs_f64().max(1e-9)),
    );
    obj.insert(
        "jobs_per_s".to_string(),
        Json::Num((2 * reps * kernels.len() as u64) as f64 / wall.as_secs_f64().max(1e-9)),
    );
    std::fs::write(&out, format!("{}\n", Json::Obj(obj)))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", out.display()))?;
    println!(
        "bench: wrote {} (max elision speedup {speedup_max:.1}x over {} kernels)",
        out.display(),
        kernels.len()
    );

    // --baseline: the deterministic elision speedup must not erode. Like
    // the serve gate, wall-clock rates are never compared.
    if let Some(base_path) = a.flag("baseline") {
        let max_pct: f64 = match a.flag("max-regress-pct") {
            None => 10.0,
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("bad --max-regress-pct {v:?}: {e}"))?,
        };
        anyhow::ensure!(max_pct >= 0.0, "--max-regress-pct must be >= 0");
        let text = std::fs::read_to_string(base_path)
            .map_err(|e| anyhow::anyhow!("read baseline {base_path}: {e}"))?;
        let base = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse baseline {base_path}: {e}"))?;
        let base_speedup = base
            .get("elision_speedup_max")
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                anyhow::anyhow!("baseline {base_path} has no numeric elision_speedup_max")
            })?;
        let drop_pct = if base_speedup > 0.0 {
            100.0 * (base_speedup - speedup_max) / base_speedup
        } else {
            0.0
        };
        println!(
            "bench: elision speedup {speedup_max:.1}x vs baseline {base_speedup:.1}x ({:+.1}%, tolerance {max_pct}%)",
            -drop_pct
        );
        anyhow::ensure!(
            drop_pct <= max_pct,
            "elision speedup dropped {drop_pct:.1}% under baseline {base_path} (tolerance {max_pct}%)"
        );
    }
    Ok(())
}

/// `occamy audit`: run the determinism-domain static analysis over the
/// given paths (default: the crate's own sources) and render the report.
/// With `--deny`, any finding makes the process exit nonzero — the CI
/// gate. The report is byte-deterministic: findings sorted by position,
/// `--json` rendered with sorted keys on a single line.
fn cmd_audit(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("audit", &["deny", "json", "manifest"], usize::MAX)?;
    let manifest = match a.flag("manifest") {
        None => analysis::Manifest::builtin(),
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read manifest {path}: {e}"))?;
            analysis::Manifest::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?
        }
    };
    let paths: Vec<PathBuf> = if a.positional.is_empty() {
        vec![default_audit_root()?]
    } else {
        a.positional.iter().map(PathBuf::from).collect()
    };
    let report = analysis::audit_paths(&manifest, &paths)?;
    if a.has("json") {
        print!("{}", analysis::render_json(&report));
    } else {
        print!("{}", analysis::render_text(&report));
    }
    if a.has("deny") && !report.findings.is_empty() {
        anyhow::bail!("audit --deny: {} finding(s)", report.findings.len());
    }
    Ok(())
}

/// The default audit root: the crate sources relative to the repo root
/// (`rust/src`) or to the crate directory (`src`), whichever exists.
fn default_audit_root() -> anyhow::Result<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(p);
        }
    }
    anyhow::bail!("no rust/src or src directory here; pass audit paths explicitly")
}

fn cmd_validate(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("validate-artifacts", &["artifacts"], 0)?;
    let dir = artifacts_dir(a);
    let rt = PjrtRuntime::new(&dir)?;
    println!(
        "platform {}, {} artifacts",
        rt.platform(),
        rt.manifest().entries.len()
    );
    let mut failed = 0;
    for e in rt.manifest().entries.clone() {
        let spec = spec_for_entry(&e.kernel, &e.params)?;
        match run_and_verify(&rt, &spec, 7) {
            Ok(_) => println!("  {:<24} OK", e.id),
            Err(err) => {
                failed += 1;
                println!("  {:<24} FAIL: {err:#}", e.id);
            }
        }
    }
    anyhow::ensure!(failed == 0, "{failed} artifacts failed verification");
    println!("all artifacts verified");
    Ok(())
}

fn spec_for_entry(kernel: &str, params: &HashMap<String, u64>) -> anyhow::Result<JobSpec> {
    let p = |k: &str| -> anyhow::Result<u64> {
        params
            .get(k)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("missing param {k}"))
    };
    Ok(match kernel {
        "axpy" => JobSpec::Axpy { n: p("n")? },
        "montecarlo" => JobSpec::MonteCarlo { samples: p("n")? },
        "matmul" => JobSpec::Matmul {
            m: p("m")?,
            n: p("n")?,
            k: p("k")?,
        },
        "atax" => JobSpec::Atax {
            m: p("m")?,
            n: p("n")?,
        },
        "covariance" => JobSpec::Covariance {
            m: p("m")?,
            n: p("n")?,
        },
        "bfs" => JobSpec::Bfs {
            nodes: p("n")?,
            levels: 4,
        },
        other => anyhow::bail!("unknown kernel {other:?} in manifest"),
    })
}

fn cmd_model(a: &Args) -> anyhow::Result<()> {
    a.reject_unknown("model", &["kernel", "size", "config"], 0)?;
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let model = OffloadModel::new(&cfg);
    let planner = Planner::new(&cfg);
    println!(
        "{kernel} {size}: host estimate {} cycles",
        planner.host_estimate(&spec)
    );
    println!("{:>8}  {:>10}  {:>10}  {:>8}", "clusters", "model", "sim", "err%");
    for n in planner.candidates() {
        let est = model.estimate(&spec, n);
        let sim = sweep::run_one(&cfg, OffloadRequest::new(spec, n, RoutineKind::Multicast)).total;
        println!(
            "{n:>8}  {est:>10}  {sim:>10}  {:>8.1}",
            (est as f64 - sim as f64).abs() / sim as f64 * 100.0
        );
    }
    let plan = planner.plan(&spec);
    println!(
        "planner decision: {:?} (estimate {})",
        plan.placement, plan.estimate
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Args {
        Args::parse(&list.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parse_splits_positionals_flags_and_values() {
        let a = args(&["run", "--spec", "f.toml", "--verify", "--shards", "2"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.flag("spec"), Some("f.toml"));
        assert_eq!(a.flag("shards"), Some("2"));
        assert!(a.has("verify"));
        assert!(!a.has("csv"));
    }

    #[test]
    fn boolean_flags_never_swallow_a_following_positional() {
        // `fleet gc --store ROOT --dry-run spec.toml` — the exact order
        // the usage line documents — must keep spec.toml a positional.
        let a = args(&["gc", "--store", "root", "--dry-run", "spec.toml"]);
        assert_eq!(a.positional, vec!["gc", "spec.toml"]);
        assert!(a.has("dry-run"));
        assert_eq!(a.flag("store"), Some("root"));
        let a = args(&["merge", "--verify", "out.csv"]);
        assert_eq!(a.positional, vec!["merge", "out.csv"]);
        assert!(a.has("verify"));
    }

    #[test]
    fn reject_unknown_names_the_typo_and_the_allowed_set() {
        let a = args(&["--warp", "9", "--spec", "f.toml"]);
        let err = a.reject_unknown("campaign run", &["spec"], 0);
        let err = err.unwrap_err().to_string();
        assert!(err.contains("unknown flag(s) for `campaign run`: --warp"), "{err}");
        assert!(err.contains("allowed: --spec"), "{err}");
        assert!(err.contains("usage:"), "{err}");
        // The allowed set passes.
        let a = args(&["--spec", "f.toml"]);
        a.reject_unknown("campaign run", &["spec"], 0).unwrap();
    }

    #[test]
    fn reject_unknown_catches_extra_positionals_and_serves_help() {
        let a = args(&["run", "stray"]);
        let err = a.reject_unknown("fleet", &[], 1).unwrap_err().to_string();
        assert!(err.contains("unexpected argument \"stray\""), "{err}");
        let err = args(&["--help"]).reject_unknown("sim", &[], 0).unwrap_err().to_string();
        assert!(err.starts_with("usage:"), "{err}");
    }

    #[test]
    fn every_subcommand_rejects_a_bogus_flag() {
        for cmd in [
            "experiment",
            "sim",
            "interfere",
            "serve",
            "loadgen",
            "validate-artifacts",
            "model",
            "config-dump",
        ] {
            let raw: Vec<String> = [cmd, "--definitely-bogus-flag", "1"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            let err = run(&raw).unwrap_err().to_string();
            assert!(
                err.contains("--definitely-bogus-flag"),
                "{cmd}: {err}"
            );
        }
        // campaign/fleet validate flags per action, before loading the
        // spec, so a typo'd flag is caught even without a spec file.
        for cmd in ["campaign", "fleet"] {
            for action in ["run", "status"] {
                let raw: Vec<String> = [cmd, action, "--definitely-bogus-flag", "1"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                let err = run(&raw).unwrap_err().to_string();
                assert!(err.contains("--definitely-bogus-flag"), "{cmd} {action}: {err}");
            }
        }
        let err = run(&["fleet".to_string(), "run".to_string()]).unwrap_err().to_string();
        assert!(err.contains("--spec"), "{err}");
        let err = run(&["fleet".to_string(), "frobnicate".to_string()]).unwrap_err().to_string();
        assert!(err.contains("unknown fleet action"), "{err}");
        // trace validates per-action too, and names its actions.
        for action in ["export", "report", "flight", "serve-report"] {
            let raw: Vec<String> = ["trace", action, "--definitely-bogus-flag", "1"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            let err = run(&raw).unwrap_err().to_string();
            assert!(err.contains("--definitely-bogus-flag"), "trace {action}: {err}");
        }
        let err = run(&["trace".to_string(), "frobnicate".to_string()]).unwrap_err().to_string();
        assert!(err.contains("unknown trace action"), "{err}");
        let err = run(&["trace".to_string(), "export".to_string()]).unwrap_err().to_string();
        assert!(err.contains("--out"), "{err}");
        // Each new action explains its required input when run bare.
        let err = run(&["trace".to_string(), "flight".to_string()]).unwrap_err().to_string();
        assert!(err.contains("--dump") && err.contains("--store"), "{err}");
        let err =
            run(&["trace".to_string(), "serve-report".to_string()]).unwrap_err().to_string();
        assert!(err.contains("--log"), "{err}");
        // bench validates per-target, like campaign/fleet per-action.
        let raw: Vec<String> = ["bench", "serve", "--definitely-bogus-flag", "1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = run(&raw).unwrap_err().to_string();
        assert!(err.contains("--definitely-bogus-flag"), "{err}");
        let err = run(&["bench".to_string(), "sleep".to_string()]).unwrap_err().to_string();
        assert!(err.contains("unknown bench target"), "{err}");
    }

    #[test]
    fn fleet_gc_validates_its_flags_and_requires_a_store() {
        let raw: Vec<String> = ["fleet", "gc", "--definitely-bogus-flag", "1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = run(&raw).unwrap_err().to_string();
        assert!(err.contains("--definitely-bogus-flag"), "{err}");
        let err = run(&["fleet".to_string(), "gc".to_string()]).unwrap_err().to_string();
        assert!(err.contains("--store"), "{err}");
        // --prune-merged needs a spec to know which campaign's shards
        // are up for deletion; nothing else can stand in for it.
        let raw: Vec<String> = ["fleet", "gc", "--prune-merged", "--store", "x"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = run(&raw).unwrap_err().to_string();
        assert!(err.contains("SPEC"), "{err}");
    }

    #[test]
    fn serve_daemon_and_oneshot_flags_stay_disjoint() {
        let err = run(&["serve".to_string(), "--listen".to_string(), "127.0.0.1:0".to_string(), "--oneshot".to_string()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
        // Daemon knobs on the batch path are a usage error, not a no-op.
        let err = run(&["serve".to_string(), "--oneshot".to_string(), "--slo".to_string(), "5".to_string()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--slo applies to the daemon"), "{err}");
    }
}
