//! `occamy` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   experiment <fig7|fig8|fig9|fig10|fig11|fig12|interference|all> [--csv] [--config F]
//!   campaign <run|merge|status|validate> --spec F [--shard i/N] [--out DIR]
//!   sim --kernel K --size N [--clusters C] [--routine R] [--config F]
//!   interfere --kernel K --size N [--clusters C] [--inflight LIST] [--jobs N] [--gap G]
//!   serve --jobs N [--artifacts DIR] [--timing-only] [--seed S] [--inflight W]
//!   validate-artifacts [--artifacts DIR]
//!   model --kernel K --size N [--config F]
//!   config-dump
//!
//! The binary is self-contained after `make artifacts`: python never runs
//! on the request path.

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use occamy_offload::campaign::{self, CampaignSpec, Shard, TraceStore};
use occamy_offload::config::Config;
use occamy_offload::coordinator::{Coordinator, CoordinatorConfig, JobRequest, Planner};
use occamy_offload::exp::{self, Table};
use occamy_offload::kernels::JobSpec;
use occamy_offload::model::OffloadModel;
use occamy_offload::offload::RoutineKind;
use occamy_offload::runtime::{default_artifacts_dir, run_and_verify, PjrtRuntime};
use occamy_offload::sim::Phase;
use occamy_offload::sweep::{self, OffloadRequest, SweepResults};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Tiny flag parser: positionals + `--key value` + `--flag`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(name) = args[i].strip_prefix("--") {
                let has_value = i + 1 < args.len() && !args[i + 1].starts_with("--");
                if has_value {
                    flags.insert(name.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), String::from("true"));
                    i += 1;
                }
            } else {
                positional.push(args[i].clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn u64_flag(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }
}

fn load_config(a: &Args) -> anyhow::Result<Config> {
    match a.flag("config") {
        None => Ok(Config::default()),
        Some(path) => Config::from_path(&PathBuf::from(path)),
    }
}

fn artifacts_dir(a: &Args) -> PathBuf {
    a.flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Kernel family + single size, via the campaign token grammar (one
/// mapping for the CLI and campaign specs; `matmul:S` is a cube,
/// `atax:S` square, `covariance:S` is m=S n=2S, `bfs:S` 4 levels).
fn job_spec(kernel: &str, size: u64) -> anyhow::Result<JobSpec> {
    occamy_offload::campaign::spec::parse_kernel(&format!("{kernel}:{size}"))
        .map_err(|e| anyhow::anyhow!("{e}"))
}

fn emit(table: Table, csv: bool) {
    if csv {
        print!("{}", table.to_csv());
    } else {
        println!("{}", table.render());
    }
}

const USAGE: &str = "usage: occamy <experiment|campaign|sim|interfere|serve|validate-artifacts|model|config-dump> [options]
  experiment <fig7|fig8|fig9|fig10|fig11|fig12|ablation|interference|all> [--csv] [--config F]
  campaign run      --spec F [--shard i/N] [--out DIR] [--store DIR] [--no-store]
  campaign merge    --spec F [--shards N] [--out DIR] [--verify] [--render FIG|interference] [--csv]
  campaign status   --spec F [--shards N] [--out DIR]
  campaign validate --spec F
  sim --kernel K --size N [--clusters C] [--routine baseline|multicast|mcast-only|jcu-only|ideal]
  interfere --kernel K --size N [--clusters C] [--routine R] [--inflight 1,2,4,8] [--jobs 16] [--gap 0] [--csv]
  serve --jobs N [--artifacts DIR] [--timing-only] [--seed S] [--clusters C] [--inflight W] [--gap G]
  validate-artifacts [--artifacts DIR]
  model --kernel K --size N [--config F]
  config-dump";

fn run(raw: &[String]) -> anyhow::Result<()> {
    if raw.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = raw[0].as_str();
    let a = Args::parse(&raw[1..]);
    match cmd {
        "experiment" => cmd_experiment(&a),
        "campaign" => cmd_campaign(&a),
        "sim" => cmd_sim(&a),
        "interfere" => cmd_interfere(&a),
        "serve" => cmd_serve(&a),
        "validate-artifacts" => cmd_validate(&a),
        "model" => cmd_model(&a),
        "config-dump" => {
            print!("{}", Config::default().to_toml());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_experiment(a: &Args) -> anyhow::Result<()> {
    let which = a.positional.first().map(String::as_str).unwrap_or("all");
    let cfg = load_config(a)?;
    let csv = a.has("csv");
    let mut ran = false;
    if which == "ablation" || which == "all" {
        ran = true;
        let a = exp::ablation::run(&cfg);
        emit(exp::ablation::render(&a), csv);
        emit(exp::ablation::render_port(&a), csv);
    }
    if which == "interference" || which == "all" {
        ran = true;
        emit(exp::interference::render(&exp::interference::run(&cfg)), csv);
    }
    for fig in ["fig7", "fig8", "fig9", "fig10", "fig11", "fig12"] {
        if which != "all" && which != fig {
            continue;
        }
        ran = true;
        let table = match fig {
            "fig7" => exp::fig7::render(&exp::fig7::run(&cfg)),
            "fig8" => exp::fig8::render(&exp::fig8::run(&cfg)),
            "fig9" => exp::fig9::render(&exp::fig9::run(&cfg)),
            "fig10" => exp::fig10::render(&exp::fig10::run(&cfg)),
            "fig11" => exp::fig11::render(&exp::fig11::run(&cfg)),
            "fig12" => exp::fig12::render(&exp::fig12::run(&cfg)),
            _ => unreachable!(),
        };
        emit(table, csv);
    }
    if !ran {
        anyhow::bail!("unknown experiment {which:?} (fig7..fig12, ablation, interference, or all)");
    }
    Ok(())
}

/// Render one figure from merged campaign results. The campaign must
/// cover the figure's grid (`exp::figN::sweep`) — checked up front so a
/// partial spec yields an error naming the missing points, not a panic
/// inside the render's lookups.
fn render_fig(which: &str, cfg: &Config, results: &SweepResults) -> anyhow::Result<Table> {
    let required = match which {
        "fig7" => exp::fig7::sweep(),
        "fig8" => exp::fig8::sweep(),
        "fig9" => exp::fig9::sweep(),
        "fig10" => exp::fig10::sweep(),
        "fig11" => exp::fig11::sweep(),
        "fig12" => exp::fig12::sweep(),
        other => anyhow::bail!("unknown figure {other:?} (fig7..fig12)"),
    }
    .expand();
    let missing = required
        .iter()
        .filter(|p| results.records().iter().all(|r| r.point != **p))
        .count();
    anyhow::ensure!(
        missing == 0,
        "campaign does not cover {which}: {missing} of its {} grid points are absent \
         (the spec must be a superset of exp::{which}::sweep)",
        required.len()
    );
    Ok(match which {
        "fig7" => exp::fig7::render(&exp::fig7::from_results(results)),
        "fig8" => exp::fig8::render(&exp::fig8::from_results(results)),
        "fig9" => exp::fig9::render(&exp::fig9::from_results(results)),
        "fig10" => exp::fig10::render(&exp::fig10::from_results(results)),
        "fig11" => exp::fig11::render(&exp::fig11::from_results(results)),
        "fig12" => exp::fig12::render(&exp::fig12::from_results(cfg, results)),
        _ => unreachable!("figure names validated above"),
    })
}

fn cmd_campaign(a: &Args) -> anyhow::Result<()> {
    let action = a
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("usage: occamy campaign <run|merge|status|validate> --spec FILE"))?;
    let spec_path = a
        .flag("spec")
        .ok_or_else(|| anyhow::anyhow!("campaign {action} requires --spec FILE"))?;
    let spec = CampaignSpec::from_path(&PathBuf::from(spec_path))?;
    let out_dir = a
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("campaign-out").join(&spec.name));
    match action {
        "validate" => {
            println!("{}", spec.report());
            println!("spec OK");
        }
        "run" => {
            let shard = match a.flag("shard") {
                Some(s) => Shard::parse(s)?,
                None => Shard::SINGLE,
            };
            let store = if a.has("no-store") {
                None
            } else {
                let root = a
                    .flag("store")
                    .map(PathBuf::from)
                    .unwrap_or_else(|| out_dir.join("store"));
                Some(TraceStore::open(root)?)
            };
            let report = campaign::run_shard(&spec, shard, &out_dir, store.as_ref())?;
            println!("{report}");
            if let Some(s) = &store {
                let st = s.stats();
                println!(
                    "store: {} memory hit(s), {} disk hit(s), {} simulation(s)",
                    st.memory_hits, st.disk_hits, st.simulations
                );
            }
        }
        "status" => {
            let shards = a.u64_flag("shards", 1)? as usize;
            print!("{}", campaign::status(&spec, shards, &out_dir)?);
        }
        "merge" => {
            let shards = a.u64_flag("shards", 1)? as usize;
            let results = campaign::merge(&spec, shards, &out_dir)?;
            println!(
                "merged {} points -> {}",
                results.len(),
                out_dir
                    .join(campaign::stream::merged_file_name(&spec.name))
                    .display()
            );
            if spec.interference.is_some() {
                println!(
                    "derived {} interference point(s) -> {}",
                    spec.interference_points().len(),
                    out_dir
                        .join(campaign::stream::interference_file_name(&spec.name))
                        .display()
                );
            }
            if a.has("verify") {
                let reference = campaign::run_single(&spec);
                anyhow::ensure!(
                    results == reference,
                    "merged results differ from single-process execution"
                );
                println!("verified: bit-identical to single-process execution");
            }
            if let Some(which) = a.flag("render") {
                if which == "interference" {
                    anyhow::ensure!(
                        spec.interference.is_some(),
                        "the spec has no [interference] section to render"
                    );
                    let samples: Vec<sweep::InterferenceSample> =
                        campaign::interference_records(&spec, &results)?
                            .into_iter()
                            .map(|(point, outcome)| sweep::InterferenceSample { point, outcome })
                            .collect();
                    emit(exp::interference::render(&samples), a.has("csv"));
                } else {
                    emit(render_fig(which, &spec.config, &results)?, a.has("csv"));
                }
            }
        }
        other => anyhow::bail!("unknown campaign action {other:?} (run, merge, status or validate)"),
    }
    Ok(())
}

fn cmd_sim(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let n = a.u64_flag("clusters", 8)? as usize;
    match a.flag("routine") {
        Some(r) => {
            let routine = RoutineKind::parse(r)
                .ok_or_else(|| anyhow::anyhow!("unknown routine {r:?}"))?;
            let trace = sweep::run_one(&cfg, OffloadRequest::new(spec, n, routine));
            println!("{} {} on {n} clusters ({}):", kernel, size, routine.name());
            println!("  total: {} cycles ({} events)", trace.total, trace.events);
            for p in Phase::ALL {
                if p.is_host_phase() {
                    if let Some(d) = trace.host_duration(p) {
                        println!("  {} {:<28} {:>8} (host)", p.letter(), p.name(), d);
                    }
                } else if let Some(s) = trace.stats(p) {
                    println!(
                        "  {} {:<28} min {:>6} avg {:>8.1} max {:>6}",
                        p.letter(),
                        p.name(),
                        s.min,
                        s.avg,
                        s.max
                    );
                }
            }
        }
        None => {
            let t = sweep::triple(&cfg, &spec, n);
            println!("{kernel} {size} on {n} clusters:");
            println!("  base     : {:>8} cycles", t.base);
            println!("  ideal    : {:>8} cycles", t.ideal);
            println!("  improved : {:>8} cycles", t.improved);
            println!(
                "  overhead {} / residual {} / ideal speedup {:.2} / achieved {:.2} / restored {:.0}%",
                t.overhead(),
                t.residual_overhead(),
                t.ideal_speedup(),
                t.achieved_speedup(),
                t.restored_fraction() * 100.0
            );
        }
    }
    Ok(())
}

/// One kernel under contention: replay `--jobs` copies with the
/// jobs-in-flight window swept over `--inflight` (comma-separated), and
/// print the latency decomposition per window.
fn cmd_interfere(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let n = a.u64_flag("clusters", 16)? as usize;
    let capacity = cfg.soc.n_clusters();
    anyhow::ensure!(
        (1..=capacity).contains(&n),
        "--clusters must be in 1..={capacity} (the SoC geometry), got {n}"
    );
    let routine = match a.flag("routine") {
        None => RoutineKind::Multicast,
        Some(r) => {
            RoutineKind::parse(r).ok_or_else(|| anyhow::anyhow!("unknown routine {r:?}"))?
        }
    };
    let n_jobs = a.u64_flag("jobs", 16)? as usize;
    anyhow::ensure!(n_jobs >= 1, "--jobs must be >= 1");
    let gap = a.u64_flag("gap", 0)?;
    let windows: Vec<usize> = match a.flag("inflight") {
        None => vec![1, 2, 4, 8],
        Some(list) => list
            .split(',')
            .map(|w| {
                let w: usize = w
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad inflight {w:?}: {e}"))?;
                anyhow::ensure!(w >= 1, "inflight windows must be >= 1");
                Ok(w)
            })
            .collect::<anyhow::Result<_>>()?,
    };
    anyhow::ensure!(!windows.is_empty(), "--inflight must name at least one window");
    let grid = sweep::Sweep::new()
        .kernel(spec.kind().name(), spec)
        .clusters([n])
        .routines([routine])
        .inflight(windows);
    emit(
        exp::interference::render(&grid.run_interference(&cfg, n_jobs, gap)),
        a.has("csv"),
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a)?;
    let n_jobs = a.u64_flag("jobs", 64)?;
    let seed = a.u64_flag("seed", 42)?;
    let timing_only = a.has("timing-only");
    let dir = artifacts_dir(a);
    let forced_clusters = a.flag("clusters").map(|v| v.parse::<usize>()).transpose()?;
    let inflight = a.u64_flag("inflight", 1)? as usize;
    let arrival_gap = a.u64_flag("gap", 0)?;

    let coord = Coordinator::start(
        CoordinatorConfig {
            cfg,
            timing_only,
            inflight,
            arrival_gap,
            ..Default::default()
        },
        if timing_only { None } else { Some(dir.as_path()) },
    )?;

    // A mixed trace across all six kernels at artifact-available sizes.
    let mix: Vec<JobSpec> = vec![
        JobSpec::Axpy { n: 1024 },
        JobSpec::Axpy { n: 256 },
        JobSpec::Matmul { m: 16, n: 16, k: 16 },
        JobSpec::Matmul { m: 32, n: 32, k: 32 },
        JobSpec::Atax { m: 64, n: 64 },
        JobSpec::Covariance { m: 32, n: 64 },
        JobSpec::MonteCarlo { samples: 4096 },
        JobSpec::MonteCarlo { samples: 16384 },
        JobSpec::Bfs { nodes: 64, levels: 4 },
    ];
    let t0 = std::time::Instant::now();
    for i in 0..n_jobs {
        let spec = mix[(i as usize) % mix.len()];
        let mut req = JobRequest::new(i, spec);
        req.seed = seed.wrapping_add(i);
        if let Some(c) = forced_clusters {
            req = req.with_clusters(c);
        }
        coord.submit(req)?;
    }
    let mut failures = 0u64;
    let mut rejected = 0u64;
    for _ in 0..n_jobs {
        let r = coord
            .recv()
            .ok_or_else(|| anyhow::anyhow!("coordinator died"))?;
        if let Some(err) = &r.error {
            rejected += 1;
            eprintln!("job {} ({:?}) REJECTED: {err}", r.id, r.spec);
        } else if !r.verified {
            failures += 1;
            eprintln!("job {} ({:?}) FAILED verification", r.id, r.spec);
        }
    }
    let wall = t0.elapsed();
    let metrics = coord.shutdown();
    println!("{}", metrics.summary());
    println!(
        "wall: {:.2}s ({:.1} jobs/s), sim throughput {:.0} jobs/sim-s",
        wall.as_secs_f64(),
        n_jobs as f64 / wall.as_secs_f64(),
        metrics.jobs_per_sim_second()
    );
    anyhow::ensure!(
        failures == 0 && rejected == 0,
        "{failures} verification failure(s), {rejected} rejected job(s)"
    );
    Ok(())
}

fn cmd_validate(a: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(a);
    let rt = PjrtRuntime::new(&dir)?;
    println!(
        "platform {}, {} artifacts",
        rt.platform(),
        rt.manifest().entries.len()
    );
    let mut failed = 0;
    for e in rt.manifest().entries.clone() {
        let spec = spec_for_entry(&e.kernel, &e.params)?;
        match run_and_verify(&rt, &spec, 7) {
            Ok(_) => println!("  {:<24} OK", e.id),
            Err(err) => {
                failed += 1;
                println!("  {:<24} FAIL: {err:#}", e.id);
            }
        }
    }
    anyhow::ensure!(failed == 0, "{failed} artifacts failed verification");
    println!("all artifacts verified");
    Ok(())
}

fn spec_for_entry(kernel: &str, params: &HashMap<String, u64>) -> anyhow::Result<JobSpec> {
    let p = |k: &str| -> anyhow::Result<u64> {
        params
            .get(k)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("missing param {k}"))
    };
    Ok(match kernel {
        "axpy" => JobSpec::Axpy { n: p("n")? },
        "montecarlo" => JobSpec::MonteCarlo { samples: p("n")? },
        "matmul" => JobSpec::Matmul {
            m: p("m")?,
            n: p("n")?,
            k: p("k")?,
        },
        "atax" => JobSpec::Atax {
            m: p("m")?,
            n: p("n")?,
        },
        "covariance" => JobSpec::Covariance {
            m: p("m")?,
            n: p("n")?,
        },
        "bfs" => JobSpec::Bfs {
            nodes: p("n")?,
            levels: 4,
        },
        other => anyhow::bail!("unknown kernel {other:?} in manifest"),
    })
}

fn cmd_model(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a)?;
    let kernel = a.flag("kernel").unwrap_or("axpy");
    let size = a.u64_flag("size", 1024)?;
    let spec = job_spec(kernel, size)?;
    let model = OffloadModel::new(&cfg);
    let planner = Planner::new(&cfg);
    println!(
        "{kernel} {size}: host estimate {} cycles",
        planner.host_estimate(&spec)
    );
    println!("{:>8}  {:>10}  {:>10}  {:>8}", "clusters", "model", "sim", "err%");
    for n in planner.candidates() {
        let est = model.estimate(&spec, n);
        let sim = sweep::run_one(&cfg, OffloadRequest::new(spec, n, RoutineKind::Multicast)).total;
        println!(
            "{n:>8}  {est:>10}  {sim:>10}  {:>8.1}",
            (est as f64 - sim as f64).abs() / sim as f64 * 100.0
        );
    }
    let plan = planner.plan(&spec);
    println!(
        "planner decision: {:?} (estimate {})",
        plan.placement, plan.estimate
    );
    Ok(())
}
