//! # Sharded, resumable experiment campaigns (`campaign`)
//!
//! [`crate::sweep`] made experiment grids typed and parallel within one
//! process; this subsystem scales the same grids across processes and
//! makes them survive kills. A campaign is a TOML spec
//! ([`CampaignSpec`]) — kernels × sizes × clusters × routines plus
//! `[soc]`/`[timing]` config overrides — that any number of independent
//! shard processes execute cooperatively:
//!
//! * [`Shard`] — a deterministic round-robin partition of the campaign's
//!   global point list (`--shard i/N`); shards agree on the split
//!   without coordination.
//! * [`run_shard`] — executes one shard on a scoped worker pool (the
//!   same drain-an-atomic-counter shape as `sweep`'s executor, hand-held
//!   here because it additionally **streams** each finished point as a
//!   self-contained JSONL line the moment it completes), **resuming** by
//!   skipping points already present in the shard's output file (torn
//!   tails from a kill are dropped and re-run).
//! * [`TraceStore`] — a persistent, content-addressed on-disk trace
//!   store keyed by `(config fingerprint, request)`, layered under the
//!   process-wide `sweep::cache`, so repeated runs and sibling shards
//!   reuse traces across processes; corrupt files re-simulate.
//! * [`merge`] — recombines shard outputs into a [`SweepResults`]
//!   **bit-identical** to single-process execution
//!   (property-tested in `tests/integration_campaign.rs`), ready for the
//!   `exp::fig*::from_results` constructors.
//! * `[interference]` — an optional contention axis: merge derives
//!   latency-vs-jobs-in-flight curves ([`interference_records`]) from
//!   the merged traces through the coordinator's occupancy model and
//!   writes them to `<name>.interference.jsonl`. The trace grid — and
//!   so sharding, resume and merge — is untouched: isolated traces are
//!   contention-independent, and the schedule on top of them is
//!   deterministic.
//!
//! CLI: `occamy campaign <run|merge|status|validate>`; quickstart:
//! `examples/campaign_demo.rs` + `examples/campaign.toml`. The
//! [`crate::fleet`] scheduler sits on top of this module: it launches
//! `campaign run --shard i/N` workers, watches their heartbeat leases,
//! and auto-merges when the last shard lands.

pub(crate) mod codec;
pub mod shard;
pub mod spec;
pub mod store;
pub mod stream;

pub use shard::Shard;
pub use spec::{CampaignSpec, FleetSpec, HostSpec, InterferenceSpec, SpecReport};
pub use store::{StoreStats, TraceStore};

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::Trace;
use crate::sweep::{
    cache, InterferenceOutcome, InterferencePoint, OffloadRequest, SweepPoint, SweepRecord,
    SweepResults,
};

/// Outcome of one [`run_shard`] invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardReport {
    pub shard: Shard,
    /// Global campaign size.
    pub total_points: usize,
    /// Points this shard owns.
    pub owned: usize,
    /// Owned points already complete in the output file (resume).
    pub resumed: usize,
    /// Points executed by this invocation.
    pub executed: usize,
    /// Corrupt lines dropped from a previous (killed) run.
    pub dropped: usize,
    /// The shard's output file.
    pub output: PathBuf,
}

impl ShardReport {
    /// Whether every owned point is now in the output file. Only a
    /// `max_points` cap (see [`run_shard_limited`]) can leave this
    /// false — an uncapped run either finishes or errors.
    pub fn is_complete(&self) -> bool {
        self.resumed + self.executed >= self.owned
    }
}

impl std::fmt::Display for ShardReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "shard {}: {} of {} points owned, {} resumed, {} executed{} -> {}",
            self.shard,
            self.owned,
            self.total_points,
            self.resumed,
            self.executed,
            if self.dropped > 0 {
                format!(", {} corrupt line(s) dropped", self.dropped)
            } else {
                String::new()
            },
            self.output.display()
        )
    }
}

/// Completion state of one shard (for [`status`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStatus {
    pub shard: Shard,
    pub owned: usize,
    pub done: usize,
    pub dropped: usize,
    /// Done points whose stream line is labelled as freshly simulated.
    pub sims: usize,
    /// Done points labelled as store/cache hits. `sims + hits` can be
    /// less than `done` for files written before source labels existed.
    pub hits: usize,
}

impl ShardStatus {
    /// One-line progress summary — the single renderer behind both
    /// `occamy campaign status` and `occamy fleet status` (the fleet
    /// view appends lease state to it).
    pub fn summary(&self) -> String {
        let mut line = format!("shard {}: {}/{} done", self.shard, self.done, self.owned);
        if self.done > 0 {
            line.push_str(&format!(
                " ({} simulated, {} store/cache hit(s))",
                self.sims, self.hits
            ));
        }
        if self.dropped > 0 {
            line.push_str(&format!(", {} corrupt line(s)", self.dropped));
        }
        line
    }
}

/// Completion state of a whole campaign's shard set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CampaignStatus {
    pub total_points: usize,
    pub shards: Vec<ShardStatus>,
}

impl CampaignStatus {
    pub fn done(&self) -> usize {
        self.shards.iter().map(|s| s.done).sum()
    }

    pub fn is_complete(&self) -> bool {
        self.done() == self.total_points
    }
}

impl std::fmt::Display for CampaignStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "{} of {} points complete{}",
            self.done(),
            self.total_points,
            if self.is_complete() { " — ready to merge" } else { "" }
        )?;
        for s in &self.shards {
            writeln!(f, "  {}", s.summary())?;
        }
        Ok(())
    }
}

/// Execute the whole campaign in-process — the single-process reference
/// shard-merge must match bit-identically.
pub fn run_single(spec: &CampaignSpec) -> SweepResults {
    spec.to_sweep().run(&spec.config)
}

/// Check a restored record against the campaign's expansion; a mismatch
/// means the output file belongs to a different spec. Crate-visible so
/// `fleet gc --prune-merged` can re-verify a merged file before deleting
/// the shards behind it.
pub(crate) fn check_point(points: &[SweepPoint], index: usize, rec: &SweepRecord, path: &Path) -> anyhow::Result<()> {
    let expected = points.get(index).ok_or_else(|| {
        anyhow::anyhow!(
            "{}: point index {index} out of range ({} points) — output from a different spec?",
            path.display(),
            points.len()
        )
    })?;
    anyhow::ensure!(
        rec.point == *expected,
        "{}: point {index} is {:?}, spec expands to {:?} — output from a different spec?",
        path.display(),
        rec.point,
        expected
    );
    Ok(())
}

/// Execute one shard of a campaign, streaming results to
/// `<out_dir>/<name>.shard-<i>-of-<N>.jsonl` and resuming from any
/// points already in that file. `store` layers the persistent trace
/// store under the in-process cache (pass `None` for cache-only runs).
pub fn run_shard(
    spec: &CampaignSpec,
    shard: Shard,
    out_dir: &Path,
    store: Option<&TraceStore>,
) -> anyhow::Result<ShardReport> {
    run_shard_limited(spec, shard, out_dir, store, None)
}

/// [`run_shard`] with an execution budget: at most `max_points` of the
/// shard's remaining points run this invocation (`--max-points` on the
/// CLI). Useful for time-boxed scavenging runs, and the fleet
/// scheduler's chaos injection uses it to rehearse crash recovery — a
/// capped run leaves [`ShardReport::is_complete`] false and the CLI
/// exits nonzero, exactly like a worker killed mid-shard.
pub fn run_shard_limited(
    spec: &CampaignSpec,
    shard: Shard,
    out_dir: &Path,
    store: Option<&TraceStore>,
    max_points: Option<usize>,
) -> anyhow::Result<ShardReport> {
    let cfg = &spec.config;
    // Profile-aware memory key (the disk fingerprint stays profile-free:
    // persisted traces are verified before they land).
    let mem_key = cache::profiled_config_key(cfg, spec.profile);
    let fp = store::fingerprint(cfg);
    let points = spec.expand();
    let owned = shard.indices(points.len());
    std::fs::create_dir_all(out_dir)
        .map_err(|e| anyhow::anyhow!("create {}: {e}", out_dir.display()))?;
    let output = out_dir.join(stream::shard_file_name(&spec.name, shard));

    // Resume: collect completed points (written under the same config
    // fingerprint — read_shard rejects stale files), drop torn tails,
    // and rewrite the file to contain exactly the valid records before
    // appending.
    let shard_file = stream::read_shard(&output, &fp)?;
    let (done, sources, dropped) = (shard_file.records, shard_file.sources, shard_file.dropped);
    for (&index, rec) in &done {
        anyhow::ensure!(
            shard.owns(index),
            "{}: contains point {index} owned by another shard — output from a different split?",
            output.display()
        );
        check_point(&points, index, rec, &output)?;
    }
    if dropped > 0 {
        let tmp = output.with_extension("jsonl.tmp");
        let mut text = String::new();
        for (&index, rec) in &done {
            text.push_str(&stream::line_of_sourced(&fp, index, rec, sources.get(&index).copied()));
            text.push('\n');
        }
        std::fs::write(&tmp, text)?;
        std::fs::rename(&tmp, &output)?;
    }
    let mut todo: Vec<usize> = owned.iter().copied().filter(|i| !done.contains_key(i)).collect();
    if let Some(cap) = max_points {
        todo.truncate(cap);
    }
    if crate::obs::log::enabled() {
        crate::obs::log::emit(
            &crate::obs::log::Event::wall("campaign", "shard_start")
                .str("campaign", &spec.name)
                .str("shard", &shard.to_string())
                .u64("owned", owned.len() as u64)
                .u64("resumed", done.len() as u64)
                .u64("todo", todo.len() as u64),
        );
    }

    let file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&output)?;
    let writer = Mutex::new(std::io::BufWriter::new(file));
    let failure: Mutex<Option<String>> = Mutex::new(None);

    let run_point = |req: OffloadRequest| -> (Arc<Trace>, stream::Source) {
        match store {
            Some(s) => s.run_sourced_profiled(&fp, &mem_key, cfg, req, spec.profile),
            None => match cache::peek(&mem_key, req) {
                Some(t) => (t, stream::Source::Mem),
                None => (
                    cache::insert(&mem_key, req, Arc::new(req.run_with(cfg, spec.profile))),
                    stream::Source::Sim,
                ),
            },
        }
    };
    let record_one = |i: usize| -> Result<(), String> {
        let point = points[i];
        let (trace, source) = run_point(point.req);
        let line = stream::line_of_sourced(&fp, i, &SweepRecord { point, trace }, Some(source));
        let mut w = writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        // Flush per line so a killed shard keeps every finished point.
        writeln!(w, "{line}").and_then(|_| w.flush()).map_err(|e| e.to_string())
    };

    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(todo.len());
    if workers <= 1 {
        for &i in &todo {
            record_one(i).map_err(|e| anyhow::anyhow!("write {}: {e}", output.display()))?;
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // ordering: Relaxed — the RMW atomicity alone hands
                    // each worker a unique task index; results go
                    // through the writer mutex, not this counter.
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= todo.len() {
                        break;
                    }
                    let mut fail = failure.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    if fail.is_some() {
                        break;
                    }
                    drop(fail);
                    if let Err(e) = record_one(todo[t]) {
                        fail = failure.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                        fail.get_or_insert(e);
                        break;
                    }
                });
            }
        });
        if let Some(e) = failure.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner) {
            anyhow::bail!("write {}: {e}", output.display());
        }
    }

    if crate::obs::log::enabled() {
        crate::obs::log::emit(
            &crate::obs::log::Event::wall("campaign", "shard_complete")
                .str("campaign", &spec.name)
                .str("shard", &shard.to_string())
                .u64("executed", todo.len() as u64),
        );
    }
    Ok(ShardReport {
        shard,
        total_points: points.len(),
        owned: owned.len(),
        resumed: done.len(),
        executed: todo.len(),
        dropped,
        output,
    })
}

/// Read every shard's output and report completion without executing
/// anything. Applies the same spec checks as [`run_shard`]/[`merge`],
/// so stale files from a different grid error out instead of being
/// counted as done.
pub fn status(spec: &CampaignSpec, shard_count: usize, out_dir: &Path) -> anyhow::Result<CampaignStatus> {
    anyhow::ensure!(shard_count > 0, "shard count must be positive");
    let fp = store::fingerprint(&spec.config);
    let points = spec.expand();
    let total = points.len();
    let shards = (0..shard_count)
        .map(|i| {
            let shard = Shard::new(i, shard_count)?;
            let path = out_dir.join(stream::shard_file_name(&spec.name, shard));
            let file = stream::read_shard(&path, &fp)?;
            for (&index, rec) in &file.records {
                check_point(&points, index, rec, &path)?;
            }
            Ok(ShardStatus {
                shard,
                owned: shard.indices(total).len(),
                done: file.records.len(),
                dropped: file.dropped,
                sims: file.sims(),
                hits: file.hits(),
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(CampaignStatus {
        total_points: total,
        shards,
    })
}

/// Outcome of one [`merge_report`] pass: the merged results plus the
/// source-label tallies gathered from the same read of the shard files
/// (fresh simulations vs. store/cache hits, summed over every attempt
/// of every shard — the fleet summary line prints them).
#[derive(Debug, Clone)]
pub struct MergeReport {
    pub results: SweepResults,
    pub sims: usize,
    pub hits: usize,
}

/// Recombine the outputs of an N-way shard split into input-ordered
/// [`SweepResults`] bit-identical to [`run_single`], writing the merged
/// stream to `<out_dir>/<name>.merged.jsonl`. Fails (naming the missing
/// counts per shard) unless every point is present.
pub fn merge(spec: &CampaignSpec, shard_count: usize, out_dir: &Path) -> anyhow::Result<SweepResults> {
    Ok(merge_report(spec, shard_count, out_dir)?.results)
}

/// [`merge`], also reporting the shard files' source-label tallies —
/// one pass over the (trace-heavy) JSONL serves both.
pub fn merge_report(
    spec: &CampaignSpec,
    shard_count: usize,
    out_dir: &Path,
) -> anyhow::Result<MergeReport> {
    anyhow::ensure!(shard_count > 0, "shard count must be positive");
    let fp = store::fingerprint(&spec.config);
    let points = spec.expand();
    let mut collected: BTreeMap<usize, SweepRecord> = BTreeMap::new();
    let (mut sims, mut hits) = (0usize, 0usize);
    for i in 0..shard_count {
        let shard = Shard::new(i, shard_count)?;
        let path = out_dir.join(stream::shard_file_name(&spec.name, shard));
        let file = stream::read_shard(&path, &fp)?;
        sims += file.sims();
        hits += file.hits();
        for (index, rec) in file.records {
            check_point(&points, index, &rec, &path)?;
            collected.entry(index).or_insert(rec);
        }
    }
    if collected.len() != points.len() {
        let st = status(spec, shard_count, out_dir)?;
        let missing: Vec<String> = st
            .shards
            .iter()
            .filter(|s| s.done < s.owned)
            .map(|s| format!("shard {} has {}/{}", s.shard, s.done, s.owned))
            .collect();
        anyhow::bail!(
            "campaign incomplete: {}/{} points present ({}); re-run the missing shards",
            collected.len(),
            points.len(),
            missing.join(", ")
        );
    }
    let merged_path = out_dir.join(stream::merged_file_name(&spec.name));
    let mut text = String::new();
    for (&index, rec) in &collected {
        text.push_str(&stream::line_of(&fp, index, rec));
        text.push('\n');
    }
    std::fs::write(&merged_path, text)?;
    let results = SweepResults::new(collected.into_values().collect());
    // Contention axis: derived deterministically from the merged traces
    // (no extra simulation, no extra sharding), one JSONL line per
    // (point, inflight).
    if spec.interference.is_some() {
        let records = interference_records(spec, &results)?;
        let mut text = String::new();
        for (point, outcome) in &records {
            text.push_str(&stream::interference_line_of(&fp, point, outcome));
            text.push('\n');
        }
        std::fs::write(out_dir.join(stream::interference_file_name(&spec.name)), text)?;
    }
    Ok(MergeReport { results, sims, hits })
}

/// Schedule the campaign's `[interference]` axis over already-merged
/// trace results: each interference point replays its request through
/// the coordinator's occupancy model using the merged isolated runtime.
/// Deterministic given the results; fails if a point's trace is absent
/// (merge guarantees completeness, so this only trips on foreign
/// results).
pub fn interference_records(
    spec: &CampaignSpec,
    results: &SweepResults,
) -> anyhow::Result<Vec<(InterferencePoint, InterferenceOutcome)>> {
    spec.interference_points()
        .into_iter()
        .map(|point| {
            let isolated = results
                .isolated_total(point.label, point.ireq.req)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no merged trace for interference point {:?} — results from a different spec?",
                        point.ireq.req
                    )
                })?;
            Ok((point, point.ireq.run_on(&spec.config, isolated)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec(name: &str, gap: u64) -> CampaignSpec {
        // A unique timing override per test keeps the process-wide cache
        // and store namespaces disjoint across parallel tests.
        CampaignSpec::parse(&format!(
            "[campaign]\nname = \"{name}\"\n[grid]\nkernels = [\"axpy:96\", \"atax:16\"]\nclusters = [1, 4]\n\
             routines = [\"baseline\", \"ideal\"]\n[timing]\nhost_ipi_issue_gap = {gap}\n"
        ))
        .unwrap()
    }

    fn temp_out(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("occamy-campaign-mod-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn two_shards_merge_to_the_single_process_results() {
        let spec = demo_spec("unit-two-shards", 31);
        let out = temp_out("two-shards");
        for i in 0..2 {
            let report = run_shard(&spec, Shard::new(i, 2).unwrap(), &out, None).unwrap();
            assert_eq!(report.executed, report.owned);
            assert_eq!(report.resumed, 0);
        }
        let merged = merge(&spec, 2, &out).unwrap();
        assert_eq!(merged, run_single(&spec));
        assert!(out.join(stream::merged_file_name(&spec.name)).exists());
    }

    #[test]
    fn merge_refuses_incomplete_campaigns() {
        let spec = demo_spec("unit-incomplete", 32);
        let out = temp_out("incomplete");
        run_shard(&spec, Shard::new(0, 2).unwrap(), &out, None).unwrap();
        let err = merge(&spec, 2, &out).unwrap_err().to_string();
        assert!(err.contains("incomplete"), "{err}");
        assert!(err.contains("shard 1/2"), "{err}");
        let st = status(&spec, 2, &out).unwrap();
        assert!(!st.is_complete());
        assert_eq!(st.done(), st.shards[0].owned);
    }

    #[test]
    fn rerunning_a_complete_shard_resumes_everything() {
        let spec = demo_spec("unit-resume", 33);
        let out = temp_out("resume");
        let shard = Shard::SINGLE;
        let first = run_shard(&spec, shard, &out, None).unwrap();
        assert_eq!(first.executed, first.owned);
        let second = run_shard(&spec, shard, &out, None).unwrap();
        assert_eq!(second.executed, 0);
        assert_eq!(second.resumed, second.owned);
        let merged = merge(&spec, 1, &out).unwrap();
        assert_eq!(merged, run_single(&spec));
    }

    #[test]
    fn interference_campaigns_shard_and_merge_like_any_other() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"unit-interfere\"\n[grid]\nkernels = [\"axpy:512\"]\nclusters = [16]\n\
             routines = [\"multicast\"]\n[timing]\nhost_ipi_issue_gap = 36\n\
             [interference]\njobs_in_flight = [1, 4]\njobs = 8\n",
        )
        .unwrap();
        let out = temp_out("interfere");
        for i in 0..2 {
            run_shard(&spec, Shard::new(i, 2).unwrap(), &out, None).unwrap();
        }
        let merged = merge(&spec, 2, &out).unwrap();
        assert_eq!(merged, run_single(&spec));
        // Merge wrote the derived contention curves next to the traces.
        let ipath = out.join(stream::interference_file_name(&spec.name));
        let fp = store::fingerprint(&spec.config);
        let records = stream::read_interference(&ipath, &fp).unwrap();
        assert_eq!(records.len(), 2);
        let serial = &records[0];
        assert_eq!(serial.0.ireq.inflight, 1);
        assert_eq!(serial.1.total_queue_delay(), 0, "serial reference");
        assert_eq!(
            serial.1.isolated,
            merged.records()[0].total(),
            "service time is the merged isolated trace"
        );
        let contended = &records[1];
        assert_eq!(contended.0.ireq.inflight, 4);
        assert!(contended.1.total_queue_delay() > 0);
        // And the records match an in-process derivation exactly.
        assert_eq!(records, interference_records(&spec, &merged).unwrap());
    }

    #[test]
    fn foreign_output_files_are_detected() {
        let a = demo_spec("unit-foreign", 34);
        let out = temp_out("foreign");
        run_shard(&a, Shard::SINGLE, &out, None).unwrap();
        // Same config, different grid: caught by the point check.
        let mut b = demo_spec("unit-foreign", 34);
        b.kernels.reverse();
        let err = run_shard(&b, Shard::SINGLE, &out, None).unwrap_err().to_string();
        assert!(err.contains("different spec"), "{err}");
        // Same grid, different [timing]: caught by the fingerprint check.
        let c = demo_spec("unit-foreign", 35);
        let err = run_shard(&c, Shard::SINGLE, &out, None).unwrap_err().to_string();
        assert!(err.contains("[soc]/[timing]"), "{err}");
    }
}
