//! Declarative TOML campaign specs.
//!
//! A campaign is the on-disk form of a [`crate::sweep::Sweep`]: a
//! cartesian grid of kernels × cluster counts × routines, plus the
//! config the grid runs on — including non-default SoC geometries and
//! timing ablations as first-class `[soc]`/`[timing]` override sections
//! (reusing `Config::set_field`, the same vendored-parser approach as
//! `Config::from_toml`). Every parse error names the offending line so
//! malformed specs fail fast (`occamy campaign validate`).
//!
//! ```toml
//! [campaign]
//! name = "fig7-small"
//! profile = "reference"      # optional engine profile ("fast" opts in)
//!
//! [grid]
//! kernels = ["axpy:1024", "atax:64x64"]
//! clusters = [1, 8, 32]
//! routines = ["baseline", "ideal", "multicast"]  # optional: triple default
//!
//! [soc]                      # optional geometry overrides
//! n_quadrants = 2
//!
//! [timing]                   # optional timing overrides
//! host_ipi_issue_gap = 20
//!
//! [interference]             # optional contention axis
//! jobs_in_flight = [1, 2, 4] # windows to sweep (1 = serial reference)
//! jobs = 16                  # jobs replayed per point (default 16)
//! arrival_gap = 0            # cycles between arrivals (default 0)
//!
//! [fleet]                    # optional scheduler defaults (occamy fleet)
//! workers = 3                # shard count / concurrent workers (default 2)
//! lease_ttl = 30             # seconds without a heartbeat => stale (default 30)
//! max_restarts = 2           # relaunches per shard before giving up (default 2)
//! # Multi-host: non-empty `hosts` makes `occamy fleet run` fan shards
//! # out over SSH against the shared mount instead of spawning local
//! # subprocesses. Each entry is "host" optionally followed by
//! # space-separated attributes: `bin=` (remote occamy binary, overriding
//! # remote_bin) and `root=` (what this host mounts `local_root` as —
//! # every task path under local_root is rewritten with that prefix).
//! hosts = ["alpha", "beta bin=/opt/occamy root=/data/shared"]
//! remote_bin = "occamy"      # default remote binary (default "occamy")
//! local_root = "/mnt/shared" # local prefix the per-host root= replaces
//! ```

use std::collections::HashSet;

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::RoutineKind;
use crate::sim::SimProfile;
use crate::sweep::{InterferencePoint, Sweep, SweepPoint};

/// A parsed campaign: grid axes plus the fully-resolved config.
#[derive(Debug, Clone, PartialEq)]
pub struct CampaignSpec {
    /// Campaign name (`[campaign] name`); names output files.
    pub name: String,
    /// Kernel grid axis, in spec order. Labels are the kernel family
    /// names (`KernelKind::name`), so one family may appear at several
    /// problem sizes (Fig. 10 style).
    pub kernels: Vec<JobSpec>,
    /// Cluster-count axis.
    pub clusters: Vec<usize>,
    /// Routine axis; empty means the base/ideal/improved triple.
    pub routines: Vec<RoutineKind>,
    /// The config the whole grid runs on (defaults + `[soc]`/`[timing]`
    /// overrides).
    pub config: Config,
    /// Engine profile (`[campaign] profile`, default `"reference"`).
    /// `"fast"` runs the grid on the elision/memoization engine — the
    /// bit-identity harness guarantees equal traces, and the store only
    /// persists fast-path traces after verifying them against a
    /// reference run.
    pub profile: SimProfile,
    /// Contention axis (`[interference]`): when present, merge
    /// additionally derives latency-vs-inflight curves from the merged
    /// traces. The trace grid itself — and therefore sharding, resume
    /// and merge — is unaffected: isolated traces are
    /// contention-independent.
    pub interference: Option<InterferenceSpec>,
    /// Scheduler defaults (`[fleet]`) for `occamy fleet`; CLI flags
    /// override them. `None` means the spec carries no fleet section
    /// and the built-in [`FleetSpec::default`] applies.
    pub fleet: Option<FleetSpec>,
}

/// The `[fleet]` section of a campaign spec: defaults for the
/// [`crate::fleet`] scheduler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetSpec {
    /// Shard count — one worker process per shard.
    pub workers: usize,
    /// Seconds without a heartbeat before a running worker's shard is
    /// declared stale and reassigned.
    pub lease_ttl_secs: u64,
    /// Relaunches allowed per shard before the whole fleet run fails.
    pub max_restarts: usize,
    /// SSH hosts to fan shards out over; empty means local subprocesses.
    pub hosts: Vec<HostSpec>,
    /// Remote `occamy` binary for hosts without their own `bin=`.
    pub remote_bin: String,
    /// Local prefix that per-host `root=` attributes replace in every
    /// task path (shared mounts mounted at different points per host).
    pub local_root: Option<std::path::PathBuf>,
}

impl Default for FleetSpec {
    fn default() -> Self {
        Self {
            workers: 2,
            lease_ttl_secs: 30,
            max_restarts: 2,
            hosts: Vec::new(),
            remote_bin: "occamy".to_string(),
            local_root: None,
        }
    }
}

/// One SSH host of a multi-host fleet, parsed from a `[fleet] hosts`
/// entry: `"name"` or `"name bin=/path/occamy root=/remote/mount"`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostSpec {
    /// The ssh destination (`host` or `user@host`).
    pub name: String,
    /// Remote `occamy` binary; `None` falls back to
    /// [`FleetSpec::remote_bin`].
    pub remote_bin: Option<String>,
    /// What this host mounts [`FleetSpec::local_root`] as; task paths
    /// under `local_root` are rewritten with this prefix.
    pub remote_root: Option<std::path::PathBuf>,
}

impl HostSpec {
    /// A host with no per-host overrides.
    pub fn named(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            remote_bin: None,
            remote_root: None,
        }
    }

    /// Parse a host token: whitespace-separated, first the ssh
    /// destination, then optional `bin=`/`root=` attributes.
    pub fn parse(tok: &str) -> Result<Self, String> {
        let mut parts = tok.split_whitespace();
        let name = parts
            .next()
            .ok_or_else(|| "empty host entry".to_string())?
            .to_string();
        if name.contains('=') {
            return Err(format!(
                "host entry {tok:?} starts with an attribute; the ssh destination comes first"
            ));
        }
        if name.starts_with('-') {
            return Err(format!(
                "host {name:?} begins with '-' — ssh would read it as an option, not a destination"
            ));
        }
        let mut host = Self::named(name);
        for attr in parts {
            let (key, value) = attr
                .split_once('=')
                .ok_or_else(|| format!("host attribute {attr:?} is not key=value"))?;
            if value.is_empty() {
                return Err(format!("host attribute {attr:?} has an empty value"));
            }
            match key {
                "bin" => host.remote_bin = Some(value.to_string()),
                "root" => host.remote_root = Some(std::path::PathBuf::from(value)),
                other => {
                    return Err(format!(
                        "unknown host attribute {other:?} (expected bin= or root=)"
                    ))
                }
            }
        }
        Ok(host)
    }
}

/// The `[interference]` section of a campaign spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterferenceSpec {
    /// Jobs-in-flight windows to sweep (1 = the serial reference).
    pub jobs_in_flight: Vec<usize>,
    /// Jobs replayed per (point, inflight).
    pub n_jobs: usize,
    /// Virtual cycles between consecutive arrivals.
    pub arrival_gap: u64,
}

/// Dry-run diagnostics of a spec (`occamy campaign validate`).
#[derive(Debug, Clone)]
pub struct SpecReport {
    pub name: String,
    pub points: usize,
    /// Distinct (spec, clusters, routine) requests — the number of
    /// simulations a cold run performs and of traces the store will hold.
    pub unique_traces: usize,
    pub kernels: Vec<String>,
    pub clusters: Vec<usize>,
    pub routines: Vec<&'static str>,
    /// Interference points derived at merge (0 without `[interference]`).
    pub interference_points: usize,
    /// The spec's `[fleet]` scheduler defaults, if any.
    pub fleet: Option<FleetSpec>,
    /// Content fingerprint of the resolved config (store directory name).
    pub config_fingerprint: String,
}

impl std::fmt::Display for SpecReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "campaign {:?}", self.name)?;
        writeln!(f, "  kernels  ({}): {}", self.kernels.len(), self.kernels.join(", "))?;
        let clusters: Vec<String> = self.clusters.iter().map(|c| c.to_string()).collect();
        writeln!(f, "  clusters ({}): {}", clusters.len(), clusters.join(", "))?;
        writeln!(f, "  routines ({}): {}", self.routines.len(), self.routines.join(", "))?;
        writeln!(f, "  points: {} ({} unique traces)", self.points, self.unique_traces)?;
        if self.interference_points > 0 {
            writeln!(f, "  interference points: {}", self.interference_points)?;
        }
        if let Some(fleet) = &self.fleet {
            write!(
                f,
                "  fleet: {} worker(s), lease ttl {}s, max {} restart(s) per shard",
                fleet.workers, fleet.lease_ttl_secs, fleet.max_restarts
            )?;
            if !fleet.hosts.is_empty() {
                let names: Vec<&str> = fleet.hosts.iter().map(|h| h.name.as_str()).collect();
                write!(f, ", {} ssh host(s): {}", names.len(), names.join(", "))?;
            }
            writeln!(f)?;
        }
        write!(f, "  config fingerprint: {}", self.config_fingerprint)
    }
}

impl CampaignSpec {
    /// Parse a campaign spec; errors carry 1-based line numbers.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut name = None;
        let mut kernels: Vec<JobSpec> = Vec::new();
        let mut clusters: Vec<usize> = Vec::new();
        let mut routines: Vec<RoutineKind> = Vec::new();
        let mut config = Config::default();
        let mut profile = SimProfile::Reference;
        let mut interference_section = false;
        let mut jobs_in_flight: Vec<usize> = Vec::new();
        let mut interference_jobs: usize = 16;
        let mut interference_gap: u64 = 0;
        let mut fleet_section = false;
        let mut fleet = FleetSpec::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(s) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = s.trim().to_string();
                if !matches!(
                    section.as_str(),
                    "campaign" | "grid" | "soc" | "timing" | "interference" | "fleet"
                ) {
                    anyhow::bail!(
                        "line {lineno}: unknown section [{section}] (expected [campaign], [grid], [soc], [timing], [interference] or [fleet])"
                    );
                }
                if section == "interference" {
                    interference_section = true;
                }
                if section == "fleet" {
                    fleet_section = true;
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {lineno}: expected key = value"))?;
            let (key, value) = (key.trim(), value.trim());
            match (section.as_str(), key) {
                ("campaign", "name") => {
                    name = Some(parse_string(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?);
                }
                ("campaign", "profile") => {
                    let s = parse_string(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    profile = SimProfile::parse(&s).ok_or_else(|| {
                        anyhow::anyhow!(
                            "line {lineno}: unknown profile {s:?} (expected \"reference\" or \"fast\")"
                        )
                    })?;
                }
                ("campaign", other) => {
                    anyhow::bail!(
                        "line {lineno}: unknown [campaign] key {other:?} (expected name or profile)"
                    )
                }
                ("grid", "kernels") => {
                    for tok in parse_string_array(value)
                        .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?
                    {
                        kernels.push(
                            parse_kernel(&tok)
                                .map_err(|e| anyhow::anyhow!("line {lineno}: kernel {tok:?}: {e}"))?,
                        );
                    }
                }
                ("grid", "clusters") => {
                    for v in parse_int_array(value)
                        .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?
                    {
                        anyhow::ensure!(v > 0, "line {lineno}: cluster count must be positive");
                        clusters.push(v as usize);
                    }
                }
                ("grid", "routines") => {
                    for tok in parse_string_array(value)
                        .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?
                    {
                        routines.push(RoutineKind::parse(&tok).ok_or_else(|| {
                            anyhow::anyhow!(
                                "line {lineno}: unknown routine {tok:?} (expected one of {})",
                                RoutineKind::ALL.map(|r| r.name()).join(", ")
                            )
                        })?);
                    }
                }
                ("grid", other) => anyhow::bail!(
                    "line {lineno}: unknown [grid] key {other:?} (expected kernels, clusters or routines)"
                ),
                ("interference", "jobs_in_flight") => {
                    for v in parse_int_array(value)
                        .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?
                    {
                        anyhow::ensure!(v > 0, "line {lineno}: jobs_in_flight must be positive");
                        jobs_in_flight.push(v as usize);
                    }
                }
                ("interference", "jobs") => {
                    let v = parse_int(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    anyhow::ensure!(v > 0, "line {lineno}: jobs must be positive");
                    interference_jobs = v as usize;
                }
                ("interference", "arrival_gap") => {
                    interference_gap =
                        parse_int(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                }
                ("interference", other) => anyhow::bail!(
                    "line {lineno}: unknown [interference] key {other:?} (expected jobs_in_flight, jobs or arrival_gap)"
                ),
                ("fleet", "workers") => {
                    let v = parse_int(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    anyhow::ensure!(v > 0, "line {lineno}: workers must be positive");
                    fleet.workers = v as usize;
                }
                ("fleet", "lease_ttl") => {
                    let v = parse_int(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    anyhow::ensure!(v > 0, "line {lineno}: lease_ttl must be positive (seconds)");
                    fleet.lease_ttl_secs = v;
                }
                ("fleet", "max_restarts") => {
                    let v = parse_int(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    fleet.max_restarts = v as usize;
                }
                ("fleet", "hosts") => {
                    for tok in parse_string_array(value)
                        .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?
                    {
                        fleet.hosts.push(
                            HostSpec::parse(&tok)
                                .map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?,
                        );
                    }
                }
                ("fleet", "remote_bin") => {
                    let v = parse_string(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    anyhow::ensure!(!v.is_empty(), "line {lineno}: remote_bin must be non-empty");
                    fleet.remote_bin = v;
                }
                ("fleet", "local_root") => {
                    let v = parse_string(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    anyhow::ensure!(!v.is_empty(), "line {lineno}: local_root must be non-empty");
                    fleet.local_root = Some(std::path::PathBuf::from(v));
                }
                ("fleet", other) => anyhow::bail!(
                    "line {lineno}: unknown [fleet] key {other:?} (expected workers, lease_ttl, max_restarts, hosts, remote_bin or local_root)"
                ),
                ("soc", key) | ("timing", key) => {
                    let v = parse_int(value).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                    let r = if section == "soc" {
                        config.soc.set_field(key, v)
                    } else {
                        config.timing.set_field(key, v)
                    };
                    r.map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
                }
                ("", _) => anyhow::bail!("line {lineno}: key outside a section"),
                _ => unreachable!("sections are validated on entry"),
            }
        }
        let name = name.ok_or_else(|| anyhow::anyhow!("missing [campaign] name"))?;
        // The name becomes shard/merged file names and the default
        // output directory — keep it from escaping that directory.
        anyhow::ensure!(
            !name.is_empty()
                && !name.contains(['/', '\\'])
                && !name.contains("..")
                && !name.starts_with('.'),
            "campaign name {name:?} must be non-empty and free of path separators, '..' and a leading '.' (it names output files)"
        );
        anyhow::ensure!(!kernels.is_empty(), "missing or empty [grid] kernels");
        anyhow::ensure!(!clusters.is_empty(), "missing or empty [grid] clusters");
        let max = config.soc.n_clusters();
        for &c in &clusters {
            anyhow::ensure!(
                c <= max,
                "cluster count {c} exceeds the SoC geometry ({max} clusters)"
            );
        }
        let interference = if interference_section {
            anyhow::ensure!(
                !jobs_in_flight.is_empty(),
                "[interference] requires a non-empty jobs_in_flight axis"
            );
            Some(InterferenceSpec {
                jobs_in_flight,
                n_jobs: interference_jobs,
                arrival_gap: interference_gap,
            })
        } else {
            None
        };
        Ok(Self {
            name,
            kernels,
            clusters,
            routines,
            config,
            profile,
            interference,
            fleet: fleet_section.then_some(fleet),
        })
    }

    /// Load from a file path.
    pub fn from_path(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// The equivalent single-process sweep.
    pub fn to_sweep(&self) -> Sweep {
        let mut sweep = Sweep::new()
            .profile(self.profile)
            .clusters(self.clusters.iter().copied())
            .routines(self.routines.iter().copied());
        for spec in &self.kernels {
            sweep = sweep.kernel(spec.kind().name(), *spec);
        }
        sweep
    }

    /// The campaign's ordered point list (global point indices are
    /// offsets into this).
    pub fn expand(&self) -> Vec<SweepPoint> {
        self.to_sweep().expand()
    }

    /// The campaign's interference points (empty without an
    /// `[interference]` section): the trace grid crossed with the
    /// jobs-in-flight axis.
    pub fn interference_points(&self) -> Vec<InterferencePoint> {
        match &self.interference {
            None => Vec::new(),
            Some(i) => self
                .to_sweep()
                .inflight(i.jobs_in_flight.iter().copied())
                .expand_interference(i.n_jobs, i.arrival_gap),
        }
    }

    /// Dry-run diagnostics: point count, estimated trace count, axes
    /// summary, config fingerprint. The axes are read back from the
    /// expansion (the single source of dedup/default semantics), so the
    /// printed counts always multiply out to the printed point count.
    pub fn report(&self) -> SpecReport {
        let points = self.expand();
        let unique: HashSet<_> = points.iter().map(|p| p.req).collect();
        let mut clusters: Vec<usize> = Vec::new();
        let mut routines: Vec<&'static str> = Vec::new();
        for p in &points {
            if !clusters.contains(&p.req.n_clusters) {
                clusters.push(p.req.n_clusters);
            }
            let r = p.req.routine.name();
            if !routines.contains(&r) {
                routines.push(r);
            }
        }
        SpecReport {
            name: self.name.clone(),
            points: points.len(),
            unique_traces: unique.len(),
            kernels: self.kernels.iter().map(|s| s.id()).collect(),
            clusters,
            routines,
            interference_points: self.interference_points().len(),
            fleet: self.fleet.clone(),
            config_fingerprint: super::store::fingerprint(&self.config),
        }
    }
}

/// Strip a `#` comment, ignoring `#` inside double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_string(v: &str) -> Result<String, String> {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .map(str::to_string)
        .ok_or_else(|| format!("expected a double-quoted string, found {v:?}"))
}

fn parse_int(v: &str) -> Result<u64, String> {
    if let Some(hex) = v.strip_prefix("0x") {
        u64::from_str_radix(hex, 16)
    } else {
        v.parse()
    }
    .map_err(|e| format!("bad integer {v:?}: {e}"))
}

/// Split a `[a, b, c]` array body into element tokens.
fn array_elems(v: &str) -> Result<Vec<&str>, String> {
    let body = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("expected an array [..], found {v:?}"))?;
    let body = body.trim();
    if body.is_empty() {
        return Ok(Vec::new());
    }
    Ok(body.split(',').map(str::trim).collect())
}

fn parse_string_array(v: &str) -> Result<Vec<String>, String> {
    array_elems(v)?.into_iter().map(parse_string).collect()
}

fn parse_int_array(v: &str) -> Result<Vec<u64>, String> {
    array_elems(v)?.into_iter().map(parse_int).collect()
}

/// Parse a kernel token: `family:dims` with `x`-separated dimensions.
///
/// * `axpy:N`, `montecarlo:SAMPLES`
/// * `matmul:MxNxK` or `matmul:S` (cube)
/// * `atax:MxN` or `atax:S` (square)
/// * `covariance:MxN` or `covariance:S` (m=S, n=2S, as the CLI)
/// * `bfs:NODESxLEVELS` or `bfs:NODES` (levels=4)
pub fn parse_kernel(tok: &str) -> Result<JobSpec, String> {
    let (family, dims) = tok
        .split_once(':')
        .ok_or_else(|| "expected family:size, e.g. \"axpy:1024\"".to_string())?;
    let dims: Vec<u64> = dims
        .split('x')
        .map(|d| parse_int(d.trim()))
        .collect::<Result<_, _>>()?;
    let arity = |want: &[usize]| -> Result<(), String> {
        if want.contains(&dims.len()) {
            Ok(())
        } else {
            Err(format!(
                "{family} takes {} dimension(s), got {}",
                want.iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join(" or "),
                dims.len()
            ))
        }
    };
    Ok(match family {
        "axpy" => {
            arity(&[1])?;
            JobSpec::Axpy { n: dims[0] }
        }
        "montecarlo" | "mc" => {
            arity(&[1])?;
            JobSpec::MonteCarlo { samples: dims[0] }
        }
        "matmul" => {
            arity(&[1, 3])?;
            if dims.len() == 3 {
                JobSpec::Matmul {
                    m: dims[0],
                    n: dims[1],
                    k: dims[2],
                }
            } else {
                JobSpec::Matmul {
                    m: dims[0],
                    n: dims[0],
                    k: dims[0],
                }
            }
        }
        "atax" => {
            arity(&[1, 2])?;
            if dims.len() == 2 {
                JobSpec::Atax {
                    m: dims[0],
                    n: dims[1],
                }
            } else {
                JobSpec::Atax {
                    m: dims[0],
                    n: dims[0],
                }
            }
        }
        "covariance" | "cov" => {
            arity(&[1, 2])?;
            if dims.len() == 2 {
                JobSpec::Covariance {
                    m: dims[0],
                    n: dims[1],
                }
            } else {
                JobSpec::Covariance {
                    m: dims[0],
                    n: 2 * dims[0],
                }
            }
        }
        "bfs" => {
            arity(&[1, 2])?;
            JobSpec::Bfs {
                nodes: dims[0],
                levels: if dims.len() == 2 { dims[1] } else { 4 },
            }
        }
        other => return Err(format!("unknown kernel family {other:?}")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::TRIPLE_ROUTINES;

    const DEMO: &str = r#"
        # A small demo campaign.
        [campaign]
        name = "demo"

        [grid]
        kernels = ["axpy:1024", "atax:64x64", "matmul:16"]
        clusters = [1, 8]
        routines = ["baseline", "ideal", "multicast"]
    "#;

    #[test]
    fn parses_a_spec_and_expands_the_grid() {
        let spec = CampaignSpec::parse(DEMO).unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.kernels.len(), 3);
        assert_eq!(spec.kernels[2], JobSpec::Matmul { m: 16, n: 16, k: 16 });
        assert_eq!(spec.clusters, vec![1, 8]);
        assert_eq!(spec.config, Config::default());
        let points = spec.expand();
        assert_eq!(points.len(), 3 * 2 * 3);
        assert_eq!(points[0].label, "axpy");
        let report = spec.report();
        assert_eq!(report.points, 18);
        assert_eq!(report.unique_traces, 18);
    }

    #[test]
    fn routines_default_to_the_triple() {
        // The empty-routines default lives in Sweep::expand; the spec
        // inherits it rather than re-implementing it.
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"t\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n",
        )
        .unwrap();
        let routines: Vec<_> = spec.expand().iter().map(|p| p.req.routine).collect();
        assert_eq!(routines, TRIPLE_ROUTINES.to_vec());
    }

    #[test]
    fn geometry_overrides_are_first_class_axes() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"geo\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [8]\n\
             [soc]\nn_quadrants = 2\n[timing]\nhost_ipi_issue_gap = 21\n",
        )
        .unwrap();
        assert_eq!(spec.config.soc.n_quadrants, 2);
        assert_eq!(spec.config.soc.n_clusters(), 8);
        assert_eq!(spec.config.timing.host_ipi_issue_gap, 21);
        assert_ne!(spec.config, Config::default());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = |text: &str| CampaignSpec::parse(text).unwrap_err().to_string();
        assert!(err("[grid]\nkernels = 7\n").contains("line 2"), "{}", err("[grid]\nkernels = 7\n"));
        assert!(err("[campaign]\nname = \"x\"\n[grid]\nkernels = [\"warp:9\"]\n").contains("line 4"));
        assert!(err("[nope]\n").contains("line 1"));
        assert!(err("[grid]\nclusters = [0]\n").contains("line 2"));
        assert!(err("key = 1\n").contains("outside a section"));
        assert!(err("[soc]\nwarp_factor = 9\n").contains("line 2"));
        assert!(err("[grid]\nroutines = [\"warp\"]\n").contains("line 2"));
    }

    #[test]
    fn missing_axes_are_rejected() {
        assert!(CampaignSpec::parse("[campaign]\nname = \"x\"\n")
            .unwrap_err()
            .to_string()
            .contains("kernels"));
        assert!(CampaignSpec::parse("[grid]\nkernels = [\"axpy:1\"]\nclusters = [1]\n")
            .unwrap_err()
            .to_string()
            .contains("name"));
        // Cluster axis beyond the (overridden) geometry fails fast.
        let err = CampaignSpec::parse(
            "[campaign]\nname = \"x\"\n[grid]\nkernels = [\"axpy:1\"]\nclusters = [32]\n[soc]\nn_quadrants = 2\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn kernel_tokens_cover_all_families() {
        for (tok, id) in [
            ("axpy:256", "axpy_n256"),
            ("montecarlo:4096", "montecarlo_n4096"),
            ("matmul:8x16x32", "matmul_k32_m8_n16"),
            ("atax:64", "atax_m64_n64"),
            ("covariance:32", "covariance_m32_n64"),
            ("bfs:64x2", "bfs_n64"),
        ] {
            assert_eq!(parse_kernel(tok).unwrap().id(), id, "{tok}");
        }
        assert_eq!(
            parse_kernel("bfs:64x2").unwrap(),
            JobSpec::Bfs { nodes: 64, levels: 2 }
        );
        assert!(parse_kernel("axpy").is_err());
        assert!(parse_kernel("matmul:1x2").is_err());
    }

    #[test]
    fn report_axes_match_the_deduplicated_expansion() {
        // Duplicate clusters/routines must not make the report's axes
        // disagree with its point count.
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"dup\"\n[grid]\nkernels = [\"axpy:8\"]\nclusters = [4, 4]\n\
             routines = [\"baseline\", \"baseline\"]\n",
        )
        .unwrap();
        let r = spec.report();
        assert_eq!(r.points, 1);
        assert_eq!(r.clusters, vec![4]);
        assert_eq!(r.routines, vec!["baseline"]);
    }

    #[test]
    fn path_escaping_names_are_rejected() {
        for bad in ["a/b", "a\\b", "..", "x/../y", ".hidden", ""] {
            let err = CampaignSpec::parse(&format!(
                "[campaign]\nname = \"{bad}\"\n[grid]\nkernels = [\"axpy:8\"]\nclusters = [1]\n"
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains("name"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn interference_section_round_trips() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"contend\"\n[grid]\nkernels = [\"axpy:512\"]\nclusters = [16]\n\
             routines = [\"multicast\"]\n[interference]\njobs_in_flight = [1, 4]\njobs = 8\narrival_gap = 50\n",
        )
        .unwrap();
        let i = spec.interference.as_ref().unwrap();
        assert_eq!(i.jobs_in_flight, vec![1, 4]);
        assert_eq!(i.n_jobs, 8);
        assert_eq!(i.arrival_gap, 50);
        let ipoints = spec.interference_points();
        assert_eq!(ipoints.len(), 2, "1 trace point x 2 windows");
        assert_eq!(ipoints[0].ireq.inflight, 1);
        assert_eq!(ipoints[1].ireq.inflight, 4);
        assert!(ipoints.iter().all(|p| p.ireq.n_jobs == 8 && p.ireq.arrival_gap == 50));
        let report = spec.report();
        assert_eq!(report.interference_points, 2);
        assert!(report.to_string().contains("interference points: 2"));
    }

    #[test]
    fn interference_defaults_and_errors() {
        // Defaults: 16 jobs, gap 0.
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"d\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n\
             [interference]\njobs_in_flight = [2]\n",
        )
        .unwrap();
        let i = spec.interference.unwrap();
        assert_eq!((i.n_jobs, i.arrival_gap), (16, 0));
        // Without the section there is no interference axis.
        let plain = CampaignSpec::parse(
            "[campaign]\nname = \"p\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n",
        )
        .unwrap();
        assert_eq!(plain.interference, None);
        assert!(plain.interference_points().is_empty());
        assert_eq!(plain.report().interference_points, 0);
        // Errors: empty axis, zero window, unknown key.
        let err = |text: &str| CampaignSpec::parse(text).unwrap_err().to_string();
        let base = "[campaign]\nname = \"e\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n";
        assert!(err(&format!("{base}[interference]\n")).contains("jobs_in_flight"));
        assert!(err(&format!("{base}[interference]\njobs_in_flight = [0]\n")).contains("positive"));
        assert!(err(&format!("{base}[interference]\nwarp = 1\n")).contains("unknown [interference] key"));
        assert!(err(&format!("{base}[interference]\njobs_in_flight = [1]\njobs = 0\n")).contains("positive"));
    }

    #[test]
    fn fleet_section_round_trips_with_defaults() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"f\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n\
             [fleet]\nworkers = 3\nlease_ttl = 10\nmax_restarts = 1\n",
        )
        .unwrap();
        let fleet = spec.fleet.as_ref().unwrap();
        assert_eq!(fleet.workers, 3);
        assert_eq!(fleet.lease_ttl_secs, 10);
        assert_eq!(fleet.max_restarts, 1);
        let report = spec.report();
        assert_eq!(report.fleet, spec.fleet);
        assert!(report.to_string().contains("fleet: 3 worker(s)"));

        // Partial section: unset keys take the FleetSpec defaults.
        let partial = CampaignSpec::parse(
            "[campaign]\nname = \"p\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n\
             [fleet]\nworkers = 5\n",
        )
        .unwrap();
        assert_eq!(
            partial.fleet,
            Some(FleetSpec {
                workers: 5,
                ..FleetSpec::default()
            })
        );

        // No section: no fleet defaults, and the report omits the line.
        let plain = CampaignSpec::parse(
            "[campaign]\nname = \"n\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n",
        )
        .unwrap();
        assert_eq!(plain.fleet, None);
        assert!(!plain.report().to_string().contains("fleet:"));
    }

    #[test]
    fn fleet_section_rejects_bad_values() {
        let err = |text: &str| CampaignSpec::parse(text).unwrap_err().to_string();
        let base = "[campaign]\nname = \"e\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n";
        assert!(err(&format!("{base}[fleet]\nworkers = 0\n")).contains("positive"));
        assert!(err(&format!("{base}[fleet]\nlease_ttl = 0\n")).contains("positive"));
        assert!(err(&format!("{base}[fleet]\nwarp = 1\n")).contains("unknown [fleet] key"));
        assert!(err(&format!("{base}[fleet]\nworkers = \"two\"\n")).contains("bad integer"));
        assert!(err(&format!("{base}[fleet]\nremote_bin = \"\"\n")).contains("non-empty"));
        assert!(err(&format!("{base}[fleet]\nhosts = [\"a warp=1\"]\n"))
            .contains("unknown host attribute"));
    }

    #[test]
    fn fleet_hosts_parse_with_per_host_attributes() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"ssh\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n\
             [fleet]\nworkers = 2\nhosts = [\"alpha\", \"user@beta bin=/opt/occamy root=/data/shared\"]\n\
             remote_bin = \"/shared/bin/occamy\"\nlocal_root = \"/mnt/shared\"\n",
        )
        .unwrap();
        let fleet = spec.fleet.as_ref().unwrap();
        assert_eq!(fleet.hosts.len(), 2);
        assert_eq!(fleet.hosts[0], HostSpec::named("alpha"));
        assert_eq!(
            fleet.hosts[1],
            HostSpec {
                name: "user@beta".into(),
                remote_bin: Some("/opt/occamy".into()),
                remote_root: Some(std::path::PathBuf::from("/data/shared")),
            }
        );
        assert_eq!(fleet.remote_bin, "/shared/bin/occamy");
        assert_eq!(fleet.local_root, Some(std::path::PathBuf::from("/mnt/shared")));
        let rendered = spec.report().to_string();
        assert!(rendered.contains("2 ssh host(s): alpha, user@beta"), "{rendered}");

        // An empty hosts array stays local and reports no host list.
        let local = CampaignSpec::parse(
            "[campaign]\nname = \"l\"\n[grid]\nkernels = [\"axpy:64\"]\nclusters = [4]\n\
             [fleet]\nhosts = []\n",
        )
        .unwrap();
        assert!(local.fleet.as_ref().unwrap().hosts.is_empty());
        assert!(!local.report().to_string().contains("ssh host"));
    }

    #[test]
    fn host_spec_grammar_edge_cases() {
        assert_eq!(HostSpec::parse("alpha").unwrap(), HostSpec::named("alpha"));
        let full = HostSpec::parse("  beta   bin=/x/occamy   root=/y  ").unwrap();
        assert_eq!(full.name, "beta");
        assert_eq!(full.remote_bin.as_deref(), Some("/x/occamy"));
        assert_eq!(full.remote_root, Some(std::path::PathBuf::from("/y")));
        for bad in ["", "bin=/x", "a bin", "a bin=", "a warp=9", "-i", "-oProxyCommand=x"] {
            assert!(HostSpec::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn comments_and_strings_interact_correctly() {
        let spec = CampaignSpec::parse(
            "[campaign]\nname = \"a#b\" # trailing comment\n[grid]\nkernels = [\"axpy:8\"]\nclusters = [1]\n",
        )
        .unwrap();
        assert_eq!(spec.name, "a#b");
    }
}
