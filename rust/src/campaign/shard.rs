//! Deterministic shard planning: partition a campaign's global point
//! list across N independent processes.
//!
//! Points are dealt round-robin by global index (`index % count ==
//! shard`), so every shard sees a balanced mix of cheap and expensive
//! points even when cost correlates with grid position (e.g. cluster
//! counts expanding innermost). The partition depends only on
//! `(index, count)` — shards planned on different hosts agree without
//! coordination.

/// One shard of an N-way campaign split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// This shard's index, `0 <= index < count`.
    pub index: usize,
    /// Total number of shards.
    pub count: usize,
}

impl Shard {
    /// The whole campaign as a single shard.
    pub const SINGLE: Shard = Shard { index: 0, count: 1 };

    pub fn new(index: usize, count: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(count > 0, "shard count must be positive");
        anyhow::ensure!(
            index < count,
            "shard index {index} out of range (0..{count})"
        );
        Ok(Self { index, count })
    }

    /// Parse the CLI syntax `i/N` (e.g. `--shard 0/2`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| anyhow::anyhow!("expected i/N (e.g. 0/2), found {s:?}"))?;
        Self::new(
            i.trim().parse().map_err(|e| anyhow::anyhow!("bad shard index {i:?}: {e}"))?,
            n.trim().parse().map_err(|e| anyhow::anyhow!("bad shard count {n:?}: {e}"))?,
        )
    }

    /// Whether this shard owns the point at `global_index`.
    pub fn owns(&self, global_index: usize) -> bool {
        global_index % self.count == self.index
    }

    /// The global indices this shard owns, out of `total` points.
    pub fn indices(&self, total: usize) -> Vec<usize> {
        (self.index..total).step_by(self.count).collect()
    }
}

impl std::fmt::Display for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_exactly() {
        for total in [0usize, 1, 7, 18, 100] {
            for count in [1usize, 2, 3, 5] {
                let mut seen = vec![0u32; total];
                for index in 0..count {
                    let shard = Shard::new(index, count).unwrap();
                    for i in shard.indices(total) {
                        seen[i] += 1;
                        assert!(shard.owns(i));
                    }
                }
                assert!(seen.iter().all(|&c| c == 1), "total={total} count={count}");
            }
        }
    }

    #[test]
    fn round_robin_balances_within_one() {
        let sizes: Vec<usize> = (0..3)
            .map(|i| Shard::new(i, 3).unwrap().indices(20).len())
            .collect();
        assert_eq!(sizes.iter().sum::<usize>(), 20);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        let s = Shard::parse("1/4").unwrap();
        assert_eq!((s.index, s.count), (1, 4));
        assert_eq!(Shard::parse(&s.to_string()).unwrap(), s);
        for bad in ["", "2", "2/2", "3/2", "a/b", "1/0", "-1/2"] {
            assert!(Shard::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn parse_edge_cases() {
        // Whitespace around the separator and redundant digits are
        // tolerated (hand-typed CLI values)...
        assert_eq!(Shard::parse(" 1 / 4 ").unwrap(), Shard::new(1, 4).unwrap());
        assert_eq!(Shard::parse("01/2").unwrap(), Shard::new(1, 2).unwrap());
        // ...but anything structurally off is not.
        for bad in [
            "1//2",                   // the remainder "/2" is not a count
            "1/2/3",                  // extra segment
            "/2",                     // missing index
            "1/",                     // missing count
            "18446744073709551616/2", // index overflows usize
            "1/18446744073709551616", // count overflows usize
            "0x1/2",                  // hex is not shard syntax
            "1.0/2",                  // fractions are not indices
        ] {
            assert!(Shard::parse(bad).is_err(), "{bad:?}");
        }
    }
}
