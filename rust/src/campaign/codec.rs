//! JSON codecs for the campaign's persistent formats: job specs,
//! requests and full traces. Round-tripping is exact for every cycle
//! count the DES can produce (`runtime::json` writes integers up to
//! 2^53 losslessly), which is what makes shard merge and store reuse
//! bit-identical to in-process execution.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::kernels::JobSpec;
use crate::offload::RoutineKind;
use crate::runtime::json::{Json, EXACT_INT};
use crate::sim::{Phase, PhaseSpan, Trace};
use crate::sweep::OffloadRequest;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Strict u64 extraction: unlike `Json::as_u64` (which truncates
/// fractions and saturates negatives for the lenient manifest path),
/// corrupted values must be *rejected* so the caller re-simulates.
pub(crate) fn exact_u64(j: &Json) -> Option<u64> {
    let n = j.as_f64()?;
    (n >= 0.0 && n.fract() == 0.0 && n <= EXACT_INT).then_some(n as u64)
}

fn get_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(exact_u64)
        .ok_or_else(|| format!("missing or invalid integer {key:?}"))
}

/// Serialize a job spec with its full parameter set (unlike
/// `JobSpec::id`, which omits the BFS level count).
pub fn spec_to_json(spec: &JobSpec) -> Json {
    match *spec {
        JobSpec::Axpy { n } => obj(vec![("kernel", Json::Str("axpy".into())), ("n", num(n))]),
        JobSpec::MonteCarlo { samples } => obj(vec![
            ("kernel", Json::Str("montecarlo".into())),
            ("samples", num(samples)),
        ]),
        JobSpec::Matmul { m, n, k } => obj(vec![
            ("kernel", Json::Str("matmul".into())),
            ("m", num(m)),
            ("n", num(n)),
            ("k", num(k)),
        ]),
        JobSpec::Atax { m, n } => obj(vec![
            ("kernel", Json::Str("atax".into())),
            ("m", num(m)),
            ("n", num(n)),
        ]),
        JobSpec::Covariance { m, n } => obj(vec![
            ("kernel", Json::Str("covariance".into())),
            ("m", num(m)),
            ("n", num(n)),
        ]),
        JobSpec::Bfs { nodes, levels } => obj(vec![
            ("kernel", Json::Str("bfs".into())),
            ("nodes", num(nodes)),
            ("levels", num(levels)),
        ]),
    }
}

pub fn spec_from_json(j: &Json) -> Result<JobSpec, String> {
    let kernel = j
        .get("kernel")
        .and_then(Json::as_str)
        .ok_or("missing \"kernel\"")?;
    Ok(match kernel {
        "axpy" => JobSpec::Axpy { n: get_u64(j, "n")? },
        "montecarlo" => JobSpec::MonteCarlo {
            samples: get_u64(j, "samples")?,
        },
        "matmul" => JobSpec::Matmul {
            m: get_u64(j, "m")?,
            n: get_u64(j, "n")?,
            k: get_u64(j, "k")?,
        },
        "atax" => JobSpec::Atax {
            m: get_u64(j, "m")?,
            n: get_u64(j, "n")?,
        },
        "covariance" => JobSpec::Covariance {
            m: get_u64(j, "m")?,
            n: get_u64(j, "n")?,
        },
        "bfs" => JobSpec::Bfs {
            nodes: get_u64(j, "nodes")?,
            levels: get_u64(j, "levels")?,
        },
        other => return Err(format!("unknown kernel {other:?}")),
    })
}

pub fn request_to_json(req: &OffloadRequest) -> Json {
    obj(vec![
        ("spec", spec_to_json(&req.spec)),
        ("clusters", num(req.n_clusters as u64)),
        ("routine", Json::Str(req.routine.name().into())),
    ])
}

pub fn request_from_json(j: &Json) -> Result<OffloadRequest, String> {
    let spec = spec_from_json(j.get("spec").ok_or("missing \"spec\"")?)?;
    let n_clusters = get_u64(j, "clusters")? as usize;
    let routine = j
        .get("routine")
        .and_then(Json::as_str)
        .ok_or("missing \"routine\"")?;
    let routine =
        RoutineKind::parse(routine).ok_or_else(|| format!("unknown routine {routine:?}"))?;
    Ok(OffloadRequest::new(spec, n_clusters, routine))
}

fn spans_to_json(spans: &BTreeMap<Phase, PhaseSpan>) -> Json {
    Json::Obj(
        spans
            .iter()
            .map(|(p, s)| {
                (
                    p.letter().to_string(),
                    Json::Arr(vec![num(s.start), num(s.end)]),
                )
            })
            .collect(),
    )
}

fn spans_from_json(j: &Json) -> Result<BTreeMap<Phase, PhaseSpan>, String> {
    let m = match j {
        Json::Obj(m) => m,
        _ => return Err("phase map is not an object".into()),
    };
    let mut out = BTreeMap::new();
    for (k, v) in m {
        let mut chars = k.chars();
        let phase = chars
            .next()
            .filter(|_| chars.next().is_none())
            .and_then(Phase::from_letter)
            .ok_or_else(|| format!("unknown phase {k:?}"))?;
        let arr = v.as_arr().filter(|a| a.len() == 2).ok_or("span is not [start, end]")?;
        let (start, end) = (
            exact_u64(&arr[0]).ok_or("invalid span start")?,
            exact_u64(&arr[1]).ok_or("invalid span end")?,
        );
        if end < start {
            return Err(format!("span ends before it starts: {start}..{end}"));
        }
        out.insert(phase, PhaseSpan::new(start, end));
    }
    Ok(out)
}

/// Serialize a full trace (all per-cluster and host phase spans).
pub fn trace_to_json(trace: &Trace) -> Json {
    obj(vec![
        ("total", num(trace.total)),
        ("events", num(trace.events)),
        ("host", spans_to_json(&trace.host_spans)),
        (
            "clusters",
            Json::Arr(trace.cluster_spans.iter().map(spans_to_json).collect()),
        ),
    ])
}

pub fn trace_from_json(j: &Json) -> Result<Trace, String> {
    let clusters = j
        .get("clusters")
        .and_then(Json::as_arr)
        .ok_or("missing \"clusters\"")?;
    Ok(Trace {
        cluster_spans: clusters
            .iter()
            .map(spans_from_json)
            .collect::<Result<_, _>>()?,
        host_spans: spans_from_json(j.get("host").ok_or("missing \"host\"")?)?,
        total: get_u64(j, "total")?,
        events: get_u64(j, "events")?,
    })
}

/// Parse a trace from raw file contents (corruption-tolerant callers
/// map `Err` to a re-simulation).
pub fn trace_from_str(text: &str) -> Result<Arc<Trace>, String> {
    Json::parse(text).and_then(|j| trace_from_json(&j)).map(Arc::new)
}

/// Serialize one interference point + its schedule: the request, the
/// window parameters, the isolated service time and every per-job
/// queueing delay (all exact integers, so round-tripping is
/// bit-identical like the trace codec).
pub fn interference_to_json(
    point: &crate::sweep::InterferencePoint,
    outcome: &crate::sweep::InterferenceOutcome,
) -> Json {
    obj(vec![
        ("req", request_to_json(&point.ireq.req)),
        ("inflight", num(point.ireq.inflight as u64)),
        ("jobs", num(point.ireq.n_jobs as u64)),
        ("arrival_gap", num(point.ireq.arrival_gap)),
        ("isolated", num(outcome.isolated)),
        (
            "queue_delays",
            Json::Arr(outcome.queue_delays.iter().map(|&d| num(d)).collect()),
        ),
        ("makespan", num(outcome.makespan)),
    ])
}

pub fn interference_from_json(
    j: &Json,
) -> Result<(crate::sweep::InterferencePoint, crate::sweep::InterferenceOutcome), String> {
    let req = request_from_json(j.get("req").ok_or("missing \"req\"")?)?;
    let inflight = get_u64(j, "inflight")? as usize;
    let n_jobs = get_u64(j, "jobs")? as usize;
    let arrival_gap = get_u64(j, "arrival_gap")?;
    if inflight == 0 {
        return Err("inflight must be >= 1".into());
    }
    let delays = j
        .get("queue_delays")
        .and_then(Json::as_arr)
        .ok_or("missing \"queue_delays\"")?;
    let queue_delays = delays
        .iter()
        .map(|d| exact_u64(d).ok_or_else(|| "invalid queue delay".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    if queue_delays.len() != n_jobs {
        return Err(format!(
            "queue_delays has {} entries for {n_jobs} jobs",
            queue_delays.len()
        ));
    }
    Ok((
        crate::sweep::InterferencePoint {
            label: req.spec.kind().name(),
            ireq: crate::sweep::InterferenceRequest::new(req, inflight, n_jobs, arrival_gap),
        },
        crate::sweep::InterferenceOutcome {
            isolated: get_u64(j, "isolated")?,
            queue_delays,
            makespan: get_u64(j, "makespan")?,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn spec_round_trips_all_families() {
        let specs = [
            JobSpec::Axpy { n: 1024 },
            JobSpec::MonteCarlo { samples: 1 << 20 },
            JobSpec::Matmul { m: 8, n: 16, k: 32 },
            JobSpec::Atax { m: 64, n: 63 },
            JobSpec::Covariance { m: 32, n: 64 },
            JobSpec::Bfs { nodes: 64, levels: 9 },
        ];
        for s in specs {
            let j = Json::parse(&spec_to_json(&s).to_string()).unwrap();
            assert_eq!(spec_from_json(&j).unwrap(), s, "{s:?}");
        }
    }

    #[test]
    fn request_round_trips() {
        for routine in RoutineKind::ALL {
            let req = OffloadRequest::new(JobSpec::Atax { m: 16, n: 16 }, 8, routine);
            let j = Json::parse(&request_to_json(&req).to_string()).unwrap();
            assert_eq!(request_from_json(&j).unwrap(), req);
        }
    }

    #[test]
    fn trace_round_trips_bit_identical() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 1024 }, 8, RoutineKind::Baseline);
        let trace = req.run(&cfg);
        let line = trace_to_json(&trace).to_string();
        assert!(!line.contains('\n'));
        let back = trace_from_str(&line).unwrap();
        assert_eq!(*back, trace);
    }

    #[test]
    fn interference_round_trips_bit_identical() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 512 }, 16, RoutineKind::Multicast);
        let ireq = crate::sweep::InterferenceRequest::new(req, 4, 8, 25);
        let point = crate::sweep::InterferencePoint {
            label: "axpy",
            ireq,
        };
        let outcome = ireq.run(&cfg);
        let line = interference_to_json(&point, &outcome).to_string();
        assert!(!line.contains('\n'));
        let (p2, o2) = interference_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(p2, point);
        assert_eq!(o2, outcome);
        // Corruption is rejected, not coerced.
        for bad in [
            "{}",
            "{\"req\":{\"spec\":{\"kernel\":\"axpy\",\"n\":1},\"clusters\":1,\"routine\":\"multicast\"},\
             \"inflight\":0,\"jobs\":1,\"arrival_gap\":0,\"isolated\":1,\"queue_delays\":[0],\"makespan\":1}",
            "{\"req\":{\"spec\":{\"kernel\":\"axpy\",\"n\":1},\"clusters\":1,\"routine\":\"multicast\"},\
             \"inflight\":1,\"jobs\":2,\"arrival_gap\":0,\"isolated\":1,\"queue_delays\":[0],\"makespan\":1}",
        ] {
            assert!(
                interference_from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn corrupted_traces_are_rejected_not_panicked() {
        for bad in [
            "",
            "{",
            "null",
            "{\"total\": 1}",
            "{\"total\":1,\"events\":1,\"host\":{},\"clusters\":[{\"Z\":[0,1]}]}",
            "{\"total\":1,\"events\":1,\"host\":{\"A\":[5,2]},\"clusters\":[]}",
            "{\"total\":1,\"events\":1,\"host\":{\"A\":[0]},\"clusters\":[]}",
            // Strictness: negative and fractional cycle counts are
            // corruption, not values to coerce.
            "{\"total\":-1,\"events\":1,\"host\":{},\"clusters\":[]}",
            "{\"total\":1.5,\"events\":1,\"host\":{},\"clusters\":[]}",
            "{\"total\":1,\"events\":1,\"host\":{\"A\":[0,1.25]},\"clusters\":[]}",
        ] {
            assert!(trace_from_str(bad).is_err(), "{bad:?}");
        }
    }
}
