//! Streaming JSONL result files.
//!
//! Each executed point becomes one self-contained line — global point
//! index, label, request and the full trace — appended (and flushed) as
//! soon as the point completes, so a killed shard keeps everything it
//! finished. Lines are self-describing and order-independent: workers
//! write in completion order, and merge/resume sort by index. Reading is
//! corruption-tolerant — an unparsable line (the torn tail of a killed
//! writer) is dropped and its point re-executed.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::runtime::json::Json;
use crate::sweep::{SweepPoint, SweepRecord};

use super::codec;
use super::shard::Shard;

/// Which layer served a point's trace (the optional `"src"` field of a
/// line). `occamy campaign status` and the fleet summary aggregate these
/// into per-shard fresh-simulation vs. store/cache-hit counts; files
/// written before the field existed read back as unlabelled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Source {
    /// Simulated fresh by the writing process.
    Sim,
    /// Served from the persistent on-disk trace store.
    Disk,
    /// Served from the process-wide memory cache.
    Mem,
}

impl Source {
    pub fn name(self) -> &'static str {
        match self {
            Source::Sim => "sim",
            Source::Disk => "disk",
            Source::Mem => "mem",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sim" => Some(Source::Sim),
            "disk" => Some(Source::Disk),
            "mem" => Some(Source::Mem),
            _ => None,
        }
    }

    /// Anything that avoided a fresh simulation is a hit.
    pub fn is_hit(self) -> bool {
        !matches!(self, Source::Sim)
    }
}

/// Everything one shard file contains: the valid records by global
/// index, where each trace came from (for lines that carry the `"src"`
/// label), and how many corrupt lines were dropped.
#[derive(Debug, Default)]
pub struct ShardFile {
    pub records: BTreeMap<usize, SweepRecord>,
    pub sources: BTreeMap<usize, Source>,
    pub dropped: usize,
}

impl ShardFile {
    /// Points this file records as freshly simulated.
    pub fn sims(&self) -> usize {
        self.sources.values().filter(|s| !s.is_hit()).count()
    }

    /// Points this file records as store/cache hits.
    pub fn hits(&self) -> usize {
        self.sources.values().filter(|s| s.is_hit()).count()
    }
}

/// Shard output file name: `<name>.shard-<i>-of-<N>.jsonl`.
pub fn shard_file_name(campaign: &str, shard: Shard) -> String {
    format!("{campaign}.shard-{}-of-{}.jsonl", shard.index, shard.count)
}

/// Merged output file name: `<name>.merged.jsonl`.
pub fn merged_file_name(campaign: &str) -> String {
    format!("{campaign}.merged.jsonl")
}

/// Interference output file name: `<name>.interference.jsonl`. Written
/// by merge when the spec has an `[interference]` section — derived
/// deterministically from the merged traces, so it needs no sharding of
/// its own.
pub fn interference_file_name(campaign: &str) -> String {
    format!("{campaign}.interference.jsonl")
}

/// Serialize one interference point as a JSONL line (no trailing
/// newline), fingerprint-stamped like trace lines.
pub fn interference_line_of(
    config_fp: &str,
    point: &crate::sweep::InterferencePoint,
    outcome: &crate::sweep::InterferenceOutcome,
) -> String {
    let mut j = codec::interference_to_json(point, outcome);
    if let Json::Obj(entries) = &mut j {
        entries.insert("config".to_string(), Json::Str(config_fp.to_string()));
    }
    j.to_string()
}

/// Read an interference file back. Strict, unlike [`read_shard`]:
/// these lines are cheap to rewrite from a merged campaign, so any
/// unparsable line or foreign fingerprint is an error rather than a
/// silent drop.
pub fn read_interference(
    path: &Path,
    expected_fp: &str,
) -> anyhow::Result<Vec<(crate::sweep::InterferencePoint, crate::sweep::InterferenceOutcome)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), i + 1))?;
        let fp = j
            .get("config")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("{}:{}: missing \"config\"", path.display(), i + 1))?;
        anyhow::ensure!(
            fp == expected_fp,
            "{}: written under config fingerprint {fp}, the spec now resolves to {expected_fp}",
            path.display()
        );
        out.push(
            codec::interference_from_json(&j)
                .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), i + 1))?,
        );
    }
    Ok(out)
}

/// Serialize one executed point as a JSONL line (no trailing newline).
/// Every line carries the config fingerprint, so stale files from a
/// spec whose `[soc]`/`[timing]` changed cannot be silently resumed.
pub fn line_of(config_fp: &str, index: usize, record: &SweepRecord) -> String {
    line_of_sourced(config_fp, index, record, None)
}

/// [`line_of`] with an optional trace-source label (`"src"`), written by
/// shard runners so status views can split done points into fresh
/// simulations vs. store/cache hits. Merged files omit it.
pub fn line_of_sourced(
    config_fp: &str,
    index: usize,
    record: &SweepRecord,
    source: Option<Source>,
) -> String {
    let mut entries: BTreeMap<String, Json> = [
        ("config".to_string(), Json::Str(config_fp.to_string())),
        ("index".to_string(), Json::Num(index as f64)),
        ("label".to_string(), Json::Str(record.label().to_string())),
        ("req".to_string(), codec::request_to_json(&record.req())),
        ("trace".to_string(), codec::trace_to_json(&record.trace)),
    ]
    .into_iter()
    .collect();
    if let Some(s) = source {
        entries.insert("src".to_string(), Json::Str(s.name().to_string()));
    }
    Json::Obj(entries).to_string()
}

/// Parse one JSONL line back into `(config fingerprint, global index,
/// record, source label)`. The source is `None` for merged output and
/// for files written before the `"src"` field existed.
pub fn record_from_line(
    line: &str,
) -> Result<(String, usize, SweepRecord, Option<Source>), String> {
    let j = Json::parse(line)?;
    let config = j
        .get("config")
        .and_then(Json::as_str)
        .ok_or("missing \"config\"")?
        .to_string();
    let index = j
        .get("index")
        .and_then(codec::exact_u64)
        .ok_or("missing or invalid \"index\"")? as usize;
    let req = codec::request_from_json(j.get("req").ok_or("missing \"req\"")?)?;
    let label = j
        .get("label")
        .and_then(Json::as_str)
        .ok_or("missing \"label\"")?;
    // Campaign grids label points by kernel family, which gives back the
    // 'static name the in-memory SweepPoint carries.
    let family = req.spec.kind().name();
    if label != family {
        return Err(format!(
            "label {label:?} does not match the kernel family {family:?}"
        ));
    }
    let trace = codec::trace_from_json(j.get("trace").ok_or("missing \"trace\"")?)?;
    // Tolerant: an unknown source label degrades to "unlabelled", it
    // does not invalidate an otherwise-good trace line.
    let source = j.get("src").and_then(Json::as_str).and_then(Source::parse);
    Ok((
        config,
        index,
        SweepRecord {
            point: SweepPoint { label: family, req },
            trace: Arc::new(trace),
        },
        source,
    ))
}

/// Read a shard file tolerantly: unparsable lines (torn tails of killed
/// writers, manual edits) are dropped and counted; duplicate indices
/// keep the first occurrence (the DES is deterministic, so any
/// duplicates are equal). A missing file is an empty shard. A parsable
/// record written under a *different* config fingerprint is a hard
/// error, not a drop — silently re-simulating would hide that the
/// spec's `[soc]`/`[timing]` changed under an existing output dir.
pub fn read_shard(path: &Path, expected_fp: &str) -> anyhow::Result<ShardFile> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        // Only an absent file is an empty shard; a permission or I/O
        // error must not masquerade as "nothing done yet" (resume would
        // silently re-simulate finished work).
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(ShardFile::default()),
        Err(e) => return Err(anyhow::anyhow!("read {}: {e}", path.display())),
    };
    let mut out = ShardFile::default();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match record_from_line(line) {
            Ok((fp, index, rec, source)) => {
                anyhow::ensure!(
                    fp == expected_fp,
                    "{}: written under config fingerprint {fp}, the spec now resolves to {expected_fp} — \
                     its [soc]/[timing] changed; delete the file or use a fresh --out",
                    path.display()
                );
                if !out.records.contains_key(&index) {
                    out.records.insert(index, rec);
                    if let Some(s) = source {
                        out.sources.insert(index, s);
                    }
                }
            }
            Err(_) => out.dropped += 1,
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::kernels::JobSpec;
    use crate::offload::RoutineKind;
    use crate::sweep::OffloadRequest;

    fn sample_record() -> SweepRecord {
        let req = OffloadRequest::new(JobSpec::Axpy { n: 160 }, 4, RoutineKind::Multicast);
        SweepRecord {
            point: SweepPoint { label: "axpy", req },
            trace: Arc::new(req.run(&Config::default())),
        }
    }

    #[test]
    fn line_round_trips_bit_identical() {
        let rec = sample_record();
        let line = line_of("fp16chars", 7, &rec);
        assert!(!line.contains('\n'));
        let (fp, index, back, source) = record_from_line(&line).unwrap();
        assert_eq!(fp, "fp16chars");
        assert_eq!(index, 7);
        assert_eq!(back, rec);
        assert_eq!(source, None, "plain lines carry no source label");
    }

    #[test]
    fn source_labels_round_trip_and_tolerate_garbage() {
        let rec = sample_record();
        for src in [Source::Sim, Source::Disk, Source::Mem] {
            let line = line_of_sourced("fp", 3, &rec, Some(src));
            let (_, _, back, parsed) = record_from_line(&line).unwrap();
            assert_eq!(back, rec);
            assert_eq!(parsed, Some(src));
            assert_eq!(Source::parse(src.name()), Some(src));
        }
        assert_eq!(Source::parse("warp"), None);
        assert!(!Source::Sim.is_hit());
        assert!(Source::Disk.is_hit() && Source::Mem.is_hit());
        // An unknown label is dropped, not fatal: the record survives.
        let line = line_of_sourced("fp", 3, &rec, Some(Source::Sim)).replace("\"sim\"", "\"warp\"");
        let (_, _, back, parsed) = record_from_line(&line).unwrap();
        assert_eq!(back, rec);
        assert_eq!(parsed, None);
    }

    #[test]
    fn mismatched_label_is_rejected() {
        let rec = sample_record();
        let line = line_of("fp", 0, &rec).replace("\"axpy\"", "\"warp\"");
        // Replaces both the label and the kernel name; corrupt either way.
        assert!(record_from_line(&line).is_err());
    }

    #[test]
    fn read_shard_drops_torn_tails_and_dedups() {
        let rec = sample_record();
        let dir = std::env::temp_dir().join(format!("occamy-stream-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");
        let full = line_of("fp", 0, &rec);
        let torn = &full[..full.len() - 10];
        let text = format!("{full}\n{}\n\n{torn}", line_of("fp", 0, &rec));
        std::fs::write(&path, text).unwrap();
        let file = read_shard(&path, "fp").unwrap();
        assert_eq!(file.records.len(), 1);
        assert_eq!(file.dropped, 1);
        assert_eq!(file.records[&0], rec);
        let empty = read_shard(&dir.join("absent.jsonl"), "fp").unwrap();
        assert!(empty.records.is_empty());
        assert_eq!(empty.dropped, 0);
    }

    #[test]
    fn read_shard_counts_sims_and_hits() {
        let rec = sample_record();
        let dir = std::env::temp_dir().join(format!(
            "occamy-stream-src-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sources.jsonl");
        let text = format!(
            "{}\n{}\n{}\n{}\n",
            line_of_sourced("fp", 0, &rec, Some(Source::Sim)),
            line_of_sourced("fp", 1, &rec, Some(Source::Disk)),
            line_of_sourced("fp", 2, &rec, Some(Source::Mem)),
            line_of("fp", 3, &rec), // unlabelled (pre-`src` file)
        );
        std::fs::write(&path, text).unwrap();
        let file = read_shard(&path, "fp").unwrap();
        assert_eq!(file.records.len(), 4);
        assert_eq!(file.dropped, 0);
        assert_eq!(file.sims(), 1);
        assert_eq!(file.hits(), 2, "disk and mem both count as hits");
        assert_eq!(file.sources.len(), 3, "the unlabelled line stays unlabelled");
    }

    #[test]
    fn foreign_config_fingerprints_are_a_hard_error() {
        let rec = sample_record();
        let dir = std::env::temp_dir().join(format!(
            "occamy-stream-fp-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stale.jsonl");
        std::fs::write(&path, line_of("old-config", 0, &rec)).unwrap();
        let err = read_shard(&path, "new-config").unwrap_err().to_string();
        assert!(err.contains("old-config"), "{err}");
        assert!(err.contains("[soc]/[timing]"), "{err}");
    }
}
