//! Persistent, content-addressed on-disk trace store.
//!
//! Spills `sweep::cache` entries to disk so traces survive the process:
//! repeated figure runs and cross-process campaign shards reuse each
//! other's simulations. Layout, keyed by `(config fingerprint, request)`:
//!
//! ```text
//! <root>/<fingerprint>/config.toml        # the full config, for humans
//! <root>/<fingerprint>/<request-key>.json # one trace per request
//! ```
//!
//! The fingerprint is an FNV-1a hash of the complete flat-TOML config
//! serialization (the same exhaustive key `sweep::cache` uses, so
//! distinct configs can never share a directory in practice), and the
//! request key spells out every spec parameter. Loading is
//! corruption-tolerant: a truncated or garbled file is treated as a
//! miss and re-simulated (then rewritten atomically via a temp file +
//! rename, so a killed shard can never publish a half-written trace).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::config::Config;
use crate::sim::{SimProfile, Trace};
use crate::sweep::{cache, OffloadRequest};

use super::codec;
use super::stream::Source;

/// FNV-1a 64-bit — stable across builds, unlike `DefaultHasher`.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content fingerprint of a config: 16 hex digits over its complete
/// flat-TOML serialization.
pub fn fingerprint(cfg: &Config) -> String {
    format!("{:016x}", fnv1a64(cfg.to_toml().as_bytes()))
}

/// On-disk file stem of a request: every parameter spelled out
/// (`JobSpec::id` omits the BFS level count, so it is not unique).
/// Delegates to the canonical grammar in [`crate::offload::request_key`],
/// which the fast profile's timeline memoizer shares.
pub fn request_key(req: &OffloadRequest) -> String {
    crate::offload::request_key(&req.spec, req.n_clusters, req.routine)
}

/// Hit/miss counters of one store handle (diagnostics and the warm-store
/// test assertions).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Served from the process-wide memory cache.
    pub memory_hits: u64,
    /// Served from disk (and promoted into the memory cache).
    pub disk_hits: u64,
    /// Simulated fresh (then persisted).
    pub simulations: u64,
}

/// A persistent trace store rooted at one directory.
#[derive(Debug)]
pub struct TraceStore {
    root: PathBuf,
    memory_hits: AtomicU64,
    disk_hits: AtomicU64,
    simulations: AtomicU64,
    /// Fingerprints whose `config.toml` this handle has already
    /// verified (or written): the byte-compare healing check runs once
    /// per config per handle, not once per trace save — a fresh process
    /// (the only thing that can outlive a torn writer) re-verifies.
    verified_manifests: Mutex<BTreeSet<String>>,
}

impl TraceStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .map_err(|e| anyhow::anyhow!("create store {}: {e}", root.display()))?;
        Ok(Self {
            root,
            memory_hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            simulations: AtomicU64::new(0),
            verified_manifests: Mutex::new(BTreeSet::new()),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Directory of one config's traces.
    pub fn config_dir(&self, fp: &str) -> PathBuf {
        self.root.join(fp)
    }

    fn trace_path(&self, fp: &str, req: &OffloadRequest) -> PathBuf {
        self.config_dir(fp).join(format!("{}.json", request_key(req)))
    }

    /// Load one trace from disk; `None` on absent, truncated or
    /// corrupted files (the caller re-simulates).
    pub fn load(&self, fp: &str, req: &OffloadRequest) -> Option<Arc<Trace>> {
        let path = self.trace_path(fp, req);
        let text = std::fs::read_to_string(&path).ok()?;
        match codec::trace_from_str(&text) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!(
                    "campaign store: discarding corrupt {} ({e}); re-simulating",
                    path.display()
                );
                None
            }
        }
    }

    /// Persist one trace. Atomic: writes a temp file in the same
    /// directory, then renames over the target, so readers never observe
    /// a partial trace. Also keeps the human-readable `config.toml`
    /// alongside, written the same way — a torn manifest from a killed
    /// writer is healed by the next save rather than shadowing the
    /// correct content forever.
    pub fn save(&self, fp: &str, cfg: &Config, req: &OffloadRequest, trace: &Trace) -> anyhow::Result<()> {
        let dir = self.config_dir(fp);
        std::fs::create_dir_all(&dir)?;
        // Verify the manifest once per config per handle (the read is a
        // shared-FS round-trip; per-trace it would dominate large
        // campaigns). Skip the write only when the manifest already
        // holds exactly the right bytes; anything else (absent, torn,
        // stale) is rewritten atomically. Concurrent writers racing here
        // all rename identical content, so last-writer-wins is harmless.
        let mut verified = self
            .verified_manifests
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if !verified.contains(fp) {
            let manifest = dir.join("config.toml");
            let toml = cfg.to_toml();
            if std::fs::read_to_string(&manifest).ok().as_deref() != Some(toml.as_str()) {
                atomic_write(&dir, &manifest, "config", &toml)?;
            }
            verified.insert(fp.to_string());
        }
        drop(verified);
        let target = self.trace_path(fp, req);
        atomic_write(&dir, &target, &request_key(req), &codec::trace_to_json(trace).to_string())?;
        Ok(())
    }

    /// Run one request through all three layers: process memory cache →
    /// disk → simulation. Every simulation is persisted; every disk hit
    /// is promoted into the memory cache so in-process reuse stays
    /// `Arc`-shared. `fp`/`mem_key` must come from [`fingerprint`] and
    /// `sweep::cache::config_key` for the same `cfg`.
    pub fn run(&self, fp: &str, mem_key: &str, cfg: &Config, req: OffloadRequest) -> Arc<Trace> {
        self.run_sourced(fp, mem_key, cfg, req).0
    }

    /// [`TraceStore::run`], also reporting which layer served the
    /// request — shard runners stamp it onto every streamed line so
    /// status views can split done points into simulations vs. hits.
    pub fn run_sourced(
        &self,
        fp: &str,
        mem_key: &str,
        cfg: &Config,
        req: OffloadRequest,
    ) -> (Arc<Trace>, Source) {
        if let Some(t) = cache::peek(mem_key, req) {
            // ordering: Relaxed — hit/miss tallies only; traces are
            // published through the cache/store, never through these.
            self.memory_hits.fetch_add(1, Ordering::Relaxed);
            self.emit_tier("hit_mem", &req);
            return (t, Source::Mem);
        }
        if let Some(t) = self.load(fp, &req) {
            // ordering: Relaxed — same as memory_hits above.
            self.disk_hits.fetch_add(1, Ordering::Relaxed);
            self.emit_tier("hit_disk", &req);
            return (cache::insert(mem_key, req, t), Source::Disk);
        }
        let trace = Arc::new(req.run(cfg));
        // ordering: Relaxed — same as memory_hits above.
        self.simulations.fetch_add(1, Ordering::Relaxed);
        self.emit_tier("fresh_sim", &req);
        if let Err(e) = self.save(fp, cfg, &req, &trace) {
            // A read-only or full disk degrades to uncached execution.
            eprintln!("campaign store: failed to persist {}: {e}", request_key(&req));
        }
        (cache::insert(mem_key, req, trace), Source::Sim)
    }

    /// [`TraceStore::run_sourced`] under an explicit engine profile.
    /// The reference profile delegates unchanged. The fast profile
    /// serves memory/disk hits the same way (the on-disk grammar is
    /// profile-free: persisted traces are verified, so both profiles
    /// share them), but a fresh fast simulation is checked against a
    /// reference run of the same request before anything reaches disk —
    /// the store must never be seeded by an unproven engine build. A
    /// divergence degrades loudly to the reference trace. `mem_key`
    /// must come from `sweep::cache::profiled_config_key` for the same
    /// profile.
    pub fn run_sourced_profiled(
        &self,
        fp: &str,
        mem_key: &str,
        cfg: &Config,
        req: OffloadRequest,
        profile: SimProfile,
    ) -> (Arc<Trace>, Source) {
        if profile == SimProfile::Reference {
            return self.run_sourced(fp, mem_key, cfg, req);
        }
        if let Some(t) = cache::peek(mem_key, req) {
            // ordering: Relaxed — hit/miss tallies only; traces are
            // published through the cache/store, never through these.
            self.memory_hits.fetch_add(1, Ordering::Relaxed);
            self.emit_tier("hit_mem", &req);
            return (t, Source::Mem);
        }
        if let Some(t) = self.load(fp, &req) {
            // ordering: Relaxed — same as memory_hits above.
            self.disk_hits.fetch_add(1, Ordering::Relaxed);
            self.emit_tier("hit_disk", &req);
            return (cache::insert(mem_key, req, t), Source::Disk);
        }
        let fast = req.run_with(cfg, SimProfile::Fast);
        let reference = req.run(cfg);
        let trace = if fast == reference {
            Arc::new(fast)
        } else {
            eprintln!(
                "campaign store: fast profile diverged from reference on {}; persisting the reference trace",
                request_key(&req)
            );
            Arc::new(reference)
        };
        // ordering: Relaxed — same as memory_hits above.
        self.simulations.fetch_add(1, Ordering::Relaxed);
        self.emit_tier("fresh_sim", &req);
        if let Err(e) = self.save(fp, cfg, &req, &trace) {
            // A read-only or full disk degrades to uncached execution.
            eprintln!("campaign store: failed to persist {}: {e}", request_key(&req));
        }
        (cache::insert(mem_key, req, trace), Source::Sim)
    }

    /// One wall-domain event per memoization decision. Campaign shards
    /// and fleet workers have no virtual clock of their own, so store
    /// events carry wall time — the warm-store CI check greps the file
    /// for zero `fresh_sim` events after a rerun.
    fn emit_tier(&self, tier: &'static str, req: &OffloadRequest) {
        if crate::obs::log::enabled() {
            crate::obs::log::emit(
                &crate::obs::log::Event::wall("store", tier).str("key", &request_key(req)),
            );
        }
    }

    /// Counters since this handle was opened.
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            // ordering: Relaxed — diagnostic snapshot; callers get no
            // cross-counter consistency guarantee and need none.
            memory_hits: self.memory_hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            simulations: self.simulations.load(Ordering::Relaxed),
        }
    }

    /// Traces currently persisted for one config fingerprint.
    pub fn traces_on_disk(&self, fp: &str) -> usize {
        traces_in(&self.root, fp)
    }
}

/// Write `text` to `target` atomically: a `.{stem}.tmp-{pid}-{seq}` file
/// in `dir`, then a rename over the target. The temp file is unlinked
/// (best-effort) on either a failed write or a failed rename — a writer
/// killed *between* the two still leaks one, which `fleet gc` sweeps.
/// The pid + process-wide sequence keep concurrent writers (two workers
/// of one shard saving the same request, two heartbeats in one lease
/// dir) off each other's temp paths. The one publication idiom for the
/// whole shared store: traces and manifests here, leases via
/// `fleet::lease::write`.
pub(crate) fn atomic_write(
    dir: &Path,
    target: &Path,
    stem: &str,
    text: &str,
) -> anyhow::Result<()> {
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    // ordering: Relaxed — the fetch_add's RMW atomicity alone guarantees
    // unique temp names; no other memory is synchronized through it.
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = dir.join(format!(".{stem}.tmp-{}-{seq}", std::process::id()));
    let written = std::fs::write(&tmp, text)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", tmp.display()))
        .and_then(|()| {
            std::fs::rename(&tmp, target)
                .map_err(|e| anyhow::anyhow!("rename {} -> {}: {e}", tmp.display(), target.display()))
        });
    if written.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    written
}

/// Traces persisted under `root` for one config fingerprint, without
/// opening (and thereby creating) a store — status displays use this so
/// a read-only query never mutates the filesystem.
pub fn traces_in(root: &Path, fp: &str) -> usize {
    match std::fs::read_dir(root.join(fp)) {
        Err(_) => 0,
        Ok(entries) => entries
            .filter_map(Result::ok)
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::JobSpec;
    use crate::offload::RoutineKind;

    fn temp_store(tag: &str) -> TraceStore {
        let dir = std::env::temp_dir().join(format!(
            "occamy-store-test-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        TraceStore::open(dir).unwrap()
    }

    #[test]
    fn save_load_round_trips() {
        let store = temp_store("roundtrip");
        let cfg = Config::default();
        let fp = fingerprint(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 192 }, 4, RoutineKind::Baseline);
        assert!(store.load(&fp, &req).is_none());
        let trace = req.run(&cfg);
        store.save(&fp, &cfg, &req, &trace).unwrap();
        assert_eq!(*store.load(&fp, &req).unwrap(), trace);
        assert_eq!(store.traces_on_disk(&fp), 1);
        // The human-readable manifest rides along.
        let manifest = store.config_dir(&fp).join("config.toml");
        assert_eq!(
            Config::from_path(&manifest).unwrap(),
            cfg,
            "config.toml round-trips"
        );
    }

    #[test]
    fn corrupt_files_load_as_none() {
        let store = temp_store("corrupt");
        let cfg = Config::default();
        let fp = fingerprint(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 224 }, 2, RoutineKind::Ideal);
        let trace = req.run(&cfg);
        store.save(&fp, &cfg, &req, &trace).unwrap();
        let path = store.config_dir(&fp).join(format!("{}.json", request_key(&req)));
        // Truncate mid-file (a killed writer without the atomic rename).
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(store.load(&fp, &req).is_none());
        // Re-saving heals it.
        store.save(&fp, &cfg, &req, &trace).unwrap();
        assert_eq!(*store.load(&fp, &req).unwrap(), trace);
    }

    /// Non-hidden files in a config dir (the temp-leak assertions below
    /// must not be fooled by legitimately present traces/manifests).
    fn tmp_files_in(dir: &Path) -> Vec<String> {
        match std::fs::read_dir(dir) {
            Err(_) => Vec::new(),
            Ok(entries) => entries
                .filter_map(Result::ok)
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.starts_with('.'))
                .collect(),
        }
    }

    #[test]
    fn a_torn_manifest_is_healed_by_the_next_save() {
        let store = temp_store("heal-manifest");
        let cfg = Config::default();
        let fp = fingerprint(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 128 }, 2, RoutineKind::Baseline);
        let trace = req.run(&cfg);
        store.save(&fp, &cfg, &req, &trace).unwrap();
        let manifest = store.config_dir(&fp).join("config.toml");
        // A writer killed mid-write publishes a torn manifest. The old
        // `!manifest.exists()` guard would have shadowed the good content
        // forever; now the next save from a *fresh handle* (the torn
        // writer is dead — any healer is another process) detects the
        // mismatch and heals it.
        let full = std::fs::read_to_string(&manifest).unwrap();
        std::fs::write(&manifest, &full[..full.len() / 2]).unwrap();
        let healer = TraceStore::open(store.root()).unwrap();
        healer.save(&fp, &cfg, &req, &trace).unwrap();
        assert_eq!(std::fs::read_to_string(&manifest).unwrap(), cfg.to_toml());
        assert_eq!(Config::from_path(&manifest).unwrap(), cfg);
        // And a healthy manifest is left alone (byte-compare short-circuit).
        healer.save(&fp, &cfg, &req, &trace).unwrap();
        assert_eq!(std::fs::read_to_string(&manifest).unwrap(), cfg.to_toml());
    }

    #[test]
    fn a_failed_rename_does_not_leak_the_temp_file() {
        let store = temp_store("rename-fail");
        let cfg = Config::default();
        let fp = fingerprint(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 256 }, 2, RoutineKind::Baseline);
        let trace = req.run(&cfg);
        // Make the trace target an occupied *directory*: the temp write
        // succeeds, the rename over it fails.
        let target = store.config_dir(&fp).join(format!("{}.json", request_key(&req)));
        std::fs::create_dir_all(&target).unwrap();
        let err = store.save(&fp, &cfg, &req, &trace).unwrap_err().to_string();
        assert!(err.contains("rename"), "{err}");
        let leaked = tmp_files_in(&store.config_dir(&fp));
        assert!(leaked.is_empty(), "temp files leaked: {leaked:?}");
        // Clearing the obstruction lets the same save succeed.
        std::fs::remove_dir(&target).unwrap();
        store.save(&fp, &cfg, &req, &trace).unwrap();
        assert_eq!(*store.load(&fp, &req).unwrap(), trace);
    }

    #[test]
    fn request_keys_distinguish_bfs_levels() {
        let a = OffloadRequest::new(JobSpec::Bfs { nodes: 64, levels: 2 }, 4, RoutineKind::Ideal);
        let b = OffloadRequest::new(JobSpec::Bfs { nodes: 64, levels: 4 }, 4, RoutineKind::Ideal);
        assert_ne!(request_key(&a), request_key(&b));
    }

    #[test]
    fn fingerprints_differ_across_configs() {
        let cfg = Config::default();
        let mut other = cfg.clone();
        other.timing.host_ipi_issue_gap += 1;
        assert_ne!(fingerprint(&cfg), fingerprint(&other));
        assert_eq!(fingerprint(&cfg).len(), 16);
    }
}
