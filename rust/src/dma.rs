//! Tightly-coupled DMA engine model (§3.1, §5.5.E/G).
//!
//! Each cluster's DM core programs the engine with (src, dst, len) and
//! polls for completion. Timing follows the paper's measured
//! decomposition (Eq. 1): per-transfer programming cost on the DM core,
//! a round-trip latency (AR to the SPM, first R beat back, AW + first W
//! beat to the TCDM, B response), and one cycle per 512-bit beat at the
//! wide port. The beat stream itself is arbitrated by the shared
//! [`crate::sim::PsPort`]; this module computes the per-transfer
//! quantities the executor feeds into it.

use crate::config::TimingConfig;

/// A programmed DMA transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaTransfer {
    /// Payload length in bytes.
    pub bytes: u64,
    /// Direction: true = SPM -> TCDM (operand fetch), false = TCDM -> SPM
    /// (writeback). Both directions share the single wide SPM port.
    pub into_tcdm: bool,
}

impl DmaTransfer {
    /// Number of 512-bit beats on the wide network.
    pub fn beats(&self, wide_bus_bytes: u64) -> u64 {
        self.bytes.div_ceil(wide_bus_bytes).max(1)
    }
}

/// Per-transfer timing quantities (excluding port contention).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaTiming {
    /// DM-core cycles to program the transfer.
    pub setup: u64,
    /// Cycles from issue until the request occupies the SPM port.
    pub request_latency: u64,
    /// Cycles from the last beat leaving the port to completion visible
    /// at the DM core.
    pub response_latency: u64,
}

/// Split of the lumped 55-cycle round trip between the request and
/// response halves. The split is unobservable in the paper (only the sum
/// is measured); 20/35 apportions the AR path vs. the R+AW+W+B path.
const REQUEST_FRACTION_NUM: u64 = 4;
const REQUEST_FRACTION_DEN: u64 = 11;

pub fn dma_timing(t: &TimingConfig) -> DmaTiming {
    let request_latency = t.dma_roundtrip * REQUEST_FRACTION_NUM / REQUEST_FRACTION_DEN;
    DmaTiming {
        setup: t.dma_setup_per_transfer,
        request_latency,
        response_latency: t.dma_roundtrip - request_latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beats_round_up() {
        let t = DmaTransfer {
            bytes: 65,
            into_tcdm: true,
        };
        assert_eq!(t.beats(64), 2);
        assert_eq!(
            DmaTransfer {
                bytes: 64,
                into_tcdm: true
            }
            .beats(64),
            1
        );
        // Degenerate empty transfer still occupies one beat slot.
        assert_eq!(
            DmaTransfer {
                bytes: 0,
                into_tcdm: false
            }
            .beats(64),
            1
        );
    }

    #[test]
    fn split_preserves_roundtrip_sum() {
        // Eq. 1 only constrains the sum: request + response == 55.
        let t = TimingConfig::default();
        let d = dma_timing(&t);
        assert_eq!(d.request_latency + d.response_latency, t.dma_roundtrip);
        assert_eq!(d.setup, 21); // §5.5.G
    }

    #[test]
    fn axpy_1024_phase_e_beats_match_eq1() {
        // Eq. 1: 2*N*8/bw beats for the two operand vectors; N=1024 ->
        // 256 beats total on the 64 B/cycle port.
        let n = 1024u64;
        let x = DmaTransfer {
            bytes: n * 8,
            into_tcdm: true,
        };
        let y = DmaTransfer {
            bytes: n * 8,
            into_tcdm: true,
        };
        assert_eq!(x.beats(64) + y.beats(64), 2 * n * 8 / 64);
    }
}
