//! The line/token-level rule engine.
//!
//! No `syn`, no parsing beyond what the rules need (the same
//! vendored-minimal philosophy as `runtime/json`): a comment/string
//! stripper normalizes each line to bare code, `#[cfg(test)]` items are
//! skipped by brace tracking, and per-file identifier collection types
//! receivers well enough to tell `map.values()` on a `HashMap` from the
//! same call on a `BTreeMap`. The engine is conservative by design —
//! what it cannot type it does not flag — and every finding it does
//! emit names an exact line a human can check in seconds.
//!
//! Suppression: `// audit:allow(<rule>[,<rule>]) -- reason` silences the
//! listed rules on the pragma's line and the next line. The reason is
//! mandatory; a malformed pragma is itself a (non-suppressible)
//! `bad-pragma` finding.

use std::collections::{BTreeMap, BTreeSet};

use super::domains::Domain;
use super::Finding;

/// `Instant::now`/`SystemTime::now` in a `sim` module.
pub const WALL_CLOCK_IN_SIM: &str = "wall-clock-in-sim";
/// Iterating a `HashMap`/`HashSet` in a `sim` or `mixed` module.
pub const UNORDERED_ITERATION: &str = "unordered-iteration";
/// An atomic `Ordering::` use without an adjacent `// ordering:`
/// justification comment (all domains; mirrors `// SAFETY:`).
pub const RELAXED_ORDERING: &str = "relaxed-ordering";
/// Entropy sources (default hashers, rng seeding, env reads) in `sim`.
pub const ENTROPY_IN_SIM: &str = "entropy-in-sim";
/// Order-sensitive float reduction over an unordered iterator in `sim`
/// or `mixed`.
pub const FLOAT_REDUCTION_ORDER: &str = "float-reduction-order";
/// Meta: a malformed or reason-less suppression pragma.
pub const BAD_PRAGMA: &str = "bad-pragma";
/// Meta: a file whose module the manifest does not classify.
pub const UNKNOWN_MODULE: &str = "unknown-module";

/// The suppressible rules, in report order. The meta findings
/// ([`BAD_PRAGMA`], [`UNKNOWN_MODULE`]) are intentionally absent: they
/// cannot be `audit:allow`ed away.
pub const RULES: &[&str] = &[
    ENTROPY_IN_SIM,
    FLOAT_REDUCTION_ORDER,
    RELAXED_ORDERING,
    UNORDERED_ITERATION,
    WALL_CLOCK_IN_SIM,
];

const WALL_CLOCK_PATTERNS: &[&str] = &["Instant::now", "SystemTime::now"];

const ENTROPY_PATTERNS: &[&str] = &[
    "DefaultHasher",
    "OsRng",
    "RandomState",
    "env::var",
    "env::vars",
    "from_entropy",
    "getrandom",
    "process::id",
    "thread_rng",
];

const ATOMIC_ORDERINGS: &[&str] = &[
    "Ordering::AcqRel",
    "Ordering::Acquire",
    "Ordering::Relaxed",
    "Ordering::Release",
    "Ordering::SeqCst",
];

const ITER_METHODS: &[&str] = &[
    ".drain(",
    ".into_iter()",
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
];

const FLOAT_REDUCTIONS: &[&str] = &[".fold(", ".reduce(", ".sum::<f32>", ".sum::<f64>"];

/// The result of scanning one file.
#[derive(Debug, Default)]
pub struct Scan {
    pub findings: Vec<Finding>,
    /// Findings silenced by a valid `audit:allow` pragma.
    pub suppressed: usize,
}

/// One source line after comment/string stripping.
struct Line {
    /// The line with comments removed and literal contents blanked.
    code: String,
    /// Text of a `//` comment starting on this line, if any.
    comment: Option<String>,
}

/// Scan one file's source under the given domain.
pub fn scan_source(path: &str, domain: Domain, text: &str) -> Scan {
    let lines = strip(text);
    let skipped = test_mask(&lines);
    let idents = collect_idents(&lines, &skipped);
    let mut scan = Scan::default();
    let allow = pragmas(path, &lines, &mut scan);

    let sim = domain == Domain::Sim;
    let ordered_output = domain != Domain::Wall;
    let mut justified = false;
    for (idx, line) in lines.iter().enumerate() {
        let n = idx + 1;
        if let Some(c) = &line.comment {
            if c.contains("ordering:") {
                justified = true;
            }
        }
        if skipped[idx] {
            continue;
        }
        let code = line.code.as_str();
        if code.trim().is_empty() {
            continue;
        }
        let mut emit = |rule: &'static str, message: String| {
            let silenced = allow.get(&n).is_some_and(|rules| rules.contains(rule));
            if silenced {
                scan.suppressed += 1;
            } else {
                scan.findings.push(Finding {
                    path: path.to_string(),
                    line: n,
                    rule,
                    message,
                });
            }
        };
        if sim {
            if let Some(p) = first_match(code, WALL_CLOCK_PATTERNS) {
                emit(
                    WALL_CLOCK_IN_SIM,
                    format!("`{p}` in a sim-domain module; wall-clock reads belong to wall code"),
                );
            }
            if let Some(p) = first_match(code, ENTROPY_PATTERNS) {
                emit(
                    ENTROPY_IN_SIM,
                    format!("`{p}` in a sim-domain module; sim code must stay entropy-free"),
                );
            }
        }
        if let Some(p) = first_match(code, ATOMIC_ORDERINGS) {
            if !justified {
                emit(
                    RELAXED_ORDERING,
                    format!("`{p}` without an adjacent `// ordering:` justification comment"),
                );
            }
        } else {
            justified = false;
        }
        if ordered_output {
            if let Some(ident) = hash_iteration(code, &idents) {
                emit(
                    UNORDERED_ITERATION,
                    format!("iteration over unordered `{ident}`; use an ordered container or sort"),
                );
                if chain_has_reduction(&lines, idx) {
                    emit(
                        FLOAT_REDUCTION_ORDER,
                        format!("order-sensitive reduction over unordered `{ident}`"),
                    );
                }
            }
        }
    }
    scan
}

/// Strip comments and literal contents from every line, tracking state
/// (block comments, multi-line strings) across lines.
fn strip(text: &str) -> Vec<Line> {
    let mut state = State::Normal;
    text.lines().map(|l| strip_line(l, &mut state)).collect()
}

enum State {
    Normal,
    /// Inside `/* */`, with nesting depth.
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string, closed by `"` followed by this many `#`s.
    Raw(u8),
}

fn strip_line(line: &str, state: &mut State) -> Line {
    let chars: Vec<char> = line.chars().collect();
    let mut code = String::new();
    let mut comment = None;
    let mut i = 0;
    while i < chars.len() {
        match *state {
            State::Block(depth) => {
                if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    *state = match depth {
                        0 | 1 => State::Normal,
                        d => State::Block(d - 1),
                    };
                    i += 2;
                } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    *state = State::Block(depth + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            State::Str => {
                if chars[i] == '\\' {
                    i += 2;
                } else if chars[i] == '"' {
                    *state = State::Normal;
                    code.push('"');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::Raw(h) => {
                let closes = chars[i] == '"'
                    && (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#'));
                if closes {
                    *state = State::Normal;
                    i += 1 + h as usize;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::Normal => {
                let c = chars[i];
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    comment = Some(chars[i + 2..].iter().collect());
                    break;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    *state = State::Block(1);
                    i += 2;
                    continue;
                }
                if let Some(consumed) = raw_or_byte_string(&chars, i, state) {
                    i += consumed;
                    continue;
                }
                if c == '"' {
                    *state = State::Str;
                    code.push('"');
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    i += char_literal(&chars, i, &mut code);
                    continue;
                }
                code.push(c);
                i += 1;
            }
        }
    }
    Line { code, comment }
}

/// Detect `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#` starts at `i`; returns the
/// prefix length consumed and updates the state.
fn raw_or_byte_string(chars: &[char], i: usize, state: &mut State) -> Option<usize> {
    let c = chars[i];
    if c != 'r' && c != 'b' {
        return None;
    }
    if i > 0 && is_ident_char(chars[i - 1]) {
        return None; // tail of an identifier like `for` or `sub`
    }
    let mut j = i + 1;
    let mut raw = c == 'r';
    if c == 'b' && chars.get(j) == Some(&'r') {
        raw = true;
        j += 1;
    }
    let mut hashes = 0u8;
    while raw && chars.get(j) == Some(&'#') && hashes < 255 {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    *state = if raw { State::Raw(hashes) } else { State::Str };
    Some(j + 1 - i)
}

/// Consume a char literal (or a lone lifetime tick) at `i`; returns the
/// number of chars consumed.
fn char_literal(chars: &[char], i: usize, code: &mut String) -> usize {
    if chars.get(i + 1) == Some(&'\\') {
        // '\n', '\u{1f}', '\\': skip the backslash and its escape, then
        // scan to the closing quote.
        let mut j = i + 3;
        while j < chars.len() && chars[j] != '\'' {
            j += 1;
        }
        code.push('\'');
        code.push('\'');
        j + 1 - i
    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
        // 'x'
        code.push('\'');
        code.push('\'');
        3
    } else {
        // A lifetime ('a) or stray tick: plain code.
        code.push('\'');
        1
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Mark the lines belonging to `#[cfg(test)]` items (the attribute line
/// through the end of the attributed braced item, or through the first
/// `;` for braceless items).
fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut skip = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        'item: while j < lines.len() {
            skip[j] = true;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth <= 0 {
                            break 'item;
                        }
                    }
                    ';' if !opened => break 'item,
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
    skip
}

/// Per-file identifier typing: names whose declared type (or
/// initializer) mentions an unordered hash container, and functions
/// returning one. Type aliases propagate (`type Shard = HashMap<…>`
/// makes `Shard` a marker for the rest of the file).
struct HashIdents {
    idents: BTreeSet<String>,
    fns: BTreeSet<String>,
}

fn collect_idents(lines: &[Line], skipped: &[bool]) -> HashIdents {
    let mut markers: BTreeSet<String> = BTreeSet::new();
    markers.insert("HashMap".to_string());
    markers.insert("HashSet".to_string());
    // Two rounds so an alias-of-an-alias still resolves.
    for _ in 0..2 {
        for (idx, line) in lines.iter().enumerate() {
            if skipped[idx] {
                continue;
            }
            let code = line.code.trim();
            let rest = code
                .strip_prefix("pub type ")
                .or_else(|| code.strip_prefix("pub(crate) type "))
                .or_else(|| code.strip_prefix("type "));
            if let Some(rest) = rest {
                if let Some((name, rhs)) = rest.split_once('=') {
                    let name = name.trim().split('<').next().unwrap_or("").trim();
                    if !name.is_empty() && mentions_marker(rhs, &markers) {
                        markers.insert(name.to_string());
                    }
                }
            }
        }
    }
    let mut idents = BTreeSet::new();
    let mut fns = BTreeSet::new();
    for (idx, line) in lines.iter().enumerate() {
        if skipped[idx] {
            continue;
        }
        let code = line.code.as_str();
        if !mentions_marker(code, &markers) {
            continue;
        }
        // `fn name(…) -> …Hash…`
        if let Some(fn_pos) = find_token(code, "fn ") {
            let name: String = code[fn_pos + 3..]
                .chars()
                .take_while(|&c| is_ident_char(c))
                .collect();
            if let Some(arrow) = code.find("->") {
                if !name.is_empty() && mentions_marker(&code[arrow..], &markers) {
                    fns.insert(name);
                }
            }
        }
        // `name: …Hash…` (fields, params, lets, statics) and
        // `let name = Hash…::new()`-style initializers.
        for m in marker_positions(code, &markers) {
            if let Some(name) = owner_ident(code, m) {
                idents.insert(name);
            }
        }
    }
    HashIdents { idents, fns }
}

/// Whether `text` contains any marker as a whole identifier.
fn mentions_marker(text: &str, markers: &BTreeSet<String>) -> bool {
    markers.iter().any(|m| find_token(text, m).is_some())
}

/// Start offsets of every marker occurring as a whole identifier.
fn marker_positions(code: &str, markers: &BTreeSet<String>) -> Vec<usize> {
    let mut out = Vec::new();
    for m in markers {
        let mut from = 0;
        while let Some(rel) = code[from..].find(m.as_str()) {
            let pos = from + rel;
            from = pos + m.len();
            let before_ok = pos == 0 || !is_ident_char(code[..pos].chars().next_back().unwrap());
            let next = code[pos + m.len()..].chars().next();
            let after_ok = !next.is_some_and(is_ident_char);
            if before_ok && after_ok {
                out.push(pos);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Find `pat` at an identifier boundary (so `fn ` does not match in
/// `long_fn `, and `HashMap` does not match in `MyHashMapLike`).
fn find_token(code: &str, pat: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = code[from..].find(pat) {
        let pos = from + rel;
        from = pos + 1;
        let before_ok = pos == 0 || !is_ident_char(code[..pos].chars().next_back().unwrap());
        if before_ok {
            return Some(pos);
        }
    }
    None
}

/// The identifier a marker occurrence types: walk left over type syntax
/// to a `:` (not `::`) or `=`, then read the name before it. Returns
/// `None` for occurrences in other positions (turbofish, paths).
fn owner_ident(code: &str, marker_pos: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut i = marker_pos;
    while i > 0 {
        let c = b[i - 1] as char;
        match c {
            ':' => {
                // `::` is a path, keep walking left past it.
                if i >= 2 && b[i - 2] == b':' {
                    i -= 2;
                    continue;
                }
                return ident_before(code, i - 1);
            }
            '=' => return ident_before(code, i - 1),
            c if is_ident_char(c) => i -= 1,
            '<' | '>' | '&' | '\'' | ' ' | ',' | '(' => i -= 1,
            _ => return None,
        }
    }
    None
}

/// The identifier ending just before byte `end` (skipping trailing
/// whitespace and `mut`/`static`-style keywords are left to the caller's
/// patterns: we only read one identifier).
fn ident_before(code: &str, end: usize) -> Option<String> {
    let trimmed = code[..end].trim_end();
    let s = trimmed.rfind(|c: char| !is_ident_char(c)).map_or(0, |p| p + 1);
    let name = &trimmed[s..];
    if name.is_empty() || name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    if matches!(name, "mut" | "let" | "pub" | "static" | "const" | "type" | "fn") {
        return None;
    }
    Some(name.to_string())
}

/// Detect iteration over a hash-typed receiver on this code line:
/// `recv.iter()`-style method calls and `for … in recv` loops. Returns
/// the receiver name.
fn hash_iteration(code: &str, idents: &HashIdents) -> Option<String> {
    for m in ITER_METHODS {
        let mut from = 0;
        while let Some(rel) = code[from..].find(m) {
            let dot = from + rel;
            from = dot + m.len();
            if let Some(ident) = hash_receiver(code, dot, idents) {
                return Some(ident);
            }
        }
    }
    // `for … in &mut recv {` / `for … in recv {`
    if let Some(for_pos) = find_token(code, "for ") {
        if let Some(in_rel) = code[for_pos..].find(" in ") {
            let after = &code[for_pos + in_rel + 4..];
            let expr = match after.find('{') {
                Some(b) => &after[..b],
                None => after,
            };
            let expr = expr.trim().trim_start_matches("&mut ").trim_start_matches('&');
            let s = expr.rfind(|c: char| !is_ident_char(c)).map_or(0, |p| p + 1);
            let name = &expr[s..];
            // Only a bare trailing identifier: method-call receivers are
            // covered above, and `0..n` ranges must not resolve to `n`.
            let simple = expr[..s].chars().all(|c| c == '.' || c == ':' || is_ident_char(c));
            if simple && idents.idents.contains(name) {
                return Some(name.to_string());
            }
        }
    }
    None
}

/// Resolve the receiver of a `.method(` at `dot`: either a trailing
/// identifier (`map.iter()`) or a call (`lock().values()`), checked
/// against the file's hash-typed names.
fn hash_receiver(code: &str, dot: usize, idents: &HashIdents) -> Option<String> {
    let b = code.as_bytes();
    let mut end = dot;
    let called = end > 0 && b[end - 1] == b')';
    if called {
        let mut depth: i64 = 0;
        while end > 0 {
            end -= 1;
            match b[end] {
                b')' => depth += 1,
                b'(' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
    }
    let s = code[..end].rfind(|c: char| !is_ident_char(c)).map_or(0, |p| p + 1);
    let name = &code[s..end];
    if name.is_empty() {
        return None;
    }
    let hash = if called {
        idents.fns.contains(name)
    } else {
        idents.idents.contains(name)
    };
    if hash {
        Some(name.to_string())
    } else {
        None
    }
}

/// Whether the iteration starting at line `idx` chains into a float (or
/// otherwise order-sensitive) reduction, looking through the standard
/// rustfmt layout of one chained call per continuation line.
fn chain_has_reduction(lines: &[Line], idx: usize) -> bool {
    let mut chain = lines[idx].code.clone();
    for line in lines.iter().skip(idx + 1).take(8) {
        let t = line.code.trim();
        if !t.starts_with('.') {
            break;
        }
        chain.push_str(t);
    }
    FLOAT_REDUCTIONS.iter().any(|p| chain.contains(p))
}

fn first_match<'p>(code: &str, patterns: &[&'p str]) -> Option<&'p str> {
    patterns.iter().copied().find(|p| code.contains(p))
}

/// Parse every `audit:allow` pragma: valid ones populate the
/// line → silenced-rules map (the pragma's line and the next line);
/// malformed ones become `bad-pragma` findings.
fn pragmas(
    path: &str,
    lines: &[Line],
    scan: &mut Scan,
) -> BTreeMap<usize, BTreeSet<&'static str>> {
    let mut allow: BTreeMap<usize, BTreeSet<&'static str>> = BTreeMap::new();
    for (idx, line) in lines.iter().enumerate() {
        let n = idx + 1;
        let Some(comment) = &line.comment else {
            continue;
        };
        // Doc comments (`///`, `//!` — a `/` or `!` right after the
        // `//`) are documentation, not pragmas: they may legitimately
        // *describe* the pragma grammar, as this module's own docs do.
        if comment.starts_with('/') || comment.starts_with('!') {
            continue;
        }
        let Some(at) = comment.find("audit:allow") else {
            continue;
        };
        let mut bad = |message: &str| {
            scan.findings.push(Finding {
                path: path.to_string(),
                line: n,
                rule: BAD_PRAGMA,
                message: message.to_string(),
            });
        };
        let rest = &comment[at + "audit:allow".len()..];
        let Some(args) = rest.strip_prefix('(') else {
            bad("malformed pragma: expected `audit:allow(<rules>) -- reason`");
            continue;
        };
        let Some((list, tail)) = args.split_once(')') else {
            bad("malformed pragma: unterminated rule list");
            continue;
        };
        let mut rules = BTreeSet::new();
        let mut ok = true;
        for raw in list.split(',') {
            let name = raw.trim();
            match RULES.iter().find(|r| **r == name) {
                Some(r) => {
                    rules.insert(*r);
                }
                None => {
                    bad(&format!("unknown rule `{name}` in audit:allow"));
                    ok = false;
                }
            }
        }
        if !ok {
            continue;
        }
        let reason = tail.split_once("--").map(|(_, r)| r.trim()).unwrap_or("");
        if reason.is_empty() {
            bad("audit:allow requires a reason: `audit:allow(<rules>) -- reason`");
            continue;
        }
        for target in [n, n + 1] {
            allow.entry(target).or_default().extend(rules.iter().copied());
        }
    }
    allow
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_sim(text: &str) -> Scan {
        scan_source("t.rs", Domain::Sim, text)
    }

    fn rules_at(scan: &Scan, line: usize) -> Vec<&'static str> {
        let mut out = Vec::new();
        for f in &scan.findings {
            if f.line == line {
                out.push(f.rule);
            }
        }
        out
    }

    #[test]
    fn stripper_blanks_comments_and_literals() {
        let src = concat!(
            "let a = \"Instant::now\"; // Instant::now\n",
            "let b = r#\"SystemTime::now\"#;\n",
            "/* Instant::now\n",
            "still comment */ let c = 1;\n",
        );
        let lines = strip(src);
        assert!(!lines[0].code.contains("Instant"));
        assert!(lines[0].comment.as_deref().unwrap().contains("Instant::now"));
        assert!(!lines[1].code.contains("SystemTime"));
        assert!(!lines[2].code.contains("Instant"));
        assert!(lines[3].code.contains("let c = 1;"));
    }

    #[test]
    fn char_literals_do_not_eat_lifetimes() {
        let lines = strip("fn f<'a>(v: &'a str) -> char { 'q' }\nlet y = '\\n';\n");
        assert!(lines[0].code.contains("&'a str"));
        assert!(!lines[0].code.contains('q'), "{}", lines[0].code);
        assert!(lines[1].code.contains("let y ="));
    }

    #[test]
    fn wall_clock_flagged_only_in_sim() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(rules_at(&scan_sim(src), 1), vec![WALL_CLOCK_IN_SIM]);
        assert!(scan_source("t.rs", Domain::Mixed, src).findings.is_empty());
        assert!(scan_source("t.rs", Domain::Wall, src).findings.is_empty());
    }

    #[test]
    fn entropy_flagged_in_sim() {
        let src = "fn f() { let v = std::env::var(\"X\"); }\n";
        assert_eq!(rules_at(&scan_sim(src), 1), vec![ENTROPY_IN_SIM]);
        assert!(scan_source("t.rs", Domain::Wall, src).findings.is_empty());
    }

    #[test]
    fn unordered_iteration_needs_a_hash_receiver() {
        let src = concat!(
            "use std::collections::HashMap;\n",
            "fn f(m: &HashMap<u32, u32>, v: &[u32]) {\n",
            "    for x in v.iter() {}\n",
            "    for (k, _) in m.iter() {}\n",
            "}\n",
        );
        let scan = scan_sim(src);
        assert_eq!(scan.findings.len(), 1, "{:?}", scan.findings);
        assert_eq!(scan.findings[0].line, 4);
        assert_eq!(scan.findings[0].rule, UNORDERED_ITERATION);
    }

    #[test]
    fn for_loop_over_hash_ident_flagged() {
        let src = concat!(
            "use std::collections::HashSet;\n",
            "fn f(s: HashSet<u32>) {\n",
            "    for x in &s {}\n",
            "    for i in 0..10 {}\n",
            "}\n",
        );
        let scan = scan_sim(src);
        assert_eq!(scan.findings.len(), 1, "{:?}", scan.findings);
        assert_eq!(scan.findings[0].line, 3);
    }

    #[test]
    fn type_alias_and_fn_return_propagate() {
        let src = concat!(
            "use std::collections::HashMap;\n",
            "type Shard = HashMap<u32, u32>;\n",
            "fn lock() -> Shard { Shard::new() }\n",
            "fn g() { let n: usize = lock().values().count(); }\n",
        );
        let scan = scan_sim(src);
        assert_eq!(rules_at(&scan, 4), vec![UNORDERED_ITERATION]);
    }

    #[test]
    fn float_reduction_over_hash_iter_flagged() {
        let src = concat!(
            "use std::collections::HashMap;\n",
            "fn f(m: &HashMap<u32, f64>) -> f64 {\n",
            "    m.values().sum::<f64>()\n",
            "}\n",
        );
        let scan = scan_sim(src);
        let rules = rules_at(&scan, 3);
        assert!(rules.contains(&UNORDERED_ITERATION), "{rules:?}");
        assert!(rules.contains(&FLOAT_REDUCTION_ORDER), "{rules:?}");
    }

    #[test]
    fn ordering_without_justification_flagged_everywhere() {
        let src = concat!(
            "fn f(x: &std::sync::atomic::AtomicU64) {\n",
            "    x.store(1, Ordering::Relaxed);\n",
            "}\n",
        );
        for d in [Domain::Sim, Domain::Wall, Domain::Mixed] {
            let scan = scan_source("t.rs", d, src);
            assert_eq!(scan.findings.len(), 1, "{d:?}");
            assert_eq!(scan.findings[0].rule, RELAXED_ORDERING);
        }
    }

    #[test]
    fn ordering_comment_justifies_contiguous_uses() {
        let src = concat!(
            "fn f(x: &A, y: &A) {\n",
            "    // ordering: Relaxed -- independent counters.\n",
            "    x.store(1, Ordering::Relaxed);\n",
            "    y.store(2, Ordering::Relaxed);\n",
            "    let z = 1;\n",
            "    y.store(3, Ordering::Relaxed);\n",
            "}\n",
        );
        let scan = scan_source("t.rs", Domain::Wall, src);
        assert_eq!(scan.findings.len(), 1, "{:?}", scan.findings);
        assert_eq!(scan.findings[0].line, 6, "the use after plain code lost the justification");
    }

    #[test]
    fn pragma_suppresses_own_and_next_line() {
        let src = concat!(
            "fn f() {\n",
            "    // audit:allow(entropy-in-sim) -- inherited handle stays deterministic\n",
            "    let v = std::env::var(\"X\");\n",
            "}\n",
        );
        let scan = scan_sim(src);
        assert!(scan.findings.is_empty(), "{:?}", scan.findings);
        assert_eq!(scan.suppressed, 1);
    }

    #[test]
    fn pragma_without_reason_is_a_finding() {
        let src = "// audit:allow(entropy-in-sim)\nlet v = std::env::var(\"X\");\n";
        let scan = scan_sim(src);
        assert!(scan.findings.iter().any(|f| f.rule == BAD_PRAGMA && f.line == 1));
        // The violation itself is NOT suppressed by a malformed pragma.
        assert!(scan.findings.iter().any(|f| f.rule == ENTROPY_IN_SIM && f.line == 2));
    }

    #[test]
    fn pragma_with_unknown_rule_is_a_finding() {
        let src = "// audit:allow(warp-factor) -- because\nlet x = 1;\n";
        let scan = scan_sim(src);
        assert_eq!(scan.findings.len(), 1);
        assert_eq!(scan.findings[0].rule, BAD_PRAGMA);
    }

    #[test]
    fn doc_comments_describing_pragmas_are_not_pragmas() {
        let src = "/// Suppress with `audit:allow(<rule>) -- reason`.\nfn f() {}\n";
        let scan = scan_sim(src);
        assert!(scan.findings.is_empty(), "{:?}", scan.findings);
    }

    #[test]
    fn cfg_test_blocks_are_skipped() {
        let src = concat!(
            "fn f() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { let t0 = std::time::Instant::now(); }\n",
            "}\n",
        );
        assert!(scan_sim(src).findings.is_empty());
    }

    #[test]
    fn patterns_inside_strings_do_not_fire() {
        let src = "fn f() -> &'static str { \"Instant::now HashMap env::var\" }\n";
        assert!(scan_sim(src).findings.is_empty());
    }
}
