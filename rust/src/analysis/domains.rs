//! Determinism domains and the module manifest that assigns them.
//!
//! The manifest (`rust/analysis.toml`, compiled into the binary) maps
//! every module path under `rust/src` to a [`Domain`]. Classification is
//! longest-prefix on `/` boundaries, so `coordinator = "sim"` plus
//! `coordinator/service = "mixed"` carves one file out of a subtree. A
//! module no prefix covers is reported as `unknown-module` — growing the
//! tree forces a conscious classification decision.

use std::collections::BTreeMap;

/// Which determinism contract a module lives under.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Domain {
    /// Virtual-clock code: output must be bit-deterministic. All rules
    /// apply.
    Sim,
    /// Daemon/fleet/OS code: wall clock and entropy are its job. Only
    /// the ordering-justification rule applies.
    Wall,
    /// Both worlds (wall-clock timing around a deterministic core):
    /// unordered iteration and float-reduction order stay forbidden;
    /// wall clock and env reads are allowed.
    Mixed,
}

impl Domain {
    pub fn name(self) -> &'static str {
        match self {
            Domain::Sim => "sim",
            Domain::Wall => "wall",
            Domain::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "sim" => Some(Domain::Sim),
            "wall" => Some(Domain::Wall),
            "mixed" => Some(Domain::Mixed),
            _ => None,
        }
    }
}

/// The module → domain table.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Prefix → domain; ordered so diagnostics and iteration are
    /// deterministic.
    modules: BTreeMap<String, Domain>,
}

impl Manifest {
    /// The manifest checked in at `rust/analysis.toml`, compiled into
    /// the binary so `occamy audit` needs no files at run time.
    pub fn builtin() -> Manifest {
        Manifest::parse(include_str!("../../analysis.toml"))
            .expect("built-in analysis.toml must parse")
    }

    /// Parse the minimal manifest grammar: comments, one `[modules]`
    /// section, `key = "domain"` entries with optionally-quoted keys.
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut modules = BTreeMap::new();
        let mut in_modules = false;
        for (idx, raw) in text.lines().enumerate() {
            let n = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(section) = line.strip_prefix('[') {
                let section = section
                    .strip_suffix(']')
                    .ok_or_else(|| format!("manifest line {n}: unterminated section header"))?;
                if section != "modules" {
                    return Err(format!("manifest line {n}: unknown section [{section}]"));
                }
                in_modules = true;
                continue;
            }
            if !in_modules {
                return Err(format!("manifest line {n}: entry before [modules]"));
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("manifest line {n}: expected `key = \"domain\"`"))?;
            let key = unquote(key.trim())
                .ok_or_else(|| format!("manifest line {n}: bad key {:?}", key.trim()))?;
            let value = value.trim();
            let value = value
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| format!("manifest line {n}: domain must be quoted"))?;
            let domain = Domain::parse(value).ok_or_else(|| {
                format!("manifest line {n}: unknown domain {value:?} (sim|wall|mixed)")
            })?;
            if modules.insert(key.to_string(), domain).is_some() {
                return Err(format!("manifest line {n}: duplicate module {key:?}"));
            }
        }
        if modules.is_empty() {
            return Err("manifest has no [modules] entries".to_string());
        }
        Ok(Manifest { modules })
    }

    /// Classify a module path (e.g. `campaign/store`): the longest
    /// prefix matching on a `/` boundary wins; `None` means unknown.
    pub fn classify(&self, module: &str) -> Option<Domain> {
        let mut best_len = 0;
        let mut best = None;
        for (prefix, &domain) in &self.modules {
            let matches = module == prefix
                || (module.len() > prefix.len()
                    && module.starts_with(prefix.as_str())
                    && module.as_bytes()[prefix.len()] == b'/');
            if matches && (best.is_none() || prefix.len() > best_len) {
                best_len = prefix.len();
                best = Some(domain);
            }
        }
        best
    }

    /// Number of classified prefixes (diagnostics).
    pub fn len(&self) -> usize {
        self.modules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.modules.is_empty()
    }
}

fn unquote(s: &str) -> Option<&str> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"')?;
        if inner.is_empty() {
            return None;
        }
        return Some(inner);
    }
    let bare = |c: char| c.is_ascii_alphanumeric() || c == '_' || c == '-';
    if !s.is_empty() && s.chars().all(bare) {
        Some(s)
    } else {
        None
    }
}

/// The module path of a source file: path separators normalized, the
/// crate-layout `src/` prefix stripped, the `.rs` suffix and a trailing
/// `/mod` collapsed. `lib.rs` and `main.rs` stay `lib`/`main`.
pub fn module_of(path: &str) -> String {
    let mut s = path.replace('\\', "/");
    if let Some(i) = s.rfind("/src/") {
        s = s[i + 5..].to_string();
    } else if let Some(rest) = s.strip_prefix("src/") {
        s = rest.to_string();
    }
    if let Some(rest) = s.strip_suffix(".rs") {
        s = rest.to_string();
    }
    if let Some(rest) = s.strip_suffix("/mod") {
        s = rest.to_string();
    } else if s == "mod" {
        s = String::new();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_parses_and_covers_core_modules() {
        let m = Manifest::builtin();
        assert!(m.len() > 20);
        assert_eq!(m.classify("sim/engine"), Some(Domain::Sim));
        assert_eq!(m.classify("fleet/lease"), Some(Domain::Wall));
        assert_eq!(m.classify("campaign/store"), Some(Domain::Mixed));
    }

    #[test]
    fn longest_prefix_wins_on_segment_boundaries() {
        let src = "[modules]\ncoordinator = \"sim\"\n\"coordinator/service\" = \"mixed\"\n";
        let m = Manifest::parse(src).unwrap();
        assert_eq!(m.classify("coordinator"), Some(Domain::Sim));
        assert_eq!(m.classify("coordinator/metrics"), Some(Domain::Sim));
        assert_eq!(m.classify("coordinator/service"), Some(Domain::Mixed));
        // `coordinators` must not match the `coordinator` prefix.
        assert_eq!(m.classify("coordinators"), None);
    }

    #[test]
    fn bad_manifests_are_rejected() {
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("a = \"sim\"\n").is_err(), "entry before section");
        assert!(Manifest::parse("[mods]\na = \"sim\"\n").is_err(), "unknown section");
        assert!(Manifest::parse("[modules]\na = \"simulated\"\n").is_err(), "bad domain");
        assert!(Manifest::parse("[modules]\na = sim\n").is_err(), "unquoted domain");
        assert!(
            Manifest::parse("[modules]\na = \"sim\"\na = \"wall\"\n").is_err(),
            "duplicate key"
        );
    }

    #[test]
    fn module_of_strips_layout() {
        assert_eq!(module_of("rust/src/campaign/store.rs"), "campaign/store");
        assert_eq!(module_of("src/lib.rs"), "lib");
        assert_eq!(module_of("rust/src/obs/mod.rs"), "obs");
        assert_eq!(module_of("campaign/store.rs"), "campaign/store");
        assert_eq!(module_of("main.rs"), "main");
    }
}
