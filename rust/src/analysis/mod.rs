//! Determinism-domain static analysis over this repository's own
//! sources (`occamy audit`).
//!
//! The simulator's contract is bit-identical output for identical
//! inputs: trace-store memoization, campaign resume and recorded
//! interference curves all reuse or compare bytes across runs. The
//! classes of Rust code that silently break that contract are known —
//! wall-clock reads, unordered `HashMap`/`HashSet` iteration, entropy
//! sources, unjustified atomic orderings, order-sensitive float
//! reductions — and every one of them type-checks fine, so they arrive
//! by accident and surface weeks later as a flaky cache hit. This
//! module gates them in CI instead.
//!
//! Layout:
//! - [`domains`]: the `sim`/`wall`/`mixed` classification and the
//!   `rust/analysis.toml` manifest (compiled in, longest-prefix match).
//! - [`rules`]: the comment/string stripper and the per-line rules,
//!   with `// audit:allow(<rule>) -- reason` suppression pragmas.
//! - This file: the sorted filesystem walk, finding aggregation, and
//!   byte-deterministic text/JSON renderers (findings sorted by
//!   position, JSON keys sorted by `runtime::json`).
//!
//! The pass is intentionally dependency-free (no `syn`, no `serde`) and
//! conservative: what it cannot type it does not flag, and every
//! finding names an exact `path:line` a reviewer can check in seconds.

pub mod domains;
pub mod rules;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::runtime::json::Json;

pub use domains::{module_of, Domain, Manifest};
pub use rules::{scan_source, Scan};

/// One rule violation (or meta finding) at an exact source location.
///
/// The derived `Ord` (path, line, rule, message) is the report order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// File path as given to the audit, normalized to `/` separators.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule name: one of [`rules::RULES`] or a meta rule
    /// ([`rules::BAD_PRAGMA`], [`rules::UNKNOWN_MODULE`]).
    pub rule: &'static str,
    pub message: String,
}

/// The aggregated result of auditing a set of paths.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings, sorted by (path, line, rule, message).
    pub findings: Vec<Finding>,
    /// Findings silenced by valid `audit:allow` pragmas.
    pub suppressed: usize,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

/// Audit every `.rs` file under `paths` (files or directories) against
/// the manifest. Directories are walked in sorted order so the report
/// is byte-identical across runs and machines.
pub fn audit_paths(manifest: &Manifest, paths: &[PathBuf]) -> anyhow::Result<Report> {
    let mut files = Vec::new();
    for path in paths {
        collect_rs_files(path, &mut files)?;
    }
    files.sort();
    files.dedup();

    let mut report = Report::default();
    for file in &files {
        let rel = file.to_string_lossy().replace('\\', "/");
        let module = module_of(&rel);
        let text = fs::read_to_string(file).with_context(|| format!("read {}", file.display()))?;
        match manifest.classify(&module) {
            Some(domain) => {
                let scan = scan_source(&rel, domain, &text);
                report.findings.extend(scan.findings);
                report.suppressed += scan.suppressed;
            }
            None => report.findings.push(Finding {
                path: rel.clone(),
                line: 1,
                rule: rules::UNKNOWN_MODULE,
                message: format!(
                    "module `{module}` is not classified in analysis.toml; add it to [modules]"
                ),
            }),
        }
        report.files += 1;
    }
    report.findings.sort();
    Ok(report)
}

fn collect_rs_files(path: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if path.is_dir() {
        let mut entries = Vec::new();
        let dir = fs::read_dir(path).with_context(|| format!("read dir {}", path.display()))?;
        for entry in dir {
            entries.push(entry?.path());
        }
        entries.sort();
        for child in entries {
            collect_rs_files(&child, out)?;
        }
        return Ok(());
    }
    if !path.exists() {
        anyhow::bail!("audit path {} does not exist", path.display());
    }
    if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

/// Render the human-readable report: one `path:line: rule: message`
/// line per finding plus a one-line summary trailer.
pub fn render_text(report: &Report) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: {}: {}\n", f.path, f.line, f.rule, f.message));
    }
    out.push_str(&format!(
        "audit: {} finding(s), {} suppressed, {} file(s) scanned\n",
        report.findings.len(),
        report.suppressed,
        report.files
    ));
    out
}

/// Render the machine-readable report as a single line of JSON with
/// sorted keys: byte-identical across runs for identical inputs.
pub fn render_json(report: &Report) -> String {
    let findings: Vec<Json> = report
        .findings
        .iter()
        .map(|f| {
            let mut o = BTreeMap::new();
            o.insert("line".to_string(), Json::Num(f.line as f64));
            o.insert("message".to_string(), Json::Str(f.message.clone()));
            o.insert("path".to_string(), Json::Str(f.path.clone()));
            o.insert("rule".to_string(), Json::Str(f.rule.to_string()));
            Json::Obj(o)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("version".to_string(), Json::Num(1.0));
    root.insert("files".to_string(), Json::Num(report.files as f64));
    root.insert("suppressed".to_string(), Json::Num(report.suppressed as f64));
    root.insert("findings".to_string(), Json::Arr(findings));
    format!("{}\n", Json::Obj(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut report = Report {
            findings: vec![
                Finding {
                    path: "src/b.rs".to_string(),
                    line: 3,
                    rule: rules::WALL_CLOCK_IN_SIM,
                    message: "b".to_string(),
                },
                Finding {
                    path: "src/a.rs".to_string(),
                    line: 9,
                    rule: rules::ENTROPY_IN_SIM,
                    message: "a".to_string(),
                },
            ],
            suppressed: 1,
            files: 2,
        };
        report.findings.sort();
        report
    }

    #[test]
    fn text_report_is_sorted_and_has_trailer() {
        let text = render_text(&sample());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("src/a.rs:9: entropy-in-sim:"), "{text}");
        assert!(lines[1].starts_with("src/b.rs:3: wall-clock-in-sim:"), "{text}");
        assert_eq!(lines[2], "audit: 2 finding(s), 1 suppressed, 2 file(s) scanned");
    }

    #[test]
    fn json_report_is_single_line_and_stable() {
        let a = render_json(&sample());
        let b = render_json(&sample());
        assert_eq!(a, b, "render must be byte-deterministic");
        assert!(a.ends_with('\n'));
        assert_eq!(a.lines().count(), 1);
        let parsed = Json::parse(a.trim()).unwrap();
        assert_eq!(parsed.get("version").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.get("files").and_then(Json::as_u64), Some(2));
        let findings = parsed.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(findings.len(), 2);
        assert_eq!(findings[0].get("rule").and_then(Json::as_str), Some("entropy-in-sim"));
    }

    #[test]
    fn empty_report_renders_cleanly() {
        let report = Report::default();
        assert_eq!(render_text(&report), "audit: 0 finding(s), 0 suppressed, 0 file(s) scanned\n");
        let json = render_json(&report);
        let parsed = Json::parse(json.trim()).unwrap();
        assert_eq!(parsed.get("findings").and_then(Json::as_arr).map(<[Json]>::len), Some(0));
    }
}
