//! Typed offload requests — the unit of work a sweep executes.

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::{Executor, RoutineKind};
use crate::sim::Trace;

/// One fully-specified DES run: which job, on how many clusters, with
/// which offload routine. Doubles as the trace-cache key (it is
/// `Copy + Eq + Hash`) and as the point identity of the campaign
/// store's on-disk layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OffloadRequest {
    pub spec: JobSpec,
    pub n_clusters: usize,
    pub routine: RoutineKind,
}

impl OffloadRequest {
    pub fn new(spec: JobSpec, n_clusters: usize, routine: RoutineKind) -> Self {
        Self {
            spec,
            n_clusters,
            routine,
        }
    }

    /// The base/ideal/improved requests of one (spec, n) configuration —
    /// the unit behind every figure of §5.
    pub fn triple(spec: JobSpec, n_clusters: usize) -> [Self; 3] {
        [
            Self::new(spec, n_clusters, RoutineKind::Baseline),
            Self::new(spec, n_clusters, RoutineKind::Ideal),
            Self::new(spec, n_clusters, RoutineKind::Multicast),
        ]
    }

    /// Execute the request on the DES, bypassing the trace cache. Panics
    /// if `n_clusters` is zero or exceeds the SoC geometry (the same
    /// contract as `offload::Executor::new`).
    pub fn run(&self, cfg: &Config) -> Trace {
        Executor::new(cfg, &self.spec, self.n_clusters, self.routine).run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_covers_base_ideal_improved() {
        let spec = JobSpec::Axpy { n: 64 };
        let [b, i, m] = OffloadRequest::triple(spec, 4);
        assert_eq!(b.routine, RoutineKind::Baseline);
        assert_eq!(i.routine, RoutineKind::Ideal);
        assert_eq!(m.routine, RoutineKind::Multicast);
        assert!([b, i, m].iter().all(|r| r.spec == spec && r.n_clusters == 4));
    }

    #[test]
    fn run_matches_direct_executor() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 256 }, 4, RoutineKind::Multicast);
        let a = req.run(&cfg);
        let b = Executor::new(&cfg, &req.spec, 4, RoutineKind::Multicast).run();
        assert_eq!(a.total, b.total);
        assert_eq!(a.events, b.events);
    }
}
