//! Typed offload requests — the unit of work a sweep executes — and
//! their interference-level counterparts, which replay one request
//! `n_jobs` times through the coordinator's occupancy model so offload
//! overheads are measured under contention, not just in isolation.

use crate::config::Config;
use crate::coordinator::{OccupancyModel, OccupancyParams, JCU_SLOTS};
use crate::kernels::JobSpec;
use crate::offload::{Executor, RoutineKind};
use crate::sim::{SimProfile, Time, Trace};

/// One fully-specified DES run: which job, on how many clusters, with
/// which offload routine. Doubles as the trace-cache key (it is
/// `Copy + Eq + Ord + Hash`) and as the point identity of the campaign
/// store's on-disk layout; `Ord` keeps every container keyed on
/// requests iterable in a deterministic order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OffloadRequest {
    pub spec: JobSpec,
    pub n_clusters: usize,
    pub routine: RoutineKind,
}

impl OffloadRequest {
    pub fn new(spec: JobSpec, n_clusters: usize, routine: RoutineKind) -> Self {
        Self {
            spec,
            n_clusters,
            routine,
        }
    }

    /// The base/ideal/improved requests of one (spec, n) configuration —
    /// the unit behind every figure of §5.
    pub fn triple(spec: JobSpec, n_clusters: usize) -> [Self; 3] {
        [
            Self::new(spec, n_clusters, RoutineKind::Baseline),
            Self::new(spec, n_clusters, RoutineKind::Ideal),
            Self::new(spec, n_clusters, RoutineKind::Multicast),
        ]
    }

    /// Execute the request on the DES, bypassing the trace cache. Panics
    /// if `n_clusters` is zero or exceeds the SoC geometry (the same
    /// contract as `offload::Executor::new`).
    pub fn run(&self, cfg: &Config) -> Trace {
        Executor::new(cfg, &self.spec, self.n_clusters, self.routine).run()
    }

    /// Like [`OffloadRequest::run`] but under an explicit engine profile
    /// (`fast` elides heap work and replays memoized timelines; see
    /// `sim::fast`).
    pub fn run_with(&self, cfg: &Config, profile: SimProfile) -> Trace {
        Executor::with_profile(cfg, &self.spec, self.n_clusters, self.routine, profile).run()
    }
}

/// One interference point: `n_jobs` copies of an [`OffloadRequest`]
/// pushed through the shared fabric with `inflight` of them kept
/// outstanding. The isolated DES trace is computed once (it is
/// contention-independent); contention is modeled by the coordinator's
/// occupancy engine on top of it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct InterferenceRequest {
    pub req: OffloadRequest,
    /// Jobs kept outstanding (closed-loop window). 1 = the serial
    /// coordinator: zero queueing delay by construction.
    pub inflight: usize,
    /// Jobs replayed through the window.
    pub n_jobs: usize,
    /// Minimum virtual cycles between consecutive arrivals.
    pub arrival_gap: Time,
}

impl InterferenceRequest {
    pub fn new(req: OffloadRequest, inflight: usize, n_jobs: usize, arrival_gap: Time) -> Self {
        Self {
            req,
            inflight,
            n_jobs,
            arrival_gap,
        }
    }

    /// The occupancy-model parameters this request schedules under.
    pub fn params(&self, cfg: &Config) -> OccupancyParams {
        OccupancyParams {
            capacity: cfg.soc.n_clusters(),
            jcu_slots: JCU_SLOTS,
            inflight: self.inflight,
            arrival_gap: self.arrival_gap,
        }
    }

    /// Schedule the request given an already-known isolated runtime
    /// (e.g. a trace restored from merged campaign output) — no
    /// simulation runs, only the deterministic occupancy model.
    pub fn run_on(&self, cfg: &Config, isolated: Time) -> InterferenceOutcome {
        let mut model = OccupancyModel::new(self.params(cfg));
        let mut queue_delays = Vec::with_capacity(self.n_jobs);
        let mut makespan = 0;
        for _ in 0..self.n_jobs {
            let adm = model.admit(self.req.n_clusters, isolated);
            queue_delays.push(adm.queue_delay);
            makespan = makespan.max(adm.completion);
        }
        model.finish();
        InterferenceOutcome {
            isolated,
            queue_delays,
            makespan,
        }
    }

    /// Simulate the isolated request through the trace cache, then
    /// schedule it under contention.
    pub fn run(&self, cfg: &Config) -> InterferenceOutcome {
        let isolated = super::cache::run_cached(cfg, self.req).total;
        self.run_on(cfg, isolated)
    }
}

/// The deterministic schedule of one interference point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterferenceOutcome {
    /// Isolated DES runtime of one job (the service time).
    pub isolated: Time,
    /// Per-job queueing delay, in admission order. All zero when
    /// `inflight = 1`.
    pub queue_delays: Vec<Time>,
    /// Completion time of the last job on the virtual timeline.
    pub makespan: Time,
}

impl InterferenceOutcome {
    pub fn n_jobs(&self) -> usize {
        self.queue_delays.len()
    }

    pub fn total_queue_delay(&self) -> Time {
        self.queue_delays.iter().sum()
    }

    pub fn max_queue_delay(&self) -> Time {
        self.queue_delays.iter().copied().max().unwrap_or(0)
    }

    pub fn mean_queue_delay(&self) -> f64 {
        if self.queue_delays.is_empty() {
            0.0
        } else {
            self.total_queue_delay() as f64 / self.queue_delays.len() as f64
        }
    }

    /// Mean end-to-end latency: isolated service time + mean queueing
    /// delay (the decomposition the acceptance criteria pin down).
    pub fn mean_latency(&self) -> f64 {
        self.isolated as f64 + self.mean_queue_delay()
    }
}

/// One labelled interference grid point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct InterferencePoint {
    pub label: &'static str,
    pub ireq: InterferenceRequest,
}

/// One executed interference point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterferenceSample {
    pub point: InterferencePoint,
    pub outcome: InterferenceOutcome,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triple_covers_base_ideal_improved() {
        let spec = JobSpec::Axpy { n: 64 };
        let [b, i, m] = OffloadRequest::triple(spec, 4);
        assert_eq!(b.routine, RoutineKind::Baseline);
        assert_eq!(i.routine, RoutineKind::Ideal);
        assert_eq!(m.routine, RoutineKind::Multicast);
        assert!([b, i, m].iter().all(|r| r.spec == spec && r.n_clusters == 4));
    }

    #[test]
    fn run_matches_direct_executor() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 256 }, 4, RoutineKind::Multicast);
        let a = req.run(&cfg);
        let b = Executor::new(&cfg, &req.spec, 4, RoutineKind::Multicast).run();
        assert_eq!(a.total, b.total);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn serial_interference_matches_isolated_runs() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 512 }, 8, RoutineKind::Multicast);
        let out = InterferenceRequest::new(req, 1, 6, 0).run(&cfg);
        assert_eq!(out.isolated, super::super::run_one(&cfg, req).total);
        assert_eq!(out.n_jobs(), 6);
        assert!(out.queue_delays.iter().all(|&d| d == 0));
        assert_eq!(out.makespan, out.isolated * 6, "back-to-back serial jobs");
        assert_eq!(out.mean_latency(), out.isolated as f64);
    }

    #[test]
    fn contended_interference_adds_nonnegative_delay() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 512 }, 16, RoutineKind::Multicast);
        let ireq = InterferenceRequest::new(req, 4, 8, 0);
        let out = ireq.run(&cfg);
        // Two 16-wide jobs fit the 32-cluster fabric; the rest queue.
        assert_eq!(out.queue_delays[0], 0);
        assert_eq!(out.queue_delays[1], 0);
        assert!(out.queue_delays[2] > 0);
        assert!(out.total_queue_delay() > 0);
        assert!(out.mean_latency() > out.isolated as f64);
        // run_on with the same isolated runtime is the same schedule.
        assert_eq!(ireq.run_on(&cfg, out.isolated), out);
    }
}
