//! Process-wide trace cache keyed by (config key, request).
//!
//! The DES is deterministic: identical (config, spec, n_clusters,
//! routine) inputs always produce bit-identical traces. Figures 7-10 all
//! sweep the same base/ideal triples, so caching at this boundary makes
//! every shared trace a one-time cost per process. The config key is the
//! complete flat-TOML serialization (`Config::to_toml` writes every
//! field), so distinct configs can never alias — no hash-collision
//! caveat. Entries live for the process lifetime (experiment grids are
//! hundreds of traces, not millions); long-running embedders like the
//! coordinator use [`peek`] + their own lightweight totals memo instead
//! of inserting full traces here, and [`clear`] exists for tests.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use crate::config::Config;
use crate::sim::{SimProfile, Trace};

use super::request::OffloadRequest;

// Ordered maps, not hash maps: the cache sits in the sim domain, where
// `occamy audit` forbids unordered iteration — `cached_runs` walks the
// shards, and a BTreeMap makes that walk (and any future one)
// deterministic by construction.
type Shard = BTreeMap<OffloadRequest, Arc<Trace>>;

fn cache() -> &'static Mutex<BTreeMap<String, Shard>> {
    static CACHE: OnceLock<Mutex<BTreeMap<String, Shard>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Lock the cache, recovering from poisoning. A worker that panics while
/// holding the lock only ever leaves the map in a consistent state (plain
/// inserts of immutable `Arc<Trace>`s), so the poison flag carries no
/// information — and propagating it would wedge every remaining worker of
/// a campaign shard behind one panicking sweep.
fn lock() -> MutexGuard<'static, BTreeMap<String, Shard>> {
    cache().lock().unwrap_or_else(PoisonError::into_inner)
}

/// The cache key of a configuration: its complete, field-exhaustive
/// flat-TOML serialization. Compute it once per campaign — serializing
/// on every lookup is the expensive part, not the hash.
pub fn config_key(cfg: &Config) -> String {
    cfg.to_toml()
}

/// Look up a trace without simulating or inserting. `key` must come from
/// [`config_key`] for the config the request targets.
pub fn peek(key: &str, req: OffloadRequest) -> Option<Arc<Trace>> {
    lock()
        .get(key)
        .and_then(|shard| shard.get(&req))
        .map(Arc::clone)
}

/// Insert an externally-produced trace (e.g. one loaded from the
/// campaign's on-disk store) so later in-process lookups share it. An
/// existing entry wins — the DES is deterministic, so both are equal,
/// and keeping the first preserves `Arc` sharing with earlier results.
pub fn insert(key: &str, req: OffloadRequest, trace: Arc<Trace>) -> Arc<Trace> {
    let mut guard = lock();
    Arc::clone(
        guard
            .entry(key.to_string())
            .or_default()
            .entry(req)
            .or_insert(trace),
    )
}

/// Run a request through the cache with a precomputed [`config_key`]:
/// a hit returns the shared trace, a miss simulates and stores it.
pub fn run_cached_keyed(key: &str, cfg: &Config, req: OffloadRequest) -> Arc<Trace> {
    if let Some(t) = peek(key, req) {
        return t;
    }
    // Simulate outside the lock: concurrent misses on the same key do
    // redundant (deterministic, so harmless) work instead of serializing
    // every sweep worker behind one mutex.
    insert(key, req, Arc::new(req.run(cfg)))
}

/// Run a request through the cache (one-off convenience; serializes the
/// config per call — use [`run_cached_keyed`] inside loops).
pub fn run_cached(cfg: &Config, req: OffloadRequest) -> Arc<Trace> {
    run_cached_keyed(&config_key(cfg), cfg, req)
}

/// The cache key of a configuration under an engine profile. The
/// reference profile keeps the bare [`config_key`] (every existing
/// caller stays on it); the fast profile appends a discriminator line
/// that no flat-TOML serialization can contain, so fast-produced
/// entries are never served to a reference run — the bit-identity
/// harness vouches for equality, the cache does not assume it.
pub fn profiled_config_key(cfg: &Config, profile: SimProfile) -> String {
    match profile {
        SimProfile::Reference => config_key(cfg),
        SimProfile::Fast => format!("{}#profile = \"fast\"\n", cfg.to_toml()),
    }
}

/// [`run_cached_keyed`] under an explicit engine profile. `key` must
/// come from [`profiled_config_key`] with the same profile.
pub fn run_cached_profiled(
    key: &str,
    cfg: &Config,
    req: OffloadRequest,
    profile: SimProfile,
) -> Arc<Trace> {
    if let Some(t) = peek(key, req) {
        return t;
    }
    insert(key, req, Arc::new(req.run_with(cfg, profile)))
}

/// Number of traces currently cached, across all configs (diagnostics).
pub fn cached_runs() -> usize {
    lock().values().map(Shard::len).sum()
}

/// Drop every cached trace.
pub fn clear() {
    lock().clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::JobSpec;
    use crate::offload::RoutineKind;

    #[test]
    fn hit_returns_the_same_arc() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 128 }, 2, RoutineKind::Baseline);
        let a = run_cached(&cfg, req);
        let b = run_cached(&cfg, req);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.total, b.total);
    }

    #[test]
    fn peek_never_inserts() {
        let cfg = Config::default();
        let key = config_key(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 80 }, 2, RoutineKind::Ideal);
        if peek(&key, req).is_none() {
            // Still absent after peeking.
            assert!(peek(&key, req).is_none());
        }
        let inserted = run_cached_keyed(&key, &cfg, req);
        let peeked = peek(&key, req).expect("present after run_cached");
        assert!(Arc::ptr_eq(&inserted, &peeked));
    }

    #[test]
    fn different_configs_do_not_alias() {
        let cfg = Config::default();
        let mut slow = cfg.clone();
        slow.timing.host_ipi_issue_gap *= 2;
        assert_ne!(config_key(&cfg), config_key(&slow));
        let req = OffloadRequest::new(JobSpec::Axpy { n: 128 }, 8, RoutineKind::Baseline);
        let a = run_cached(&cfg, req);
        let b = run_cached(&slow, req);
        assert!(!Arc::ptr_eq(&a, &b), "distinct configs must not alias");
    }

    #[test]
    fn config_key_is_stable_across_clones() {
        let cfg = Config::default();
        assert_eq!(config_key(&cfg), config_key(&cfg.clone()));
    }

    #[test]
    fn insert_keeps_the_first_entry() {
        let cfg = Config::default();
        let key = config_key(&cfg);
        let req = OffloadRequest::new(JobSpec::Axpy { n: 96 }, 2, RoutineKind::Multicast);
        let first = run_cached_keyed(&key, &cfg, req);
        // Re-inserting an equal (deterministic) trace returns the
        // original Arc, preserving sharing.
        let other = Arc::new(req.run(&cfg));
        let kept = insert(&key, req, other);
        assert!(Arc::ptr_eq(&first, &kept));
    }

    #[test]
    fn lock_recovers_from_poisoning() {
        // A worker panicking while holding the cache lock must not wedge
        // the rest of the campaign shard.
        let _ = std::panic::catch_unwind(|| {
            let _guard = super::cache().lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            panic!("poison the cache lock");
        });
        // Any accessor still works afterwards.
        let _ = cached_runs();
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 112 }, 2, RoutineKind::Ideal);
        let t = run_cached(&cfg, req);
        assert!(t.total > 0);
    }
}
