//! The sweep builder: declarative cartesian experiment campaigns.

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::RoutineKind;
use crate::sim::SimProfile;

use super::exec;
use super::request::{
    InterferencePoint, InterferenceRequest, InterferenceSample, OffloadRequest,
};
use super::results::{SweepPoint, SweepResults};

/// The routines behind every figure's base/ideal/improved triple, in
/// triple order.
pub const TRIPLE_ROUTINES: [RoutineKind; 3] = [
    RoutineKind::Baseline,
    RoutineKind::Ideal,
    RoutineKind::Multicast,
];

/// A typed experiment campaign: a (kernels × clusters × routines)
/// cartesian grid plus optional custom points, executed in parallel with
/// deterministic, input-ordered results.
///
/// Expansion order is kernels outermost, then clusters, then routines
/// (innermost), followed by custom points in insertion order. If no
/// routines are given the grid defaults to [`TRIPLE_ROUTINES`].
#[derive(Debug, Clone, Default)]
pub struct Sweep {
    kernels: Vec<(&'static str, JobSpec)>,
    clusters: Vec<usize>,
    routines: Vec<RoutineKind>,
    inflight: Vec<usize>,
    extra: Vec<SweepPoint>,
    serial: bool,
    uncached: bool,
    profile: SimProfile,
}

impl Sweep {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start from a labelled kernel set (e.g. `exp::benchmark_set()`).
    pub fn over_kernels(kernels: impl IntoIterator<Item = (&'static str, JobSpec)>) -> Self {
        Self {
            kernels: kernels.into_iter().collect(),
            ..Self::default()
        }
    }

    /// Add one labelled kernel. The same label may appear with several
    /// specs (problem-size sweeps à la Fig. 10).
    pub fn kernel(mut self, label: &'static str, spec: JobSpec) -> Self {
        self.kernels.push((label, spec));
        self
    }

    /// Add cluster counts to the grid.
    pub fn clusters(mut self, counts: impl IntoIterator<Item = usize>) -> Self {
        self.clusters.extend(counts);
        self
    }

    /// Add routines to the grid (default when never called:
    /// [`TRIPLE_ROUTINES`]).
    pub fn routines(mut self, routines: impl IntoIterator<Item = RoutineKind>) -> Self {
        self.routines.extend(routines);
        self
    }

    /// Sweep the base/ideal/improved triple (explicit spelling of the
    /// default).
    pub fn triples(self) -> Self {
        self.routines(TRIPLE_ROUTINES)
    }

    /// Add jobs-in-flight counts to the contention axis. The axis only
    /// affects the interference expansion
    /// ([`Sweep::expand_interference`] / [`Sweep::run_interference`]):
    /// isolated traces are contention-independent, so [`Sweep::expand`]
    /// and [`Sweep::run`] ignore it. Default when never called: `[1]`
    /// (the serial coordinator).
    pub fn inflight(mut self, counts: impl IntoIterator<Item = usize>) -> Self {
        self.inflight.extend(counts);
        self
    }

    /// Append one custom point outside the cartesian grid.
    pub fn point(mut self, label: &'static str, req: OffloadRequest) -> Self {
        self.extra.push(SweepPoint { label, req });
        self
    }

    /// Append custom points outside the cartesian grid.
    pub fn points(
        mut self,
        points: impl IntoIterator<Item = (&'static str, OffloadRequest)>,
    ) -> Self {
        self.extra
            .extend(points.into_iter().map(|(label, req)| SweepPoint { label, req }));
        self
    }

    /// Run on the calling thread only (the executor parallelizes by
    /// default; results are bit-identical either way).
    pub fn serial(mut self) -> Self {
        self.serial = true;
        self
    }

    /// Bypass the process-wide trace cache (honest wall-clock benches).
    pub fn uncached(mut self) -> Self {
        self.uncached = true;
        self
    }

    /// Select the engine profile (default: the reference DES). The fast
    /// profile is bit-identical — see `sim::fast` and
    /// `tests/integration_profiles.rs` — but keeps its cache entries
    /// under a separate key out of caution.
    pub fn profile(mut self, profile: SimProfile) -> Self {
        self.profile = profile;
        self
    }

    /// Expand to the ordered point list without running anything.
    /// Cluster counts and routines are deduplicated (first occurrence
    /// wins), so repeated `clusters`/`routines`/`triples` calls cannot
    /// silently inflate the grid; custom points are taken verbatim.
    pub fn expand(&self) -> Vec<SweepPoint> {
        let routines: Vec<RoutineKind> = if self.routines.is_empty() {
            TRIPLE_ROUTINES.to_vec()
        } else {
            dedup_preserving_order(&self.routines)
        };
        let clusters = dedup_preserving_order(&self.clusters);
        let mut out = Vec::with_capacity(
            self.kernels.len() * clusters.len() * routines.len() + self.extra.len(),
        );
        for &(label, spec) in &self.kernels {
            for &n_clusters in &clusters {
                for &routine in &routines {
                    out.push(SweepPoint {
                        label,
                        req: OffloadRequest::new(spec, n_clusters, routine),
                    });
                }
            }
        }
        out.extend(self.extra.iter().copied());
        out
    }

    /// Execute the campaign and return input-ordered results.
    pub fn run(&self, cfg: &Config) -> SweepResults {
        let points = self.expand();
        let records = exec::execute(cfg, &points, !self.serial, !self.uncached, self.profile);
        SweepResults::new(records)
    }

    /// Expand the interference grid: every trace point crossed with the
    /// `inflight` axis (innermost, deduplicated; `[1]` when the axis was
    /// never set), each replaying `n_jobs` jobs spaced `arrival_gap`
    /// cycles apart.
    pub fn expand_interference(
        &self,
        n_jobs: usize,
        arrival_gap: crate::sim::Time,
    ) -> Vec<InterferencePoint> {
        let counts: Vec<usize> = if self.inflight.is_empty() {
            vec![1]
        } else {
            dedup_preserving_order(&self.inflight)
        };
        let points = self.expand();
        let mut out = Vec::with_capacity(points.len() * counts.len());
        for p in &points {
            for &inflight in &counts {
                out.push(InterferencePoint {
                    label: p.label,
                    ireq: InterferenceRequest::new(p.req, inflight, n_jobs, arrival_gap),
                });
            }
        }
        out
    }

    /// Execute the interference grid: the isolated traces run through
    /// the ordinary (parallel, cached) sweep executor first, then each
    /// (point, inflight) gets its deterministic occupancy schedule on
    /// top of its isolated total. Results are input-ordered.
    pub fn run_interference(
        &self,
        cfg: &Config,
        n_jobs: usize,
        arrival_gap: crate::sim::Time,
    ) -> Vec<InterferenceSample> {
        let traces = self.run(cfg);
        self.expand_interference(n_jobs, arrival_gap)
            .into_iter()
            .map(|point| {
                let isolated = traces
                    .isolated_total(point.label, point.ireq.req)
                    .expect("the interference grid is the trace grid crossed with inflight");
                InterferenceSample {
                    point,
                    outcome: point.ireq.run_on(cfg, isolated),
                }
            })
            .collect()
    }
}

fn dedup_preserving_order<T: Copy + PartialEq>(xs: &[T]) -> Vec<T> {
    let mut out: Vec<T> = Vec::with_capacity(xs.len());
    for &x in xs {
        if !out.contains(&x) {
            out.push(x);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion_order_is_kernels_clusters_routines() {
        let sweep = Sweep::new()
            .kernel("a", JobSpec::Axpy { n: 64 })
            .kernel("b", JobSpec::Atax { m: 16, n: 16 })
            .clusters([1, 2])
            .routines([RoutineKind::Baseline, RoutineKind::Ideal])
            .point(
                "custom",
                OffloadRequest::new(JobSpec::Axpy { n: 32 }, 4, RoutineKind::Multicast),
            );
        let points = sweep.expand();
        assert_eq!(points.len(), 2 * 2 * 2 + 1);
        assert_eq!(points[0].label, "a");
        assert_eq!(points[0].req.n_clusters, 1);
        assert_eq!(points[0].req.routine, RoutineKind::Baseline);
        assert_eq!(points[1].req.routine, RoutineKind::Ideal);
        assert_eq!(points[2].req.n_clusters, 2);
        assert_eq!(points[4].label, "b");
        assert_eq!(points[8].label, "custom");
        assert_eq!(points[8].req.n_clusters, 4);
    }

    #[test]
    fn empty_routines_default_to_triple() {
        let points = Sweep::new()
            .kernel("a", JobSpec::Axpy { n: 64 })
            .clusters([8])
            .expand();
        let routines: Vec<RoutineKind> = points.iter().map(|p| p.req.routine).collect();
        assert_eq!(routines, TRIPLE_ROUTINES.to_vec());
    }

    #[test]
    fn repeated_routines_and_clusters_do_not_inflate_the_grid() {
        // `.routines([Baseline]).triples()` and duplicate cluster counts
        // must not duplicate points.
        let points = Sweep::new()
            .kernel("a", JobSpec::Axpy { n: 64 })
            .clusters([8, 8])
            .clusters([8])
            .routines([RoutineKind::Baseline])
            .triples()
            .expand();
        let routines: Vec<RoutineKind> = points.iter().map(|p| p.req.routine).collect();
        assert_eq!(routines, TRIPLE_ROUTINES.to_vec());
        assert!(points.iter().all(|p| p.req.n_clusters == 8));
    }

    #[test]
    fn inflight_axis_only_affects_the_interference_expansion() {
        let sweep = Sweep::new()
            .kernel("a", JobSpec::Axpy { n: 64 })
            .clusters([8])
            .routines([RoutineKind::Multicast])
            .inflight([1, 4, 4, 2]);
        // Trace expansion unchanged by the contention axis.
        assert_eq!(sweep.expand().len(), 1);
        let ipoints = sweep.expand_interference(16, 0);
        let counts: Vec<usize> = ipoints.iter().map(|p| p.ireq.inflight).collect();
        assert_eq!(counts, vec![1, 4, 2], "deduplicated, first occurrence wins");
        assert!(ipoints
            .iter()
            .all(|p| p.ireq.n_jobs == 16 && p.ireq.arrival_gap == 0));
        // Default axis: the serial coordinator.
        let serial = Sweep::new()
            .kernel("a", JobSpec::Axpy { n: 64 })
            .clusters([8])
            .routines([RoutineKind::Multicast])
            .expand_interference(4, 0);
        assert_eq!(serial.len(), 1);
        assert_eq!(serial[0].ireq.inflight, 1);
    }

    #[test]
    fn run_interference_is_ordered_and_decomposes() {
        let cfg = Config::default();
        let samples = Sweep::new()
            .kernel("axpy", JobSpec::Axpy { n: 512 })
            .clusters([16])
            .routines([RoutineKind::Multicast])
            .inflight([1, 4])
            .run_interference(&cfg, 8, 0);
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].point.ireq.inflight, 1);
        assert_eq!(samples[0].outcome.total_queue_delay(), 0);
        assert_eq!(samples[1].point.ireq.inflight, 4);
        assert!(samples[1].outcome.total_queue_delay() > 0);
        // Same isolated service time on both rows.
        assert_eq!(samples[0].outcome.isolated, samples[1].outcome.isolated);
    }

    #[test]
    fn run_produces_one_record_per_point() {
        let cfg = Config::default();
        let sweep = Sweep::new()
            .kernel("axpy", JobSpec::Axpy { n: 64 })
            .clusters([1, 2])
            .routines([RoutineKind::Multicast]);
        let results = sweep.run(&cfg);
        assert_eq!(results.len(), 2);
        let expanded = sweep.expand();
        for (rec, p) in results.records().iter().zip(&expanded) {
            assert_eq!(rec.point, *p);
            assert!(rec.total() > 0);
        }
    }
}
