//! # Typed experiment campaigns (`sweep`)
//!
//! Every figure in the paper's evaluation (§5.2–§5.6) is a sweep over
//! (kernel × n_clusters × routine). This subsystem turns that shape into
//! a first-class API so the figure modules, benches and examples are
//! declarative descriptions instead of hand-rolled nested loops:
//!
//! * [`OffloadRequest`] — a typed request (spec, n_clusters, routine),
//!   the unit of work a sweep executes and the trace-cache key.
//! * [`Sweep`] — a builder expanding cartesian grids
//!   (`Sweep::over_kernels(..).clusters(..).routines(..)`) plus custom
//!   point lists, executed by a scoped worker pool (each DES run is
//!   independent) with deterministic, input-ordered [`SweepResults`].
//! * Result combinators — [`SweepResults::group_by`],
//!   [`SweepResults::triples`], [`SweepResults::triple_of`],
//!   overhead/speedup projections, and [`mean_std`].
//! * A process-wide trace [`cache`] keyed by (config key, request), so
//!   base/ideal traces shared between figures are computed once per
//!   process.
//! * Contention as a first-class axis — [`Sweep::inflight`] crosses the
//!   grid with jobs-in-flight counts, and [`InterferenceRequest`]
//!   replays a request through the coordinator's shared-fabric
//!   occupancy model, decomposing latency into the isolated service
//!   time plus a nonnegative queueing delay (`inflight = 1` is the
//!   serial coordinator: zero delay, bit-identical cycles).
//!
//! ## Quickstart
//!
//! Mirrors `examples/quickstart.rs`:
//!
//! ```
//! use occamy_offload::config::Config;
//! use occamy_offload::kernels::JobSpec;
//! use occamy_offload::sweep::Sweep;
//!
//! let cfg = Config::default();
//! let results = Sweep::new()
//!     .kernel("axpy", JobSpec::Axpy { n: 256 })
//!     .clusters([1, 8])
//!     .triples() // base/ideal/improved, the unit of every figure
//!     .run(&cfg);
//! for t in results.triples() {
//!     println!(
//!         "{} @ {} clusters: overhead {} cycles, achieved speedup {:.2}",
//!         t.label,
//!         t.n_clusters,
//!         t.runtimes.overhead(),
//!         t.runtimes.achieved_speedup(),
//!     );
//! }
//! assert_eq!(results.triples().len(), 2);
//! ```
//!
//! Parallel execution never changes results: the grid expands in a fixed
//! order, every record lands at its input index, and the DES itself is
//! deterministic — `sweep.run(&cfg)` is bit-identical to
//! `sweep.serial().run(&cfg)` (property-tested in
//! `tests/integration_sweep.rs`).

pub mod cache;
mod exec;
mod grid;
mod request;
mod results;

pub use grid::{Sweep, TRIPLE_ROUTINES};
pub use request::{
    InterferenceOutcome, InterferencePoint, InterferenceRequest, InterferenceSample,
    OffloadRequest,
};
pub use results::{mean_std, SweepPoint, SweepRecord, SweepResults, TriplePoint};

use std::sync::Arc;

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::offload::RunTriple;
use crate::sim::Trace;

/// Run one request through the process-wide trace cache.
pub fn run_one(cfg: &Config, req: OffloadRequest) -> Arc<Trace> {
    cache::run_cached(cfg, req)
}

/// The base/ideal/improved runtimes of one (spec, n) configuration,
/// through the cache — the unit behind every figure of §5.
pub fn triple(cfg: &Config, spec: &JobSpec, n_clusters: usize) -> RunTriple {
    let [base, ideal, improved] =
        OffloadRequest::triple(*spec, n_clusters).map(|req| run_one(cfg, req).total);
    RunTriple {
        n_clusters,
        base,
        ideal,
        improved,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::RoutineKind;

    #[test]
    fn triple_is_consistent() {
        let cfg = Config::default();
        let spec = JobSpec::Axpy { n: 1024 };
        let t = triple(&cfg, &spec, 8);
        assert!(t.overhead() > 0);
        assert!(t.residual_overhead() > 0);
        assert!(t.residual_overhead() < t.overhead());
        assert!(t.ideal_speedup() > 1.0);
        assert!(t.achieved_speedup() > 1.0);
        let f = t.restored_fraction();
        assert!(f > 0.0 && f <= 1.0, "restored fraction {f}");
    }

    #[test]
    fn run_one_matches_uncached_run() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Atax { m: 16, n: 16 }, 4, RoutineKind::Baseline);
        let cached = run_one(&cfg, req);
        let direct = req.run(&cfg);
        assert_eq!(cached.total, direct.total);
        assert_eq!(cached.cluster_spans, direct.cluster_spans);
    }
}
