//! Sweep results: input-ordered records plus the combinators the figure
//! modules are built from (`group_by`, `triples`, `mean_std`, overhead /
//! speedup projections).

use std::sync::Arc;

use crate::kernels::JobSpec;
use crate::offload::{RoutineKind, RunTriple};
use crate::sim::{Time, Trace};

use super::request::OffloadRequest;

/// One labelled grid point: the label identifies the kernel (or custom
/// point) in result lookups and tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SweepPoint {
    pub label: &'static str,
    pub req: OffloadRequest,
}

/// One executed point: the point plus its (possibly cache-shared) trace.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRecord {
    pub point: SweepPoint,
    pub trace: Arc<Trace>,
}

impl SweepRecord {
    pub fn label(&self) -> &'static str {
        self.point.label
    }

    pub fn req(&self) -> OffloadRequest {
        self.point.req
    }

    /// End-to-end runtime of this run, in cycles.
    pub fn total(&self) -> Time {
        self.trace.total
    }
}

type TripleKey = (&'static str, JobSpec, usize);

/// A collapsed base/ideal/improved triple at one (label, spec, n) point.
#[derive(Debug, Clone)]
pub struct TriplePoint {
    pub label: &'static str,
    pub spec: JobSpec,
    pub n_clusters: usize,
    pub runtimes: RunTriple,
}

/// Results of one sweep, in expansion (input) order — deterministic and
/// independent of the executor's parallelism.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SweepResults {
    records: Vec<SweepRecord>,
}

impl SweepResults {
    pub(crate) fn new(records: Vec<SweepRecord>) -> Self {
        Self { records }
    }

    pub fn records(&self) -> &[SweepRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &SweepRecord> {
        self.records.iter()
    }

    /// First record matching (label, n_clusters, routine).
    pub fn get(&self, label: &str, n_clusters: usize, routine: RoutineKind) -> Option<&SweepRecord> {
        self.records.iter().find(|r| {
            r.label() == label && r.req().n_clusters == n_clusters && r.req().routine == routine
        })
    }

    /// Total runtime at (label, n_clusters, routine).
    pub fn total(&self, label: &str, n_clusters: usize, routine: RoutineKind) -> Option<Time> {
        self.get(label, n_clusters, routine).map(|r| r.total())
    }

    /// Full trace at (label, n_clusters, routine).
    pub fn trace(&self, label: &str, n_clusters: usize, routine: RoutineKind) -> Option<&Trace> {
        self.get(label, n_clusters, routine).map(|r| r.trace.as_ref())
    }

    /// Isolated total of a labelled request (exact request match) — the
    /// service time an interference schedule runs on. One matcher for
    /// the in-process path (`Sweep::run_interference`) and the campaign
    /// merge path (`campaign::interference_records`), so the two can
    /// never silently diverge.
    pub fn isolated_total(&self, label: &str, req: OffloadRequest) -> Option<Time> {
        self.records
            .iter()
            .find(|r| r.label() == label && r.req() == req)
            .map(|r| r.total())
    }

    /// Group records by an arbitrary key, preserving first-seen order
    /// (deterministic, since records are input-ordered).
    pub fn group_by<K, F>(&self, key: F) -> Vec<(K, Vec<&SweepRecord>)>
    where
        K: PartialEq,
        F: Fn(&SweepRecord) -> K,
    {
        let mut groups: Vec<(K, Vec<&SweepRecord>)> = Vec::new();
        for r in &self.records {
            let k = key(r);
            match groups.iter().position(|(g, _)| *g == k) {
                Some(i) => groups[i].1.push(r),
                None => groups.push((k, vec![r])),
            }
        }
        groups
    }

    /// Collapse into base/ideal/improved [`TriplePoint`]s: one per
    /// (label, spec, n_clusters) for which the sweep ran all three of
    /// Baseline, Ideal and Multicast, in first-seen order. Other routines
    /// (the ablation variants) are ignored here — look them up with
    /// [`SweepResults::total`].
    pub fn triples(&self) -> Vec<TriplePoint> {
        let mut partial: Vec<(TripleKey, [Option<Time>; 3])> = Vec::new();
        for r in &self.records {
            let slot = match r.req().routine {
                RoutineKind::Baseline => 0,
                RoutineKind::Ideal => 1,
                RoutineKind::Multicast => 2,
                _ => continue,
            };
            let key = (r.label(), r.req().spec, r.req().n_clusters);
            let i = match partial.iter().position(|(k, _)| *k == key) {
                Some(i) => i,
                None => {
                    partial.push((key, [None; 3]));
                    partial.len() - 1
                }
            };
            partial[i].1[slot] = Some(r.total());
        }
        partial
            .into_iter()
            .filter_map(|((label, spec, n_clusters), [b, i, m])| {
                let (base, ideal, improved) = (b?, i?, m?);
                Some(TriplePoint {
                    label,
                    spec,
                    n_clusters,
                    runtimes: RunTriple {
                        n_clusters,
                        base,
                        ideal,
                        improved,
                    },
                })
            })
            .collect()
    }

    /// The triple at (label, n_clusters); ambiguous when one label sweeps
    /// several specs at the same cluster count — the first wins.
    pub fn triple_of(&self, label: &str, n_clusters: usize) -> Option<RunTriple> {
        self.triples()
            .into_iter()
            .find(|t| t.label == label && t.n_clusters == n_clusters)
            .map(|t| t.runtimes)
    }

    /// Offload-overhead projection (§5.2: base − ideal), one entry per
    /// complete triple.
    pub fn overheads(&self) -> Vec<(&'static str, usize, i64)> {
        self.triples()
            .iter()
            .map(|t| (t.label, t.n_clusters, t.runtimes.overhead()))
            .collect()
    }

    /// Ideal-speedup projection (Fig. 8 white bars).
    pub fn ideal_speedups(&self) -> Vec<(&'static str, usize, f64)> {
        self.triples()
            .iter()
            .map(|t| (t.label, t.n_clusters, t.runtimes.ideal_speedup()))
            .collect()
    }

    /// Achieved-speedup projection (Fig. 8 fill levels / Fig. 10 curves).
    pub fn achieved_speedups(&self) -> Vec<(&'static str, usize, f64)> {
        self.triples()
            .iter()
            .map(|t| (t.label, t.n_clusters, t.runtimes.achieved_speedup()))
            .collect()
    }
}

/// Mean and population standard deviation; `None` when the input is
/// empty (never NaN — see Fig7::stats_at).
pub fn mean_std(vals: impl IntoIterator<Item = f64>) -> Option<(f64, f64)> {
    let vals: Vec<f64> = vals.into_iter().collect();
    if vals.is_empty() {
        return None;
    }
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / vals.len() as f64;
    Some((mean, var.sqrt()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::sweep::Sweep;

    fn small_results() -> SweepResults {
        Sweep::new()
            .kernel("axpy", JobSpec::Axpy { n: 128 })
            .clusters([1, 4])
            .triples()
            .run(&Config::default())
    }

    #[test]
    fn triples_collapse_in_order() {
        let r = small_results();
        assert_eq!(r.len(), 6); // 2 clusters x 3 routines
        let t = r.triples();
        assert_eq!(t.len(), 2);
        assert_eq!((t[0].label, t[0].n_clusters), ("axpy", 1));
        assert_eq!((t[1].label, t[1].n_clusters), ("axpy", 4));
        assert!(t[0].runtimes.overhead() > 0);
    }

    #[test]
    fn lookup_and_projections_agree() {
        let r = small_results();
        let base = r.total("axpy", 4, RoutineKind::Baseline).unwrap();
        let ideal = r.total("axpy", 4, RoutineKind::Ideal).unwrap();
        let triple = r.triple_of("axpy", 4).unwrap();
        assert_eq!(triple.base, base);
        assert_eq!(triple.ideal, ideal);
        let overheads = r.overheads();
        assert_eq!(overheads.len(), 2);
        assert_eq!(overheads[1], ("axpy", 4, base as i64 - ideal as i64));
        assert!(r.get("axpy", 2, RoutineKind::Baseline).is_none());
    }

    #[test]
    fn group_by_preserves_first_seen_order() {
        let r = small_results();
        let by_n = r.group_by(|rec| rec.req().n_clusters);
        assert_eq!(by_n.len(), 2);
        assert_eq!(by_n[0].0, 1);
        assert_eq!(by_n[0].1.len(), 3);
        assert_eq!(by_n[1].0, 4);
    }

    #[test]
    fn mean_std_guards_empty() {
        assert_eq!(mean_std(std::iter::empty::<f64>()), None);
        let (m, s) = mean_std([2.0, 4.0]).unwrap();
        assert!((m - 3.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std([5.0]).unwrap();
        assert_eq!((m1, s1), (5.0, 0.0));
    }
}
