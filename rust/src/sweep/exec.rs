//! Parallel, deterministic execution of a sweep's point list.
//!
//! Every DES run is independent, so the grid is drained by a scoped
//! worker pool (one std::thread per available core) pulling indices off a
//! shared atomic counter. Results land in per-index slots, so the output
//! order is the input (expansion) order regardless of scheduling — and
//! because the DES itself is deterministic, parallel execution is
//! bit-identical to serial execution (see tests/integration_sweep.rs).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::config::Config;
use crate::sim::{SimProfile, Trace};

use super::cache;
use super::results::{SweepPoint, SweepRecord};

pub(crate) fn execute(
    cfg: &Config,
    points: &[SweepPoint],
    parallel: bool,
    cached: bool,
    profile: SimProfile,
) -> Vec<SweepRecord> {
    // Serialize the config once per campaign, not once per point.
    let config_key = cached.then(|| cache::profiled_config_key(cfg, profile));
    let run_point = |p: &SweepPoint| -> Arc<Trace> {
        match &config_key {
            Some(key) => cache::run_cached_profiled(key, cfg, p.req, profile),
            None => Arc::new(p.req.run_with(cfg, profile)),
        }
    };
    let workers = if parallel {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(points.len())
    } else {
        1
    };
    if workers <= 1 {
        return points
            .iter()
            .map(|p| SweepRecord {
                point: *p,
                trace: run_point(p),
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<OnceLock<Arc<Trace>>> = points.iter().map(|_| OnceLock::new()).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                // ordering: Relaxed — the RMW atomicity alone hands each
                // worker a unique index; results are published through
                // the per-slot OnceLock, not through this counter.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let trace = run_point(&points[i]);
                slots[i]
                    .set(trace)
                    .expect("every index is claimed by exactly one worker");
            });
        }
    });
    points
        .iter()
        .zip(slots)
        .map(|(p, slot)| SweepRecord {
            point: *p,
            trace: slot
                .into_inner()
                .expect("a worker filled every claimed slot"),
        })
        .collect()
}
