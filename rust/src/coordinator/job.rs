//! Job types flowing through the coordinator.

use crate::kernels::JobSpec;
use crate::offload::RoutineKind;
use crate::sim::Time;

/// A job submitted by a client of the coordinator.
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Caller-chosen id, also used to address the JCU slot (§4.3).
    pub id: u64,
    pub spec: JobSpec,
    /// Seed for deterministic input generation.
    pub seed: u64,
    /// Cluster count; `None` lets the planner pick the model-optimal one
    /// (the paper's "offload decision as an optimization problem", §5.6).
    pub n_clusters: Option<usize>,
    /// Offload routine; `None` = the optimized multicast routines.
    pub routine: Option<RoutineKind>,
}

impl JobRequest {
    pub fn new(id: u64, spec: JobSpec) -> Self {
        Self {
            id,
            spec,
            seed: id ^ 0x9E37_79B9,
            n_clusters: None,
            routine: None,
        }
    }

    pub fn with_clusters(mut self, n: usize) -> Self {
        self.n_clusters = Some(n);
        self
    }

    pub fn with_routine(mut self, r: RoutineKind) -> Self {
        self.routine = Some(r);
        self
    }
}

/// Where the planner decided to run a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Offloaded to `n_clusters` accelerator clusters.
    Accelerator { n_clusters: usize },
    /// Kept on the host (offload would not pay off).
    Host,
}

/// Result of a completed job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub spec: JobSpec,
    pub placement: Placement,
    pub routine: RoutineKind,
    /// Isolated service time: simulated cycles of the offloaded
    /// execution (DES), independent of contention.
    pub cycles: Time,
    /// Queueing delay under contention: virtual cycles spent waiting for
    /// free clusters and a free JCU slot. 0 with `inflight = 1` (serial
    /// dispatch) and for host placements; end-to-end latency is
    /// `cycles + queue_delay`.
    pub queue_delay: Time,
    /// Virtual dispatch time on the coordinator's shared timeline
    /// (accelerator placements only; 0 for host placements).
    pub start: Time,
    /// Virtual completion time (`start + cycles`; 0 for host placements).
    pub completion: Time,
    /// DES events dispatched to produce the isolated trace
    /// (`EventQueue::dispatched()`); 0 for host placements and rejected
    /// jobs, which never touch the simulator.
    pub events: u64,
    /// Model estimate the planner used (cycles).
    pub estimated_cycles: Time,
    /// Whether the PJRT outputs matched the native reference.
    pub verified: bool,
    /// Wall-clock microseconds spent on the PJRT execution.
    pub pjrt_micros: u128,
    /// Set when the request was rejected (e.g. a cluster count outside
    /// the SoC geometry): no simulation ran, all timing fields are 0.
    pub error: Option<String>,
}

impl JobResult {
    /// End-to-end latency under contention: isolated service time plus
    /// the nonnegative queueing delay.
    pub fn latency(&self) -> Time {
        self.cycles + self.queue_delay
    }

    pub fn is_rejected(&self) -> bool {
        self.error.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let r = JobRequest::new(7, JobSpec::Axpy { n: 64 })
            .with_clusters(8)
            .with_routine(RoutineKind::Baseline);
        assert_eq!(r.n_clusters, Some(8));
        assert_eq!(r.routine, Some(RoutineKind::Baseline));
        assert_eq!(r.id, 7);
    }
}
