//! The coordinator service: the host-centric execution loop tying all
//! three layers together.
//!
//! For every job it (1) plans the offload with the analytical model
//! (§5.6), (2) executes the offload on the cycle-level DES to obtain its
//! cost in cycles, (3) runs the job's numerics through the PJRT runtime
//! and verifies them against the native reference, and (4) tracks
//! completion through the JCU slots (§4.3) exactly as CVA6 would.
//!
//! Submission happens through a bounded queue (backpressure); a dispatch
//! thread drains it. The PJRT client is not Sync-shareable across
//! threads, so the dispatch thread owns the runtime — matching the
//! hardware, where a single CVA6 core issues every offload.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::Config;
use crate::interrupt::{ArrivalOutcome, Jcu};
use crate::offload::RoutineKind;
use crate::runtime::{jobs, PjrtRuntime};
use crate::sweep::OffloadRequest;

use super::decision::Planner;
use super::job::{JobRequest, JobResult, Placement};
use super::metrics::Metrics;
use super::queue::JobQueue;

/// Number of JCU slots (outstanding jobs) the coordinator programs.
pub const JCU_SLOTS: usize = 4;

/// Coordinator configuration.
pub struct CoordinatorConfig {
    pub cfg: Config,
    /// Queue capacity before submitters block.
    pub queue_depth: usize,
    /// Skip PJRT numerics (timing-only runs, e.g. benches).
    pub timing_only: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            cfg: Config::default(),
            queue_depth: 16,
            timing_only: false,
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    queue: JobQueue<JobRequest>,
    results: mpsc::Receiver<JobResult>,
    worker: Option<JoinHandle<Metrics>>,
}

impl Coordinator {
    /// Start the dispatch loop. `artifacts` is required unless
    /// `timing_only` is set. The PJRT client is `!Send`, so the runtime
    /// is constructed *inside* the dispatch thread; construction errors
    /// are reported back through a readiness channel.
    pub fn start(ccfg: CoordinatorConfig, artifacts: Option<&Path>) -> Result<Self> {
        let queue: JobQueue<JobRequest> = JobQueue::new(ccfg.queue_depth);
        let (tx, rx) = mpsc::channel::<JobResult>();
        let artifacts: Option<PathBuf> = match (ccfg.timing_only, artifacts) {
            (true, _) => None,
            (false, Some(dir)) => Some(dir.to_path_buf()),
            (false, None) => anyhow::bail!("artifacts dir required unless timing_only"),
        };
        let timing_only = ccfg.timing_only;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let q2 = queue.clone();
        let worker = std::thread::spawn(move || {
            let rt = if timing_only {
                let _ = ready_tx.send(Ok(()));
                None
            } else {
                match PjrtRuntime::new(artifacts.as_deref().expect("checked above")) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        Some(rt)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return Metrics::default();
                    }
                }
            };
            dispatch_loop(ccfg, rt, q2, tx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("dispatch thread died during startup"))??;
        Ok(Self {
            queue,
            results: rx,
            worker: Some(worker),
        })
    }

    /// A cloneable, thread-safe submission handle (the result receiver
    /// stays with the `Coordinator`).
    pub fn submitter(&self) -> Submitter {
        Submitter {
            queue: self.queue.clone(),
        }
    }

    /// Submit a job (blocks on backpressure).
    pub fn submit(&self, req: JobRequest) -> Result<()> {
        self.queue
            .push(req)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))
    }

    /// Receive the next completed result (blocks).
    pub fn recv(&self) -> Option<JobResult> {
        self.results.recv().ok()
    }

    /// Close the queue, wait for the dispatch loop, return final metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.queue.close();
        self.worker
            .take()
            .expect("shutdown called once")
            .join()
            .expect("dispatch loop panicked")
    }
}

/// Cloneable submission handle usable from other threads.
#[derive(Clone)]
pub struct Submitter {
    queue: JobQueue<JobRequest>,
}

impl Submitter {
    /// Submit a job (blocks on backpressure).
    pub fn submit(&self, req: JobRequest) -> Result<()> {
        self.queue
            .push(req)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))
    }
}

fn dispatch_loop(
    ccfg: CoordinatorConfig,
    rt: Option<PjrtRuntime>,
    queue: JobQueue<JobRequest>,
    tx: mpsc::Sender<JobResult>,
) -> Metrics {
    let cfg = ccfg.cfg;
    let planner = Planner::new(&cfg);
    let mut jcu = Jcu::new(JCU_SLOTS);
    let mut metrics = Metrics::default();
    // The DES is deterministic, so identical (spec, clusters, routine)
    // configurations always cost the same cycles: memoize totals (perf,
    // see EXPERIMENTS.md §Perf — repeated-job dispatch drops ~20x). The
    // memo holds 8-byte totals and dies with the loop; full traces the
    // experiment harness already computed are reused via a non-inserting
    // peek of the sweep cache, so a long-lived service never grows the
    // process-wide cache.
    let sim_cache_key = crate::sweep::cache::config_key(&cfg);
    let mut sim_totals: std::collections::HashMap<OffloadRequest, crate::sim::Time> =
        std::collections::HashMap::new();

    while let Some(req) = queue.pop() {
        let routine = req.routine.unwrap_or(RoutineKind::Multicast);

        // 1) Plan: model-optimal cluster count / host fallback.
        let (placement, estimate) = match req.n_clusters {
            Some(n) => (
                Placement::Accelerator { n_clusters: n },
                planner.plan_estimate(&req.spec, n),
            ),
            None => {
                let plan = planner.plan(&req.spec);
                (plan.placement, plan.estimate)
            }
        };

        // 2) Timing: DES of the offload (or the host estimate).
        let cycles = match placement {
            Placement::Accelerator { n_clusters } => {
                // Program the JCU slot like CVA6 would (§4.3).
                let job_id = (req.id % JCU_SLOTS as u64) as u32;
                jcu.program(job_id, n_clusters as u32);
                let sim_req = OffloadRequest::new(req.spec, n_clusters, routine);
                let total = *sim_totals.entry(sim_req).or_insert_with(|| {
                    match crate::sweep::cache::peek(&sim_cache_key, sim_req) {
                        Some(trace) => trace.total,
                        None => sim_req.run(&cfg).total,
                    }
                });
                // All clusters arrive; the last fires the interrupt.
                for _ in 0..n_clusters - 1 {
                    assert!(matches!(
                        jcu.arrive(job_id),
                        ArrivalOutcome::Pending { .. }
                    ));
                }
                match jcu.arrive(job_id) {
                    ArrivalOutcome::CompleteFired { cause } => {
                        debug_assert_eq!(cause, job_id);
                        jcu.host_clear();
                    }
                    other => panic!("unexpected JCU outcome {other:?}"),
                }
                total
            }
            Placement::Host => planner.host_estimate(&req.spec),
        };

        // 3) Numerics: PJRT execution + verification.
        let (verified, pjrt_micros) = match &rt {
            None => (true, 0u128),
            Some(rt) => {
                let t0 = std::time::Instant::now();
                let ok = jobs::run_and_verify(rt, &req.spec, req.seed).is_ok();
                (ok, t0.elapsed().as_micros())
            }
        };

        metrics.record_completion(
            req.spec.kind(),
            cycles,
            pjrt_micros,
            verified,
            placement == Placement::Host,
        );
        let _ = tx.send(JobResult {
            id: req.id,
            spec: req.spec,
            placement,
            routine,
            cycles,
            estimated_cycles: estimate,
            verified,
            pjrt_micros,
        });
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::JobSpec;

    #[test]
    fn timing_only_coordinator_round_trip() {
        let c = Coordinator::start(
            CoordinatorConfig {
                timing_only: true,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        for i in 0..8u64 {
            c.submit(JobRequest::new(i, JobSpec::Axpy { n: 1024 })).unwrap();
        }
        let mut got = 0;
        for _ in 0..8 {
            let r = c.recv().expect("result");
            assert!(r.cycles > 0);
            assert!(r.verified);
            got += 1;
        }
        let m = c.shutdown();
        assert_eq!(got, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.verification_failures, 0);
    }

    #[test]
    fn forced_clusters_and_routine_respected() {
        let c = Coordinator::start(
            CoordinatorConfig {
                timing_only: true,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        c.submit(
            JobRequest::new(0, JobSpec::Axpy { n: 1024 })
                .with_clusters(4)
                .with_routine(RoutineKind::Baseline),
        )
        .unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.placement, Placement::Accelerator { n_clusters: 4 });
        assert_eq!(r.routine, RoutineKind::Baseline);
        c.shutdown();
    }

    #[test]
    fn tiny_jobs_placed_on_host() {
        let c = Coordinator::start(
            CoordinatorConfig {
                timing_only: true,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        c.submit(JobRequest::new(0, JobSpec::Axpy { n: 16 })).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.placement, Placement::Host);
        let m = c.shutdown();
        assert_eq!(m.host_placements, 1);
    }
}
