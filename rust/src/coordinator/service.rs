//! The coordinator service: the host-centric execution loop tying all
//! three layers together.
//!
//! For every job it (1) plans the offload with the analytical model
//! (§5.6), (2) executes the offload on the cycle-level DES to obtain its
//! isolated cost in cycles, (3) runs the job's numerics through the PJRT
//! runtime and verifies them against the native reference, and (4)
//! schedules it on the shared virtual timeline of the
//! [`super::occupancy::OccupancyModel`], where up to
//! [`CoordinatorConfig::inflight`] jobs are outstanding and contend for
//! the JCU's slots (§4.3) and the fabric's clusters. Each result
//! therefore decomposes as isolated service time plus a nonnegative
//! queueing delay; with `inflight = 1` the schedule degenerates to the
//! serial coordinator (zero queueing, bit-identical cycles).
//!
//! Submission happens through a bounded queue (backpressure); a dispatch
//! thread drains it. The PJRT client is not Sync-shareable across
//! threads, so the dispatch thread owns the runtime — matching the
//! hardware, where a single CVA6 core issues every offload.
//!
//! Bad requests (a cluster count outside the SoC geometry) surface as a
//! per-job error [`JobResult`] instead of panicking the dispatch thread:
//! one malformed job must not poison the coordinator for every job
//! behind it.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::Config;
use crate::offload::RoutineKind;
use crate::runtime::{jobs, PjrtRuntime};
use crate::sweep::OffloadRequest;

use super::decision::Planner;
use super::job::{JobRequest, JobResult, Placement};
use super::metrics::Metrics;
use super::occupancy::{OccupancyModel, OccupancyParams};
use super::queue::JobQueue;

/// Number of JCU slots (outstanding jobs) the coordinator programs.
pub const JCU_SLOTS: usize = 4;

/// Coordinator configuration.
pub struct CoordinatorConfig {
    pub cfg: Config,
    /// Queue capacity before submitters block.
    pub queue_depth: usize,
    /// Skip PJRT numerics (timing-only runs, e.g. benches).
    pub timing_only: bool,
    /// Jobs kept outstanding on the virtual timeline (closed-loop
    /// window). 1 = serial dispatch, bit-identical to the pre-overlap
    /// coordinator; larger windows overlap offload phases and queue on
    /// JCU slots and clusters.
    pub inflight: usize,
    /// Minimum virtual cycles between consecutive job arrivals.
    pub arrival_gap: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            cfg: Config::default(),
            queue_depth: 16,
            timing_only: false,
            inflight: 1,
            arrival_gap: 0,
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    queue: JobQueue<JobRequest>,
    results: mpsc::Receiver<JobResult>,
    worker: Option<JoinHandle<Metrics>>,
}

/// Reject obviously malformed requests before they enter the queue: a
/// forced cluster count of zero can never dispatch (the JCU's offload
/// register is >= 1), and used to underflow inside the dispatch thread,
/// poisoning the whole coordinator.
fn validate_submit(req: &JobRequest) -> Result<()> {
    if req.n_clusters == Some(0) {
        anyhow::bail!("job {}: n_clusters must be >= 1 (got 0)", req.id);
    }
    Ok(())
}

impl Coordinator {
    /// Start the dispatch loop. `artifacts` is required unless
    /// `timing_only` is set. The PJRT client is `!Send`, so the runtime
    /// is constructed *inside* the dispatch thread; construction errors
    /// are reported back through a readiness channel.
    pub fn start(ccfg: CoordinatorConfig, artifacts: Option<&Path>) -> Result<Self> {
        anyhow::ensure!(ccfg.inflight >= 1, "inflight window must be >= 1");
        let queue: JobQueue<JobRequest> = JobQueue::new(ccfg.queue_depth);
        let (tx, rx) = mpsc::channel::<JobResult>();
        let artifacts: Option<PathBuf> = match (ccfg.timing_only, artifacts) {
            (true, _) => None,
            (false, Some(dir)) => Some(dir.to_path_buf()),
            (false, None) => anyhow::bail!("artifacts dir required unless timing_only"),
        };
        let timing_only = ccfg.timing_only;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let q2 = queue.clone();
        let worker = std::thread::spawn(move || {
            let rt = if timing_only {
                let _ = ready_tx.send(Ok(()));
                None
            } else {
                match PjrtRuntime::new(artifacts.as_deref().expect("checked above")) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        Some(rt)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return Metrics::default();
                    }
                }
            };
            dispatch_loop(ccfg, rt, q2, tx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("dispatch thread died during startup"))??;
        Ok(Self {
            queue,
            results: rx,
            worker: Some(worker),
        })
    }

    /// A cloneable, thread-safe submission handle (the result receiver
    /// stays with the `Coordinator`).
    pub fn submitter(&self) -> Submitter {
        Submitter {
            queue: self.queue.clone(),
        }
    }

    /// Submit a job (blocks on backpressure). Rejects `n_clusters == 0`
    /// up front; geometry violations are checked in the dispatch loop
    /// (they need the config) and surface as an error [`JobResult`].
    pub fn submit(&self, req: JobRequest) -> Result<()> {
        validate_submit(&req)?;
        self.queue
            .push(req)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))
    }

    /// Receive the next completed result (blocks).
    pub fn recv(&self) -> Option<JobResult> {
        self.results.recv().ok()
    }

    /// Close the queue, wait for the dispatch loop, return final metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.queue.close();
        self.worker
            .take()
            .expect("shutdown called once")
            .join()
            .expect("dispatch loop panicked")
    }
}

/// Cloneable submission handle usable from other threads.
#[derive(Clone)]
pub struct Submitter {
    queue: JobQueue<JobRequest>,
}

impl Submitter {
    /// Submit a job (blocks on backpressure).
    pub fn submit(&self, req: JobRequest) -> Result<()> {
        validate_submit(&req)?;
        self.queue
            .push(req)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))
    }
}

fn dispatch_loop(
    ccfg: CoordinatorConfig,
    rt: Option<PjrtRuntime>,
    queue: JobQueue<JobRequest>,
    tx: mpsc::Sender<JobResult>,
) -> Metrics {
    let cfg = ccfg.cfg;
    let planner = Planner::new(&cfg);
    let capacity = cfg.soc.n_clusters();
    let mut engine = OccupancyModel::new(OccupancyParams {
        capacity,
        jcu_slots: JCU_SLOTS,
        inflight: ccfg.inflight,
        arrival_gap: ccfg.arrival_gap,
    });
    let mut metrics = Metrics::default();
    // The DES is deterministic, so identical (spec, clusters, routine)
    // configurations always cost the same cycles: memoize totals (perf,
    // see EXPERIMENTS.md §Perf — repeated-job dispatch drops ~20x). The
    // memo holds 8-byte totals and dies with the loop; full traces the
    // experiment harness already computed are reused via a non-inserting
    // peek of the sweep cache, so a long-lived service never grows the
    // process-wide cache.
    let sim_cache_key = crate::sweep::cache::config_key(&cfg);
    let mut sim_totals: std::collections::BTreeMap<OffloadRequest, (crate::sim::Time, u64)> =
        std::collections::BTreeMap::new();

    while let Some(req) = queue.pop() {
        let routine = req.routine.unwrap_or(RoutineKind::Multicast);

        // 0) Validate: a bad job yields an error result, not a dead loop.
        if let Some(n) = req.n_clusters {
            if n == 0 || n > capacity {
                metrics.record_rejection();
                let _ = tx.send(JobResult {
                    id: req.id,
                    spec: req.spec,
                    placement: Placement::Host,
                    routine,
                    cycles: 0,
                    queue_delay: 0,
                    start: 0,
                    completion: 0,
                    events: 0,
                    estimated_cycles: 0,
                    verified: false,
                    pjrt_micros: 0,
                    error: Some(format!(
                        "n_clusters must be in 1..={capacity}, got {n}"
                    )),
                });
                continue;
            }
        }

        // 1) Plan: model-optimal cluster count / host fallback.
        let (placement, estimate) = match req.n_clusters {
            Some(n) => (
                Placement::Accelerator { n_clusters: n },
                planner.plan_estimate(&req.spec, n),
            ),
            None => {
                let plan = planner.plan(&req.spec);
                (plan.placement, plan.estimate)
            }
        };

        // 2) Timing: DES of the offload (or the host estimate), then the
        // shared-timeline schedule. Jobs the planner keeps on the host
        // run on CVA6 itself and do not contend for slots or clusters.
        let (cycles, queue_delay, start, completion, events) = match placement {
            Placement::Accelerator { n_clusters } => {
                let sim_req = OffloadRequest::new(req.spec, n_clusters, routine);
                let (service, events) = *sim_totals.entry(sim_req).or_insert_with(|| {
                    match crate::sweep::cache::peek(&sim_cache_key, sim_req) {
                        Some(trace) => (trace.total, trace.events),
                        None => {
                            let t = sim_req.run(&cfg);
                            (t.total, t.events)
                        }
                    }
                });
                // Program a free JCU slot, occupy clusters, retire
                // earlier completions through the deferred-interrupt
                // chain (§4.3) — all on the virtual timeline.
                let adm = engine.admit(n_clusters, service);
                (service, adm.queue_delay, adm.start, adm.completion, events)
            }
            Placement::Host => (planner.host_estimate(&req.spec), 0, 0, 0, 0),
        };

        // 3) Numerics: PJRT execution + verification.
        let (verified, pjrt_micros) = match &rt {
            None => (true, 0u128),
            Some(rt) => {
                let t0 = std::time::Instant::now();
                let ok = jobs::run_and_verify(rt, &req.spec, req.seed).is_ok();
                (ok, t0.elapsed().as_micros())
            }
        };

        metrics.record_completion(
            req.spec.kind(),
            cycles,
            queue_delay,
            events,
            pjrt_micros,
            verified,
            placement == Placement::Host,
        );
        let _ = tx.send(JobResult {
            id: req.id,
            spec: req.spec,
            placement,
            routine,
            cycles,
            queue_delay,
            start,
            completion,
            events,
            estimated_cycles: estimate,
            verified,
            pjrt_micros,
            error: None,
        });
    }
    // Retire everything still in flight: every admitted job's interrupt
    // is delivered before the loop reports its final metrics.
    engine.finish();
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::JobSpec;

    fn timing_config(inflight: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            timing_only: true,
            inflight,
            ..Default::default()
        }
    }

    #[test]
    fn timing_only_coordinator_round_trip() {
        let c = Coordinator::start(timing_config(1), None).unwrap();
        for i in 0..8u64 {
            c.submit(JobRequest::new(i, JobSpec::Axpy { n: 1024 })).unwrap();
        }
        let mut got = 0;
        for _ in 0..8 {
            let r = c.recv().expect("result");
            assert!(r.cycles > 0);
            assert!(r.verified);
            assert_eq!(r.queue_delay, 0, "serial dispatch never queues");
            got += 1;
        }
        let m = c.shutdown();
        assert_eq!(got, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.verification_failures, 0);
        assert_eq!(m.queueing.sum(), 0);
    }

    #[test]
    fn forced_clusters_and_routine_respected() {
        let c = Coordinator::start(timing_config(1), None).unwrap();
        c.submit(
            JobRequest::new(0, JobSpec::Axpy { n: 1024 })
                .with_clusters(4)
                .with_routine(RoutineKind::Baseline),
        )
        .unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.placement, Placement::Accelerator { n_clusters: 4 });
        assert_eq!(r.routine, RoutineKind::Baseline);
        assert!(r.events > 0, "accelerator jobs carry the DES event count");
        c.shutdown();
    }

    #[test]
    fn tiny_jobs_placed_on_host() {
        let c = Coordinator::start(timing_config(1), None).unwrap();
        c.submit(JobRequest::new(0, JobSpec::Axpy { n: 16 })).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.placement, Placement::Host);
        assert_eq!(r.events, 0, "host jobs never touch the simulator");
        let m = c.shutdown();
        assert_eq!(m.host_placements, 1);
        assert_eq!(m.sim_events.sum(), 0);
    }

    #[test]
    fn zero_cluster_submit_is_rejected_up_front() {
        // Regression: `with_clusters(0)` used to underflow inside the
        // dispatch thread, poisoning the coordinator and hanging
        // shutdown.
        let c = Coordinator::start(timing_config(1), None).unwrap();
        let err = c
            .submit(JobRequest::new(0, JobSpec::Axpy { n: 1024 }).with_clusters(0))
            .unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        let err = c
            .submitter()
            .submit(JobRequest::new(1, JobSpec::Axpy { n: 1024 }).with_clusters(0))
            .unwrap_err();
        assert!(err.to_string().contains(">= 1"), "{err}");
        // The coordinator is still alive and serves good jobs.
        c.submit(JobRequest::new(2, JobSpec::Axpy { n: 1024 })).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.id, 2);
        assert!(r.error.is_none());
        let m = c.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn geometry_violations_yield_error_results_not_a_dead_loop() {
        let c = Coordinator::start(timing_config(1), None).unwrap();
        let capacity = Config::default().soc.n_clusters();
        c.submit(JobRequest::new(0, JobSpec::Axpy { n: 1024 }).with_clusters(capacity + 1))
            .unwrap();
        c.submit(JobRequest::new(1, JobSpec::Axpy { n: 1024 }).with_clusters(8))
            .unwrap();
        let bad = c.recv().unwrap();
        assert_eq!(bad.id, 0);
        assert!(bad.is_rejected());
        assert!(bad.error.as_deref().unwrap().contains("n_clusters"));
        assert_eq!(bad.cycles, 0);
        let good = c.recv().unwrap();
        assert_eq!(good.id, 1);
        assert!(good.error.is_none());
        assert!(good.cycles > 0);
        let m = c.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn overlapped_dispatch_decomposes_latency() {
        // Four 16-cluster jobs on the 32-cluster fabric: two overlap,
        // two queue. Service times stay the isolated DES cycles.
        let c = Coordinator::start(timing_config(4), None).unwrap();
        let spec = JobSpec::Axpy { n: 1024 };
        for i in 0..4u64 {
            c.submit(JobRequest::new(i, spec).with_clusters(16)).unwrap();
        }
        let mut results: Vec<JobResult> = (0..4).map(|_| c.recv().unwrap()).collect();
        results.sort_by_key(|r| r.id);
        let isolated = results[0].cycles;
        for r in &results {
            assert_eq!(r.cycles, isolated, "service time is contention-free");
            assert_eq!(r.latency(), r.cycles + r.queue_delay);
            assert_eq!(r.completion, r.start + r.cycles);
        }
        assert_eq!(results[0].queue_delay, 0);
        assert_eq!(results[1].queue_delay, 0);
        assert!(results[2].queue_delay > 0, "third 16-wide job must wait");
        assert!(results[3].queue_delay > 0);
        let m = c.shutdown();
        assert_eq!(m.completed, 4);
        assert_eq!(m.latency.sum(), m.service.sum() + m.queueing.sum());
    }
}
