//! The shared-fabric occupancy model behind overlapped dispatch.
//!
//! The DES simulates one offload in isolation; the JCU (§4.3) exists so
//! CVA6 can keep *several* offloads outstanding. This model composes the
//! two: jobs are admitted in submission order into a virtual timeline
//! where up to `inflight` jobs are outstanding, each occupying a JCU
//! slot and `n_clusters` of the fabric's clusters for its isolated DES
//! runtime. What a job cannot get immediately it waits for, and that
//! wait — for free clusters plus for a free JCU slot — is its *queueing
//! delay*, reported separately from the isolated service time so
//! contention is observable (`latency = service + queueing`).
//!
//! Completion bookkeeping runs through the real [`Jcu`]: slots are
//! programmed at dispatch (lowest free slot, never clobbering a busy
//! one), every cluster's arrival is written at completion, and
//! simultaneous completions are delivered through the deferred-interrupt
//! chain ([`Jcu::host_clear`]) in completion order.
//!
//! The model is single-threaded and purely deterministic: a job's whole
//! schedule (arrival, start, completion) is fixed at admission by the
//! admission sequence alone, so identical submission orders always
//! produce identical schedules regardless of wall-clock timing. With
//! `inflight = 1` every job arrives exactly when its predecessor
//! completes — the serial coordinator — and every queueing delay is 0.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::interrupt::{ArrivalOutcome, Jcu, JobId};
use crate::sim::Time;

/// Parameters of the occupancy model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OccupancyParams {
    /// Total clusters in the fabric (`cfg.soc.n_clusters()`).
    pub capacity: usize,
    /// JCU slots — the hardware bound on concurrently dispatched jobs.
    pub jcu_slots: usize,
    /// Closed-loop window: how many jobs the clients keep outstanding.
    /// May exceed `jcu_slots`, in which case admitted jobs queue for a
    /// slot and that wait shows up as queueing delay.
    pub inflight: usize,
    /// Minimum virtual cycles between consecutive arrivals (0 = jobs
    /// arrive back-to-back as the window allows).
    pub arrival_gap: Time,
}

/// The virtual-time schedule of one admitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    /// Admission sequence number (submission order).
    pub seq: u64,
    /// When the job entered the dispatch window.
    pub arrival: Time,
    /// When its JCU slot was programmed and its clusters granted.
    pub start: Time,
    /// `start + service` — when the last cluster writes its arrival.
    pub completion: Time,
    /// The JCU slot the job was tracked by.
    pub slot: JobId,
    /// `start - arrival`: wait for clusters + wait for a JCU slot.
    pub queue_delay: Time,
}

/// One job currently holding a slot and clusters.
#[derive(Debug, Clone, Copy)]
struct Flight {
    seq: u64,
    slot: JobId,
    n_clusters: usize,
    completion: Time,
}

/// Deterministic virtual-time occupancy model over a [`Jcu`].
#[derive(Debug)]
pub struct OccupancyModel {
    params: OccupancyParams,
    jcu: Jcu,
    flights: Vec<Flight>,
    busy_clusters: usize,
    /// The `inflight` *largest* completion times admitted so far, as a
    /// min-heap. A closed-loop client pool of size `inflight` frees its
    /// next slot at the smallest of these (with k jobs admitted, the
    /// (k − inflight + 1)-th completion — the moment outstanding drops
    /// below `inflight`), which is all the window floor ever reads; the
    /// engine stays O(inflight) in memory over an unbounded job stream.
    window: BinaryHeap<Reverse<Time>>,
    /// Jobs admitted so far (the next admission's `seq`).
    admitted: u64,
    last_arrival: Time,
    last_start: Time,
    /// Interrupts delivered to the host so far (fired + deferred chain).
    delivered: u64,
}

impl OccupancyModel {
    pub fn new(params: OccupancyParams) -> Self {
        assert!(params.capacity >= 1, "fabric needs at least one cluster");
        assert!(params.jcu_slots >= 1, "JCU needs at least one slot");
        assert!(params.inflight >= 1, "inflight window must be >= 1");
        Self {
            params,
            jcu: Jcu::new(params.jcu_slots),
            flights: Vec::new(),
            busy_clusters: 0,
            window: BinaryHeap::with_capacity(params.inflight + 1),
            admitted: 0,
            last_arrival: 0,
            last_start: 0,
            delivered: 0,
        }
    }

    pub fn params(&self) -> OccupancyParams {
        self.params
    }

    /// Jobs currently holding a slot (not yet retired).
    pub fn in_flight(&self) -> usize {
        self.flights.len()
    }

    /// Interrupts delivered to the host so far.
    pub fn interrupts_delivered(&self) -> u64 {
        self.delivered
    }

    /// Admit the next job in submission order: `n_clusters` of the
    /// fabric for `service` cycles (its isolated DES runtime). Returns
    /// the job's complete virtual-time schedule.
    pub fn admit(&mut self, n_clusters: usize, service: Time) -> Admission {
        self.admit_at(0, n_clusters, service)
    }

    /// [`admit`](Self::admit) with an externally-driven arrival floor:
    /// the job arrives no earlier than `arrival_floor` on the virtual
    /// timeline. This is how an *open-loop* client (the serve daemon's
    /// load generator) drives the model — arrivals come from a traffic
    /// process instead of the closed-loop window, while the window floor
    /// and arrival-gap spacing still apply as lower bounds. `admit` is
    /// the `arrival_floor = 0` special case.
    pub fn admit_at(&mut self, arrival_floor: Time, n_clusters: usize, service: Time) -> Admission {
        assert!(n_clusters >= 1, "a job occupies at least one cluster");
        assert!(
            n_clusters <= self.params.capacity,
            "job wants {n_clusters} clusters, fabric has {}",
            self.params.capacity
        );
        let seq = self.admitted;
        self.admitted += 1;

        // Arrival: the latest of the caller's floor, the arrival-gap
        // spacing, and the window floor — the earliest time a client
        // slot frees, i.e. the smallest of the `inflight` largest
        // completions so far (a closed-loop client pool submits the next
        // job the moment *any* of its outstanding jobs completes, not a
        // fixed round-robin member's).
        let mut arrival = if seq == 0 {
            arrival_floor
        } else {
            arrival_floor.max(self.last_arrival + self.params.arrival_gap)
        };
        if self.window.len() == self.params.inflight {
            arrival = arrival.max(self.window.peek().expect("window is non-empty").0);
        }
        self.last_arrival = arrival;

        // Start: FIFO (no overtaking), then wait until both a JCU slot
        // and enough clusters are free, retiring completions as virtual
        // time advances.
        let mut t = arrival.max(self.last_start);
        loop {
            self.retire_up_to(t);
            if self.flights.len() < self.params.jcu_slots
                && self.busy_clusters + n_clusters <= self.params.capacity
            {
                break;
            }
            t = self
                .flights
                .iter()
                .map(|f| f.completion)
                .min()
                .expect("blocked admission implies jobs in flight");
        }
        let start = t;
        self.last_start = start;

        // Dispatch: lowest free JCU slot (held jobs wait above instead
        // of clobbering a busy slot — `Jcu::program` enforces it).
        let slot = (0..self.params.jcu_slots as u32)
            .find(|&s| !self.jcu.slot_busy(s))
            .expect("a free slot was just checked for");
        self.jcu.program(slot, n_clusters as u32);
        self.busy_clusters += n_clusters;
        let completion = start + service;
        self.flights.push(Flight {
            seq,
            slot,
            n_clusters,
            completion,
        });
        self.window.push(Reverse(completion));
        if self.window.len() > self.params.inflight {
            // Drop the smallest: only the `inflight` largest completions
            // can ever be a future window floor.
            self.window.pop();
        }

        Admission {
            seq,
            arrival,
            start,
            completion,
            slot,
            queue_delay: start - arrival,
        }
    }

    /// Retire every in-flight job whose completion is at or before `t`:
    /// write its clusters' arrivals to the JCU, then play the host's
    /// interrupt handling — the first completion fires immediately, the
    /// rest ride the deferred chain and are delivered by `host_clear` in
    /// completion order.
    fn retire_up_to(&mut self, t: Time) {
        let mut due = Vec::new();
        let mut i = 0;
        while i < self.flights.len() {
            if self.flights[i].completion <= t {
                due.push(self.flights.swap_remove(i));
            } else {
                i += 1;
            }
        }
        if due.is_empty() {
            return;
        }
        due.sort_unstable_by_key(|f| (f.completion, f.seq));

        debug_assert!(!self.jcu.irq_pending(), "previous batch fully drained");
        let mut expected: VecDeque<JobId> = VecDeque::new();
        for (k, f) in due.iter().enumerate() {
            for _ in 0..f.n_clusters - 1 {
                let outcome = self.jcu.arrive(f.slot);
                debug_assert!(matches!(outcome, ArrivalOutcome::Pending { .. }));
            }
            match self.jcu.arrive(f.slot) {
                ArrivalOutcome::CompleteFired { cause } if k == 0 => {
                    debug_assert_eq!(cause, f.slot);
                    expected.push_back(cause);
                }
                ArrivalOutcome::CompleteDeferred { cause } if k > 0 => {
                    debug_assert_eq!(cause, f.slot);
                    expected.push_back(cause);
                }
                other => panic!("unexpected JCU outcome {other:?}"),
            }
            self.busy_clusters -= f.n_clusters;
        }
        // Host side: handle the fired interrupt, then clear; each clear
        // hands over the next deferred cause in completion order.
        self.delivered += 1;
        let mut handled = expected.pop_front();
        while let Some(cause) = self.jcu.host_clear() {
            handled = expected.pop_front();
            debug_assert_eq!(handled, Some(cause), "delivery in completion order");
            self.delivered += 1;
        }
        debug_assert!(handled.is_some() || due.is_empty());
        debug_assert!(expected.is_empty());
        debug_assert!(!self.jcu.irq_pending());
    }

    /// Retire everything still in flight (shutdown). Afterwards the
    /// model is idle: no flights, no busy clusters, no pending IRQ.
    pub fn finish(&mut self) {
        self.retire_up_to(Time::MAX);
        debug_assert_eq!(self.flights.len(), 0);
        debug_assert_eq!(self.busy_clusters, 0);
        debug_assert!(!self.jcu.irq_pending());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(inflight: usize, gap: Time) -> OccupancyModel {
        OccupancyModel::new(OccupancyParams {
            capacity: 32,
            jcu_slots: 4,
            inflight,
            arrival_gap: gap,
        })
    }

    #[test]
    fn serial_window_has_zero_queue_delay() {
        let mut m = model(1, 0);
        let mut prev_completion = 0;
        for _ in 0..5 {
            let a = m.admit(16, 1000);
            assert_eq!(a.queue_delay, 0);
            assert_eq!(a.arrival, prev_completion);
            assert_eq!(a.start, a.arrival);
            assert_eq!(a.completion, a.start + 1000);
            prev_completion = a.completion;
        }
        m.finish();
        assert_eq!(m.interrupts_delivered(), 5);
    }

    #[test]
    fn two_wide_jobs_fit_four_contend() {
        // 16-cluster jobs on a 32-cluster fabric: two overlap freely, a
        // window of four queues on clusters.
        let mut m = model(2, 0);
        let a0 = m.admit(16, 1000);
        let a1 = m.admit(16, 1000);
        assert_eq!((a0.start, a1.start), (0, 0));
        assert_eq!(a1.queue_delay, 0);

        let mut m = model(4, 0);
        let admissions: Vec<Admission> = (0..4).map(|_| m.admit(16, 1000)).collect();
        assert_eq!(admissions[0].start, 0);
        assert_eq!(admissions[1].start, 0);
        // Jobs 2 and 3 arrive at 0 (window open) but wait for clusters.
        assert_eq!(admissions[2].arrival, 0);
        assert_eq!(admissions[2].start, 1000);
        assert_eq!(admissions[2].queue_delay, 1000);
        assert_eq!(admissions[3].queue_delay, 1000);
        m.finish();
    }

    #[test]
    fn window_beyond_jcu_slots_queues_on_slots() {
        // Narrow jobs (clusters never the bottleneck) with a window of 8
        // over 4 slots: the fifth job waits for a slot.
        let mut m = model(8, 0);
        let admissions: Vec<Admission> = (0..8).map(|_| m.admit(1, 100)).collect();
        for a in &admissions[..4] {
            assert_eq!(a.queue_delay, 0);
        }
        assert_eq!(admissions[4].arrival, 0);
        assert_eq!(admissions[4].start, 100, "waited for a JCU slot");
        assert_eq!(admissions[4].queue_delay, 100);
        m.finish();
        assert_eq!(m.interrupts_delivered(), 8);
    }

    #[test]
    fn window_slot_frees_at_the_earliest_completion() {
        // Closed-loop pool of 2 with one long and one short job
        // outstanding: the third job enters when the *short* one
        // completes — the pool's next free slot — not when a fixed
        // round-robin predecessor would have.
        let mut m = model(2, 0);
        let a = m.admit(1, 1_000_000);
        let b = m.admit(1, 10);
        assert_eq!((a.start, b.start), (0, 0));
        let c = m.admit(1, 10);
        assert_eq!(c.arrival, 10, "slot freed by the short job");
        assert_eq!(c.start, 10);
        assert_eq!(c.queue_delay, 0);
        let d = m.admit(1, 10);
        assert_eq!(d.arrival, 20, "then by the next-earliest completion");
        m.finish();
    }

    #[test]
    fn arrival_gap_spaces_the_open_window() {
        let mut m = model(4, 250);
        let a0 = m.admit(4, 1000);
        let a1 = m.admit(4, 1000);
        let a2 = m.admit(4, 1000);
        assert_eq!((a0.arrival, a1.arrival, a2.arrival), (0, 250, 500));
        assert_eq!(a2.queue_delay, 0, "no contention at this width");
        m.finish();
    }

    #[test]
    fn fifo_no_overtaking() {
        // A narrow job submitted behind a blocked wide job must not
        // start before it.
        let mut m = model(4, 0);
        m.admit(20, 1000); // holds 20 clusters until t=1000
        let wide = m.admit(20, 1000); // blocked on clusters until t=1000
        let narrow = m.admit(1, 10); // plenty of room, but FIFO
        assert_eq!(wide.start, 1000);
        assert!(narrow.start >= wide.start, "no overtaking");
        m.finish();
    }

    #[test]
    fn simultaneous_completions_deliver_in_completion_order() {
        let mut m = model(4, 0);
        let a = m.admit(8, 500);
        let b = m.admit(8, 500);
        let c = m.admit(8, 300);
        assert_eq!((a.slot, b.slot, c.slot), (0, 1, 2));
        // c completes first (t=300), then a and b tie at t=500; the tie
        // breaks by admission order through the deferred chain.
        m.finish();
        assert_eq!(m.interrupts_delivered(), 3);
    }

    #[test]
    fn slots_are_reused_after_retirement() {
        let mut m = model(1, 0);
        for _ in 0..10 {
            let a = m.admit(32, 100);
            assert_eq!(a.slot, 0, "serial dispatch always reuses slot 0");
        }
        m.finish();
    }

    #[test]
    fn admit_at_floors_the_arrival() {
        // Open-loop arrivals: each job carries its own arrival instant.
        let mut m = model(8, 0);
        let a = m.admit_at(100, 1, 50);
        assert_eq!((a.arrival, a.start, a.queue_delay), (100, 100, 0));
        // A later floor wins over gap/window; an earlier floor cannot
        // move the arrival clock backwards past the gap spacing.
        let b = m.admit_at(400, 1, 50);
        assert_eq!(b.arrival, 400);
        let mut gapped = model(8, 250);
        gapped.admit_at(0, 1, 10);
        let late = gapped.admit_at(100, 1, 10);
        assert_eq!(late.arrival, 250, "arrival-gap spacing still applies");
    }

    #[test]
    fn admit_at_zero_matches_admit() {
        let mut a = model(4, 0);
        let mut b = model(4, 0);
        for _ in 0..6 {
            assert_eq!(a.admit(16, 1000), b.admit_at(0, 16, 1000));
        }
        a.finish();
        b.finish();
        assert_eq!(a.interrupts_delivered(), b.interrupts_delivered());
    }

    #[test]
    fn admit_at_overload_queues_fifo() {
        // Arrivals faster than service on one slot's worth of clusters:
        // queueing delay grows linearly, classic open-loop saturation.
        let mut m = OccupancyModel::new(OccupancyParams {
            capacity: 32,
            jcu_slots: 1,
            inflight: 8,
            arrival_gap: 0,
        });
        let mut prev_start = 0;
        for i in 0..4u64 {
            let a = m.admit_at(i * 100, 32, 1000);
            assert_eq!(a.arrival, i * 100);
            assert!(a.start >= prev_start, "FIFO no overtaking");
            assert_eq!(a.queue_delay, a.start - a.arrival);
            prev_start = a.start;
        }
        m.finish();
    }

    #[test]
    #[should_panic(expected = "at least one cluster")]
    fn zero_cluster_admission_is_rejected() {
        model(1, 0).admit(0, 100);
    }

    #[test]
    #[should_panic(expected = "fabric has")]
    fn over_capacity_admission_is_rejected() {
        model(1, 0).admit(33, 100);
    }
}
