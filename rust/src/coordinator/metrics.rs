//! Coordinator metrics: completion counters, cycle totals and a simple
//! latency distribution (min/mean/p50/p99/max over recorded values).

use std::collections::HashMap;

use crate::kernels::KernelKind;

/// Aggregate over a stream of u64 samples.
#[derive(Debug, Clone, Default)]
pub struct Dist {
    samples: Vec<u64>,
}

impl Dist {
    pub fn record(&mut self, v: u64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> u64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum() as f64 / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// q in [0, 1]; nearest-rank on the sorted samples.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

/// Coordinator-level metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub completed: u64,
    pub verified: u64,
    pub verification_failures: u64,
    pub host_placements: u64,
    pub accel_placements: u64,
    /// Simulated offload cycles per kernel kind.
    pub cycles_by_kernel: HashMap<&'static str, Dist>,
    /// End-to-end simulated latency of every job.
    pub latency: Dist,
    /// PJRT wall-clock micros.
    pub pjrt_micros: Dist,
}

impl Metrics {
    pub fn record_completion(
        &mut self,
        kind: KernelKind,
        cycles: u64,
        pjrt_micros: u128,
        verified: bool,
        on_host: bool,
    ) {
        self.completed += 1;
        if verified {
            self.verified += 1;
        } else {
            self.verification_failures += 1;
        }
        if on_host {
            self.host_placements += 1;
        } else {
            self.accel_placements += 1;
        }
        self.cycles_by_kernel
            .entry(kind.name())
            .or_default()
            .record(cycles);
        self.latency.record(cycles);
        self.pjrt_micros.record(pjrt_micros as u64);
    }

    /// Aggregate throughput in jobs per simulated second (1 GHz clock).
    pub fn jobs_per_sim_second(&self) -> f64 {
        let total_cycles = self.latency.sum();
        if total_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 / (total_cycles as f64 / 1e9)
    }

    /// Human-readable summary table.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "jobs: {} completed, {} verified, {} failed, {} host / {} accel\n",
            self.completed,
            self.verified,
            self.verification_failures,
            self.host_placements,
            self.accel_placements
        ));
        out.push_str(&format!(
            "latency (cycles): min {} mean {:.0} p50 {} p99 {} max {}\n",
            self.latency.min(),
            self.latency.mean(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
            self.latency.max()
        ));
        out.push_str(&format!(
            "pjrt (us): mean {:.0} max {}\n",
            self.pjrt_micros.mean(),
            self.pjrt_micros.max()
        ));
        let mut kinds: Vec<_> = self.cycles_by_kernel.iter().collect();
        kinds.sort_by_key(|(k, _)| **k);
        for (k, d) in kinds {
            out.push_str(&format!(
                "  {:<12} n={:<4} mean {:.0} cycles\n",
                k,
                d.count(),
                d.mean()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_stats() {
        let mut d = Dist::default();
        for v in [10u64, 20, 30, 40, 50] {
            d.record(v);
        }
        assert_eq!(d.count(), 5);
        assert_eq!(d.min(), 10);
        assert_eq!(d.max(), 50);
        assert_eq!(d.quantile(0.5), 30);
        assert!((d.mean() - 30.0).abs() < 1e-12);
        assert_eq!(d.quantile(0.0), 10);
        assert_eq!(d.quantile(1.0), 50);
    }

    #[test]
    fn empty_dist_is_zeroes() {
        let d = Dist::default();
        assert_eq!(d.quantile(0.5), 0);
        assert_eq!(d.mean(), 0.0);
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = Metrics::default();
        m.record_completion(KernelKind::Axpy, 1000, 50, true, false);
        m.record_completion(KernelKind::Axpy, 2000, 60, true, false);
        m.record_completion(KernelKind::Bfs, 500, 70, false, true);
        assert_eq!(m.completed, 3);
        assert_eq!(m.verified, 2);
        assert_eq!(m.verification_failures, 1);
        assert_eq!(m.host_placements, 1);
        assert_eq!(m.cycles_by_kernel["axpy"].count(), 2);
        assert!(m.jobs_per_sim_second() > 0.0);
        let s = m.summary();
        assert!(s.contains("3 completed"));
        assert!(s.contains("axpy"));
    }
}
