//! Coordinator metrics: completion counters, cycle totals and a simple
//! latency distribution (min/mean/p50/p99/max over recorded values).

use std::collections::BTreeMap;

use crate::kernels::KernelKind;

/// Aggregate over a stream of u64 samples.
#[derive(Debug, Clone, Default)]
pub struct Dist {
    samples: Vec<u64>,
}

impl Dist {
    pub fn record(&mut self, v: u64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> u64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum() as f64 / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// Samples at or below `bound` — the cumulative counts behind
    /// Prometheus histogram buckets (`obs::metrics`).
    pub fn count_le(&self, bound: u64) -> usize {
        self.samples.iter().filter(|&&v| v <= bound).count()
    }

    /// q in [0, 1]; nearest-rank on the sorted samples.
    pub fn quantile(&self, q: f64) -> u64 {
        self.quantiles(&[q])[0]
    }

    /// Several quantiles from one sort — `summary` asks for p50 and p99
    /// of every distribution, and cloning + sorting the sample vec per
    /// quantile made that quadratic-ish in practice.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<u64> {
        if self.samples.is_empty() {
            return vec![0; qs.len()];
        }
        let mut s = self.samples.clone();
        s.sort_unstable();
        qs.iter()
            .map(|q| {
                let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
                s[idx.min(s.len() - 1)]
            })
            .collect()
    }
}

/// Coordinator-level metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub completed: u64,
    pub verified: u64,
    pub verification_failures: u64,
    pub host_placements: u64,
    pub accel_placements: u64,
    /// Requests rejected at validation (no simulation ran).
    pub rejected: u64,
    /// Simulated offload cycles per kernel kind (isolated service time).
    /// Ordered map: `summary` renders it, and keyed output must iterate
    /// in a deterministic order (see `occamy audit`'s unordered-iteration
    /// rule).
    pub cycles_by_kernel: BTreeMap<&'static str, Dist>,
    /// Isolated service time of every job (DES cycles, no contention).
    pub service: Dist,
    /// Queueing delay of every job (wait for clusters + JCU slot).
    pub queueing: Dist,
    /// End-to-end simulated latency of every job: service + queueing.
    pub latency: Dist,
    /// PJRT wall-clock micros.
    pub pjrt_micros: Dist,
    /// DES events dispatched per job to produce its isolated trace
    /// (`EventQueue::dispatched()`); 0 for host placements, which never
    /// touch the simulator.
    pub sim_events: Dist,
}

impl Metrics {
    pub fn record_completion(
        &mut self,
        kind: KernelKind,
        cycles: u64,
        queue_delay: u64,
        events: u64,
        pjrt_micros: u128,
        verified: bool,
        on_host: bool,
    ) {
        self.completed += 1;
        if verified {
            self.verified += 1;
        } else {
            self.verification_failures += 1;
        }
        if on_host {
            self.host_placements += 1;
        } else {
            self.accel_placements += 1;
        }
        self.cycles_by_kernel
            .entry(kind.name())
            .or_default()
            .record(cycles);
        self.service.record(cycles);
        self.queueing.record(queue_delay);
        self.latency.record(cycles + queue_delay);
        self.pjrt_micros.record(pjrt_micros as u64);
        self.sim_events.record(events);
    }

    /// A request rejected at validation (counted, not simulated).
    pub fn record_rejection(&mut self) {
        self.rejected += 1;
    }

    /// Aggregate throughput in jobs per simulated second (1 GHz clock).
    /// Completed jobs with zero total cycles (all-host tiny jobs) are
    /// infinitely fast by this measure, not idle — reporting 0.0 used to
    /// make a busy all-host coordinator look stalled.
    pub fn jobs_per_sim_second(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        let total_cycles = self.latency.sum();
        if total_cycles == 0 {
            return f64::INFINITY;
        }
        self.completed as f64 / (total_cycles as f64 / 1e9)
    }

    /// Human-readable summary table. Quantiles come from one sort per
    /// distribution ([`Dist::quantiles`]).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "jobs: {} completed, {} verified, {} failed, {} host / {} accel{}\n",
            self.completed,
            self.verified,
            self.verification_failures,
            self.host_placements,
            self.accel_placements,
            if self.rejected > 0 {
                format!(", {} rejected", self.rejected)
            } else {
                String::new()
            }
        ));
        let dist_line = |name: &str, d: &Dist| -> String {
            let q = d.quantiles(&[0.5, 0.99]);
            format!(
                "{name} (cycles): min {} mean {:.0} p50 {} p99 {} max {}\n",
                d.min(),
                d.mean(),
                q[0],
                q[1],
                d.max()
            )
        };
        out.push_str(&dist_line("latency", &self.latency));
        out.push_str(&dist_line("service", &self.service));
        out.push_str(&dist_line("queueing", &self.queueing));
        out.push_str(&format!(
            "pjrt (us): mean {:.0} max {}\n",
            self.pjrt_micros.mean(),
            self.pjrt_micros.max()
        ));
        out.push_str(&format!(
            "events: {} dispatched (mean {:.0}/job)\n",
            self.sim_events.sum(),
            self.sim_events.mean()
        ));
        for (k, d) in &self.cycles_by_kernel {
            out.push_str(&format!(
                "  {:<12} n={:<4} mean {:.0} cycles\n",
                k,
                d.count(),
                d.mean()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_stats() {
        let mut d = Dist::default();
        for v in [10u64, 20, 30, 40, 50] {
            d.record(v);
        }
        assert_eq!(d.count(), 5);
        assert_eq!(d.min(), 10);
        assert_eq!(d.max(), 50);
        assert_eq!(d.quantile(0.5), 30);
        assert!((d.mean() - 30.0).abs() < 1e-12);
        assert_eq!(d.quantile(0.0), 10);
        assert_eq!(d.quantile(1.0), 50);
    }

    #[test]
    fn count_le_is_cumulative() {
        let mut d = Dist::default();
        for v in [10u64, 20, 30] {
            d.record(v);
        }
        assert_eq!(d.count_le(9), 0);
        assert_eq!(d.count_le(10), 1);
        assert_eq!(d.count_le(25), 2);
        assert_eq!(d.count_le(u64::MAX), 3);
        assert_eq!(Dist::default().count_le(0), 0);
    }

    #[test]
    fn empty_dist_is_zeroes() {
        let d = Dist::default();
        assert_eq!(d.quantile(0.5), 0);
        assert_eq!(d.mean(), 0.0);
    }

    #[test]
    fn metrics_aggregate() {
        let mut m = Metrics::default();
        m.record_completion(KernelKind::Axpy, 1000, 0, 40, 50, true, false);
        m.record_completion(KernelKind::Axpy, 2000, 300, 80, 60, true, false);
        m.record_completion(KernelKind::Bfs, 500, 0, 0, 70, false, true);
        assert_eq!(m.completed, 3);
        assert_eq!(m.sim_events.sum(), 120);
        assert_eq!(m.verified, 2);
        assert_eq!(m.verification_failures, 1);
        assert_eq!(m.host_placements, 1);
        assert_eq!(m.cycles_by_kernel["axpy"].count(), 2);
        assert!(m.jobs_per_sim_second() > 0.0);
        let s = m.summary();
        assert!(s.contains("3 completed"));
        assert!(s.contains("axpy"));
    }

    #[test]
    fn latency_decomposes_into_service_plus_queueing() {
        let mut m = Metrics::default();
        m.record_completion(KernelKind::Axpy, 1000, 250, 10, 0, true, false);
        m.record_completion(KernelKind::Axpy, 2000, 0, 10, 0, true, false);
        assert_eq!(m.service.sum(), 3000);
        assert_eq!(m.queueing.sum(), 250);
        assert_eq!(m.latency.sum(), 3250);
        let s = m.summary();
        assert!(s.contains("service"), "{s}");
        assert!(s.contains("queueing"), "{s}");
    }

    #[test]
    fn zero_cycle_throughput_is_infinite_not_zero() {
        // Regression: all-host tiny jobs complete in 0 recorded cycles;
        // the coordinator used to report 0.0 jobs/sim-s, as if stalled.
        let mut m = Metrics::default();
        assert_eq!(m.jobs_per_sim_second(), 0.0, "no jobs yet: truly idle");
        m.record_completion(KernelKind::Axpy, 0, 0, 0, 10, true, true);
        m.record_completion(KernelKind::Axpy, 0, 0, 0, 10, true, true);
        assert_eq!(m.completed, 2);
        assert!(m.jobs_per_sim_second().is_infinite());
        m.record_completion(KernelKind::Axpy, 1000, 0, 10, 10, true, false);
        assert!((m.jobs_per_sim_second() - 3.0e6).abs() < 1.0);
    }

    #[test]
    fn quantiles_match_single_quantile_calls() {
        let mut d = Dist::default();
        for v in [5u64, 1, 9, 3, 7] {
            d.record(v);
        }
        let qs = d.quantiles(&[0.0, 0.5, 0.99, 1.0]);
        assert_eq!(
            qs,
            vec![
                d.quantile(0.0),
                d.quantile(0.5),
                d.quantile(0.99),
                d.quantile(1.0)
            ]
        );
        assert_eq!(Dist::default().quantiles(&[0.5, 0.9]), vec![0, 0]);
    }

    #[test]
    fn summary_bytes_are_insertion_order_independent() {
        // Regression for the audit's unordered-iteration rule: the
        // per-kernel table must render identically no matter which
        // kernel completed first.
        let mut forward = Metrics::default();
        forward.record_completion(KernelKind::Axpy, 1000, 0, 10, 0, true, false);
        forward.record_completion(KernelKind::Bfs, 2000, 0, 10, 0, true, false);
        forward.record_completion(KernelKind::Matmul, 3000, 0, 10, 0, true, false);
        let mut reverse = Metrics::default();
        reverse.record_completion(KernelKind::Matmul, 3000, 0, 10, 0, true, false);
        reverse.record_completion(KernelKind::Bfs, 2000, 0, 10, 0, true, false);
        reverse.record_completion(KernelKind::Axpy, 1000, 0, 10, 0, true, false);
        assert_eq!(forward.summary(), reverse.summary());
        // And twice from the same state is byte-identical.
        assert_eq!(forward.summary(), forward.summary());
    }

    #[test]
    fn rejections_are_counted_and_reported() {
        let mut m = Metrics::default();
        m.record_rejection();
        m.record_rejection();
        assert_eq!(m.rejected, 2);
        assert_eq!(m.completed, 0);
        assert!(m.summary().contains("2 rejected"));
    }
}
