//! The offload decision (§1, §5.6).
//!
//! The paper motivates its runtime model by the non-intuitive offload
//! decision: *whether* to offload a job and *how many* clusters to use.
//! The planner answers both with the analytical model: it evaluates the
//! Eq.-4 estimate across candidate cluster counts, picks the argmin, and
//! offloads only when the estimated offloaded runtime beats the host
//! estimate.

use crate::config::Config;
use crate::kernels::JobSpec;
use crate::model::OffloadModel;

use super::job::Placement;

/// Estimated CVA6 cycles per useful flop for a scalar in-order core with
/// a non-pipelined double-precision FPU path: load + FMA + store per
/// element class of workloads.
pub const HOST_CYCLES_PER_FLOP: f64 = 3.0;

/// The planner's choice plus the estimates it was based on.
#[derive(Debug, Clone)]
pub struct Plan {
    pub placement: Placement,
    /// Estimated cycles of the chosen placement.
    pub estimate: u64,
    /// Estimated host runtime.
    pub host_estimate: u64,
    /// (n_clusters, estimate) for every candidate evaluated.
    pub candidates: Vec<(usize, u64)>,
}

/// Model-driven offload planner.
pub struct Planner<'a> {
    cfg: &'a Config,
    model: OffloadModel<'a>,
}

impl<'a> Planner<'a> {
    pub fn new(cfg: &'a Config) -> Self {
        Self {
            cfg,
            model: OffloadModel::new(cfg),
        }
    }

    /// Estimate the host (CVA6-only) runtime of a job.
    pub fn host_estimate(&self, spec: &JobSpec) -> u64 {
        (spec.flops() as f64 * HOST_CYCLES_PER_FLOP) as u64
    }

    /// Candidate cluster counts: powers of two up to the SoC size (each
    /// is a single multicast transaction; §4.2).
    pub fn candidates(&self) -> Vec<usize> {
        let max = self.cfg.soc.n_clusters();
        let mut v = vec![1usize];
        while *v.last().unwrap() * 2 <= max {
            v.push(v.last().unwrap() * 2);
        }
        v
    }

    /// Model estimate for a forced cluster count (no argmin).
    pub fn plan_estimate(&self, spec: &JobSpec, n: usize) -> u64 {
        self.model.estimate(spec, n)
    }

    /// Plan one job: argmin over candidates, host fallback.
    pub fn plan(&self, spec: &JobSpec) -> Plan {
        let host = self.host_estimate(spec);
        let candidates: Vec<(usize, u64)> = self
            .candidates()
            .into_iter()
            .map(|n| (n, self.model.estimate(spec, n)))
            .collect();
        let &(best_n, best_t) = candidates
            .iter()
            .min_by_key(|(_, t)| *t)
            .expect("non-empty candidates");
        if best_t < host {
            Plan {
                placement: Placement::Accelerator { n_clusters: best_n },
                estimate: best_t,
                host_estimate: host,
                candidates,
            }
        } else {
            Plan {
                placement: Placement::Host,
                estimate: host,
                host_estimate: host,
                candidates,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amdahl_class_gets_many_clusters() {
        let cfg = Config::default();
        let p = Planner::new(&cfg);
        let plan = p.plan(&JobSpec::MonteCarlo { samples: 1 << 16 });
        match plan.placement {
            Placement::Accelerator { n_clusters } => {
                assert!(n_clusters >= 16, "got {n_clusters}")
            }
            Placement::Host => panic!("large MC must offload"),
        }
    }

    #[test]
    fn broadcast_class_gets_few_clusters() {
        // ATAX's n-linear broadcast term pushes the optimum to small n.
        let cfg = Config::default();
        let p = Planner::new(&cfg);
        let plan = p.plan(&JobSpec::Atax { m: 64, n: 64 });
        match plan.placement {
            Placement::Accelerator { n_clusters } => {
                assert!(n_clusters <= 4, "got {n_clusters}")
            }
            Placement::Host => {} // also acceptable for this size
        }
    }

    #[test]
    fn tiny_job_stays_on_host() {
        let cfg = Config::default();
        let p = Planner::new(&cfg);
        let plan = p.plan(&JobSpec::Axpy { n: 16 });
        assert_eq!(plan.placement, Placement::Host);
        assert!(plan.host_estimate < 400);
    }

    #[test]
    fn candidates_are_powers_of_two() {
        let cfg = Config::default();
        let p = Planner::new(&cfg);
        assert_eq!(p.candidates(), vec![1, 2, 4, 8, 16, 32]);
    }

    #[test]
    fn plan_estimates_are_consistent() {
        let cfg = Config::default();
        let p = Planner::new(&cfg);
        let plan = p.plan(&JobSpec::Axpy { n: 4096 });
        if let Placement::Accelerator { n_clusters } = plan.placement {
            let (_, t) = plan
                .candidates
                .iter()
                .find(|(n, _)| *n == n_clusters)
                .unwrap();
            assert_eq!(*t, plan.estimate);
            assert!(plan.estimate < plan.host_estimate);
        } else {
            panic!("axpy 4096 should offload");
        }
    }
}
