//! Bounded job queue with blocking backpressure.
//!
//! A single CVA6 core issues every offload, but the JCU's multiple slots
//! allow outstanding jobs (§4.3); the coordinator feeds its overlapped
//! dispatch loop (up to `inflight` jobs on the shared virtual timeline)
//! from this small bounded queue between submitters and the dispatch
//! thread. Closing the queue drains it gracefully.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    high_watermark: usize,
}

/// A bounded MPMC queue.
pub struct JobQueue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for JobQueue<T> {
    fn clone(&self) -> Self {
        Self {
            inner: self.inner.clone(),
        }
    }
}

impl<T> JobQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            inner: Arc::new(Inner {
                queue: Mutex::new(State {
                    items: VecDeque::new(),
                    closed: false,
                    high_watermark: 0,
                }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                capacity,
            }),
        }
    }

    /// Blocking push; returns `Err(item)` if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.inner.capacity {
                st.items.push_back(item);
                let depth = st.items.len();
                st.high_watermark = st.high_watermark.max(depth);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: pushes fail, pops drain the remainder.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deepest the queue has ever been (backpressure diagnostics).
    pub fn high_watermark(&self) -> usize {
        self.inner.queue.lock().unwrap().high_watermark
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = JobQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_drains_then_none() {
        let q = JobQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert!(q.push(2).is_err());
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = JobQueue::new(1);
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = thread::spawn(move || q2.push(2).unwrap());
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 1, "second push must be blocked");
        assert_eq!(q.pop(), Some(1));
        t.join().unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.high_watermark(), 1);
    }

    #[test]
    fn mpmc_counts_add_up() {
        let q = JobQueue::new(8);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..100 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = 0usize;
                    while q.pop().is_some() {
                        got += 1;
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 400);
    }
}
