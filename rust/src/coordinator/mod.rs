//! The L3 coordinator: host-centric job dispatch over the simulated SoC
//! (timing) and the PJRT runtime (numerics), with a model-driven offload
//! planner (§5.6), JCU-tracked completions (§4.3), and overlapped
//! dispatch: up to `inflight` jobs share the fabric on a deterministic
//! virtual timeline ([`occupancy`]), so offload overheads can be
//! measured under contention, with every latency decomposed into
//! isolated service time plus queueing delay.

pub mod decision;
pub mod job;
pub mod metrics;
pub mod occupancy;
pub mod queue;
pub mod service;

pub use decision::{Plan, Planner, HOST_CYCLES_PER_FLOP};
pub use job::{JobRequest, JobResult, Placement};
pub use metrics::{Dist, Metrics};
pub use occupancy::{Admission, OccupancyModel, OccupancyParams};
pub use queue::JobQueue;
pub use service::{Coordinator, CoordinatorConfig, Submitter, JCU_SLOTS};
