//! The L3 coordinator: host-centric job dispatch over the simulated SoC
//! (timing) and the PJRT runtime (numerics), with a model-driven offload
//! planner (§5.6) and JCU-tracked completions (§4.3).

pub mod decision;
pub mod job;
pub mod metrics;
pub mod queue;
pub mod service;

pub use decision::{Plan, Planner, HOST_CYCLES_PER_FLOP};
pub use job::{JobRequest, JobResult, Placement};
pub use metrics::{Dist, Metrics};
pub use queue::JobQueue;
pub use service::{Coordinator, CoordinatorConfig, Submitter, JCU_SLOTS};
