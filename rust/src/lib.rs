//! # occamy-offload
//!
//! Reproduction of *"Taming Offload Overheads in a Massively Parallel
//! Open-Source RISC-V MPSoC: Analysis and Optimization"* (Colagrande &
//! Benini, IEEE TPDS 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — a cycle-level discrete-event simulator of the
//!   Occamy SoC, the baseline and multicast/JCU-optimized offload
//!   routines (§4), the analytical runtime model (§5.6) and a
//!   coordinator that schedules jobs and executes their numerics through
//!   PJRT (behind the `pjrt` feature).
//! * **L2/L1 (python/, build-time only)** — the six workloads as JAX
//!   graphs calling Pallas kernels, AOT-lowered to the HLO-text
//!   artifacts the runtime loads. Python never runs on the request path.
//!
//! ## Running experiments: `sweep` and `campaign`
//!
//! All experiment grids go through [`sweep`]: a typed request
//! ([`sweep::OffloadRequest`]), a cartesian grid builder
//! ([`sweep::Sweep`]), a parallel executor with deterministic
//! input-ordered results, result combinators (`group_by`, `triples`,
//! `mean_std`, overhead/speedup projections) and a process-wide trace
//! cache. The per-figure modules under [`exp`] are thin declarative
//! descriptions on top of it, each reconstructible from pre-computed
//! results via `from_results`.
//!
//! [`campaign`] scales sweeps beyond one process: declarative TOML
//! campaign specs ([`campaign::CampaignSpec`]), a persistent
//! content-addressed trace store ([`campaign::TraceStore`]),
//! deterministic sharding (`--shard i/N`) with streamed JSONL results,
//! and a merge/resume step whose output is bit-identical to a
//! single-process run (`occamy campaign <run|merge|status|validate>`).
//!
//! [`fleet`] scales campaigns beyond one *operator* and one *host*: a
//! scheduler turns a spec plus a worker count into a fully automatic
//! run — it launches `campaign run --shard i/N` workers through the
//! [`fleet::Launcher`] seam (local subprocesses, or SSH fan-out over a
//! `[fleet] hosts` list against a shared mount), tracks liveness via
//! heartbeat lease files on the shared store, reassigns dead or
//! stalled shards (resume makes that safe), and auto-merges when the
//! last shard lands (`occamy fleet <run|status|watch|cancel|gc>`,
//! `[fleet]` spec table; `fleet gc` compacts long-lived shared stores).
//!
//! Contention is a first-class axis: the coordinator dispatches up to
//! `inflight` jobs concurrently on a deterministic virtual timeline
//! ([`coordinator::OccupancyModel`] — free JCU-slot allocation, shared
//! cluster occupancy, deferred-interrupt completion ordering), sweeps
//! cross their grids with jobs-in-flight counts
//! ([`sweep::Sweep::inflight`], [`sweep::InterferenceRequest`]), and
//! campaigns carry an `[interference]` table whose latency-vs-inflight
//! curves are derived at merge (`occamy interfere`,
//! `occamy experiment interference`). Every latency decomposes as
//! isolated DES cycles + nonnegative queueing delay; `inflight = 1`
//! reproduces the serial coordinator bit-identically.
//!
//! [`serve`] turns the coordinator into a *service*: `occamy serve
//! --listen` runs a long-lived daemon speaking line-delimited JSON over
//! TCP ([`serve::proto`]), scheduling concurrent sessions through the
//! same occupancy model driven open-loop (arrival gaps ride in the
//! requests, so every run is reproducible), shedding overload with an
//! explicit `rejected: overloaded` reply instead of unbounded queueing,
//! and answering repeats from the campaign trace store — a warm store
//! serves entire bursts with zero fresh simulations. `occamy loadgen`
//! is its seeded open-loop client (Poisson / bursty / diurnal arrival
//! processes over a kernel mix) and `occamy bench serve` measures the
//! engine's service rate.
//!
//! [`obs`] is the cross-cutting observability layer over all of the
//! above: `occamy trace export` renders any simulated job — and any
//! occupancy-engine batch — as deterministic Perfetto/Chrome trace
//! JSON on the virtual-cycle clock ([`obs::perfetto`]), `occamy trace
//! report` re-derives the paper's overhead decomposition and Fig.
//! 11-style phase bands from a campaign store ([`obs::report`]), a
//! structured JSONL event log replaces scattered prints for serve,
//! fleet, campaign, and store lifecycles ([`obs::log`], off by
//! default; `--log`/`OCCAMY_LOG`), and a Prometheus-text metrics
//! registry is scraped through the serve protocol's `metrics` verb
//! ([`obs::metrics`]). On top of the log rides distributed tracing
//! ([`obs::span`]): deterministic span trees per request with
//! `traceparent` propagation across processes and hosts, merged into
//! the Perfetto export (`trace export --spans`) and reassembled into
//! interference curves from recorded traffic ([`obs::curves`],
//! `trace serve-report` — bit-identical to `occamy interfere` at
//! matching points). An always-on flight recorder ([`obs::flight`])
//! dumps the last events to `<store>/flight/` on panic, overload shed
//! or a mid-shard bail (`trace flight` renders dumps).
//!
//! ## Engine profiles
//!
//! Every timeline runs under a [`sim::SimProfile`] behind the
//! [`sim::Backend`] seam: `reference` is the event-heap DES, `fast`
//! ([`sim::fast`]) elides heap work — same-cycle batch drains, stale
//! completion-poll skips, analytic fast-forward of quiescent gaps —
//! and memoizes whole specialized timelines keyed by
//! [`offload::request_key`] + config fingerprint. The profile threads
//! from [`offload::Executor::with_profile`] through sweeps, campaign
//! specs, the serve daemon and every CLI entry point (`--profile
//! fast`), and the two are bit-identical by construction: a
//! differential harness (`tests/integration_profiles.rs`) and the CI
//! `des` job compare full traces, event accounting and f64 phase
//! statistics to the bit. `occamy bench des` measures the elision
//! (`BENCH_des.json`; `--baseline` is a regression gate), and
//! [`obs::metrics`] exports the elision counters.
//!
//! ## Module map
//!
//! | layer | modules |
//! |---|---|
//! | SoC model | [`config`], [`cluster`], [`host`], [`mem`], [`noc`], [`dma`], [`interrupt`] |
//! | simulation | [`sim`] (DES engine, `fast` elision profile, traces), [`offload`] (routines §4), [`kernels`] (workloads §5.1) |
//! | experiments | [`sweep`] (in-process grids + interference), [`campaign`] (sharded + persistent), [`fleet`] (multi-host scheduler: leases, recovery, auto-merge), [`exp`] (Figs. 7-12, interference), [`bench`] |
//! | modeling | [`model`] (analytical runtime model §5.6) |
//! | serving | [`coordinator`] (overlapped job scheduling, occupancy model), [`serve`] (TCP daemon: admission control, memoization, load generator), [`runtime`] (PJRT numerics, JSON) |
//! | observability | [`obs`] (Perfetto timelines, store-wide overhead reports, JSONL event log, Prometheus metrics, distributed tracing spans, flight recorder, recorded-traffic interference curves) |
//! | support | [`rng`] |
//! | static analysis | [`analysis`] (determinism-domain audit: manifest, rule engine, deterministic reports; `occamy audit`) |
//!
//! See DESIGN.md for the system inventory and the per-figure experiment
//! index, EXPERIMENTS.md for paper-vs-measured results.

pub mod analysis;
pub mod bench;
pub mod campaign;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dma;
pub mod exp;
pub mod fleet;
pub mod host;
pub mod interrupt;
pub mod kernels;
pub mod mem;
pub mod model;
pub mod noc;
pub mod obs;
pub mod offload;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sweep;
