//! A minimal metrics registry with Prometheus text exposition.
//!
//! Snapshot-style: producers *register* their current values into a
//! fresh [`Registry`] at scrape time ([`crate::serve::ServeMetrics`],
//! [`crate::campaign::StoreStats`], the fleet's
//! [`crate::fleet::StatusView`]), and [`Registry::render`] emits the
//! [text exposition format] — `# HELP`/`# TYPE` headers, counters,
//! gauges, and cycle histograms with `_bucket{le=...}`/`_sum`/`_count`
//! series. Rebuilding the registry per scrape keeps it lock-free and
//! deterministic: families render in registration order, samples in
//! insertion order, and integral values print without a fraction.
//!
//! The serve daemon exposes a rendered registry through the `metrics`
//! wire verb (see [`crate::serve::proto`]); scrape it with
//! `occamy loadgen --connect HOST:PORT --requests 0 --metrics`.
//!
//! [text exposition format]: https://prometheus.io/docs/instrumenting/exposition_formats/

use crate::campaign::StoreStats;
use crate::coordinator::Dist;
use crate::sim::FastStats;

/// Histogram bounds for cycle-valued distributions (queue, service,
/// latency): decades from 1k to 10M virtual cycles, spanning a cache
/// hit on a tiny kernel up to a wide fresh simulation.
pub const CYCLE_BUCKETS: [u64; 5] = [1_000, 10_000, 100_000, 1_000_000, 10_000_000];

/// Prometheus sample-value formatting: integral values print without a
/// fraction (`17`, not `17.0`), everything else through Rust's shortest
/// round-trip float form.
fn fmt_value(v: f64) -> String {
    const EXACT_INT: f64 = 9_007_199_254_740_992.0; // 2^53
    if v.is_finite() && v.fract() == 0.0 && v.abs() <= EXACT_INT {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value: backslash, double quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn sample_line(name: &str, suffix: &str, labels: &[(&str, &str)], value: f64) -> String {
    let mut line = format!("{name}{suffix}");
    if !labels.is_empty() {
        line.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format!("{k}=\"{}\"", escape_label(v)));
        }
        line.push('}');
    }
    line.push(' ');
    line.push_str(&fmt_value(value));
    line
}

struct Family {
    name: String,
    help: String,
    kind: &'static str,
    samples: Vec<String>,
}

/// A write-once metrics snapshot; render with [`Registry::render`].
#[derive(Default)]
pub struct Registry {
    families: Vec<Family>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Find-or-create a family; re-registering with a different kind is
    /// a programming error.
    fn family(&mut self, name: &str, help: &str, kind: &'static str) -> &mut Family {
        if let Some(i) = self.families.iter().position(|f| f.name == name) {
            assert_eq!(self.families[i].kind, kind, "metric family {name} re-registered as {kind}");
            return &mut self.families[i];
        }
        self.families.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            samples: Vec::new(),
        });
        self.families.last_mut().expect("just pushed")
    }

    /// A monotonically increasing counter sample. Call repeatedly with
    /// distinct `labels` to grow one family.
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: u64) {
        let line = sample_line(name, "", labels, value as f64);
        self.family(name, help, "counter").samples.push(line);
    }

    /// A point-in-time gauge sample.
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        let line = sample_line(name, "", labels, value);
        self.family(name, help, "gauge").samples.push(line);
    }

    /// A whole [`Dist`] as a Prometheus histogram: cumulative
    /// `_bucket{le="..."}` counts over `buckets` plus `+Inf`, `_sum` and
    /// `_count`.
    pub fn histogram(&mut self, name: &str, help: &str, dist: &Dist, buckets: &[u64]) {
        let mut samples = Vec::with_capacity(buckets.len() + 3);
        for &b in buckets {
            samples.push(sample_line(
                name,
                "_bucket",
                &[("le", &b.to_string())],
                dist.count_le(b) as f64,
            ));
        }
        samples.push(sample_line(name, "_bucket", &[("le", "+Inf")], dist.count() as f64));
        samples.push(sample_line(name, "_sum", &[], dist.sum() as f64));
        samples.push(sample_line(name, "_count", &[], dist.count() as f64));
        self.family(name, help, "histogram").samples.extend(samples);
    }

    /// The text exposition: families in registration order, each with
    /// its `# HELP`/`# TYPE` header.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.families {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} {}\n", f.name, f.kind));
            for s in &f.samples {
                out.push_str(s);
                out.push('\n');
            }
        }
        out
    }
}

/// Register one store handle's three-tier counters — the same numbers
/// the `store:` summary line and the warm-store CI assertions read.
pub fn register_store_stats(r: &mut Registry, s: &StoreStats) {
    r.counter(
        "occamy_store_memory_hits_total",
        "Requests served from the process-wide memory cache",
        &[],
        s.memory_hits,
    );
    r.counter(
        "occamy_store_disk_hits_total",
        "Requests served from the on-disk trace store",
        &[],
        s.disk_hits,
    );
    r.counter(
        "occamy_store_simulations_total",
        "Requests that ran a fresh simulation",
        &[],
        s.simulations,
    );
}

/// Register the fast engine's process-wide elision counters — the
/// numbers behind `bench des` and the fast-profile daemon's exposition
/// (see [`crate::sim::fast::stats`]).
pub fn register_fast_stats(r: &mut Registry, s: &FastStats) {
    r.counter(
        "occamy_sim_events_popped_total",
        "Events dispatched by the fast engine (heap, same-cycle run, or slot)",
        &[],
        s.events_popped,
    );
    r.counter(
        "occamy_sim_heap_events_elided_total",
        "Stale replaceable events elided before ever reaching a pop",
        &[],
        s.heap_events_elided,
    );
    r.counter(
        "occamy_sim_fast_forward_jumps_total",
        "Contention-free segments fast-forwarded analytically",
        &[],
        s.fast_forward_jumps,
    );
    r.counter(
        "occamy_sim_stale_events_skipped_total",
        "Stale generation checks short-circuited at dispatch",
        &[],
        s.stale_events_skipped,
    );
    r.counter(
        "occamy_sim_timeline_cache_hits_total",
        "Specialized-timeline memo hits (whole-trace replays)",
        &[],
        s.timeline_hits,
    );
    r.counter(
        "occamy_sim_timeline_cache_misses_total",
        "Specialized-timeline memo misses (fresh fast-engine runs)",
        &[],
        s.timeline_misses,
    );
}

/// Register the event-log sink's health counters — ring evictions and
/// file write failures. A rising `occamy_log_dropped_total` means the
/// in-memory tail (`recent()`) no longer covers the window a scraper
/// might care about.
pub fn register_log_stats(r: &mut Registry) {
    r.counter(
        "occamy_log_dropped_total",
        "Event lines evicted from the in-memory log ring",
        &[],
        crate::obs::log::dropped(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_counters_gauges_and_headers_deterministically() {
        let mut r = Registry::new();
        r.counter("occamy_test_total", "Things counted", &[("kind", "a")], 3);
        r.counter("occamy_test_total", "Things counted", &[("kind", "b")], 0);
        r.gauge("occamy_test_depth", "Current depth", &[], 2.5);
        let text = r.render();
        let expected = "# HELP occamy_test_total Things counted\n\
                        # TYPE occamy_test_total counter\n\
                        occamy_test_total{kind=\"a\"} 3\n\
                        occamy_test_total{kind=\"b\"} 0\n\
                        # HELP occamy_test_depth Current depth\n\
                        # TYPE occamy_test_depth gauge\n\
                        occamy_test_depth 2.5\n";
        assert_eq!(text, expected);
    }

    #[test]
    fn histograms_are_cumulative_with_inf_sum_and_count() {
        let mut d = Dist::default();
        for v in [500, 1_500, 1_500, 2_000_000] {
            d.record(v);
        }
        let mut r = Registry::new();
        r.histogram("occamy_test_cycles", "Cycles", &d, &[1_000, 10_000, 1_000_000]);
        let text = r.render();
        assert!(text.contains("occamy_test_cycles_bucket{le=\"1000\"} 1\n"), "{text}");
        assert!(text.contains("occamy_test_cycles_bucket{le=\"10000\"} 3\n"), "{text}");
        assert!(text.contains("occamy_test_cycles_bucket{le=\"1000000\"} 3\n"), "{text}");
        assert!(text.contains("occamy_test_cycles_bucket{le=\"+Inf\"} 4\n"), "{text}");
        assert!(text.contains("occamy_test_cycles_sum 2003500\n"), "{text}");
        assert!(text.contains("occamy_test_cycles_count 4\n"), "{text}");
        assert!(text.contains("# TYPE occamy_test_cycles histogram\n"), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut r = Registry::new();
        r.counter("m", "h", &[("k", "a\"b\\c\nd")], 1);
        assert!(r.render().contains("m{k=\"a\\\"b\\\\c\\nd\"} 1\n"), "{}", r.render());
    }

    #[test]
    fn integral_values_print_without_a_fraction() {
        assert_eq!(fmt_value(17.0), "17");
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(2.5), "2.5");
    }

    #[test]
    fn store_stats_cover_all_three_tiers() {
        let mut r = Registry::new();
        register_store_stats(
            &mut r,
            &StoreStats {
                memory_hits: 1,
                disk_hits: 2,
                simulations: 3,
            },
        );
        let text = r.render();
        assert!(text.contains("occamy_store_memory_hits_total 1\n"), "{text}");
        assert!(text.contains("occamy_store_disk_hits_total 2\n"), "{text}");
        assert!(text.contains("occamy_store_simulations_total 3\n"), "{text}");
    }

    #[test]
    fn log_stats_expose_the_drop_counter() {
        let mut r = Registry::new();
        register_log_stats(&mut r);
        let text = r.render();
        assert!(text.contains("# TYPE occamy_log_dropped_total counter\n"), "{text}");
        assert!(text.contains("occamy_log_dropped_total "), "{text}");
    }

    #[test]
    fn fast_stats_cover_every_elision_counter() {
        let mut r = Registry::new();
        register_fast_stats(
            &mut r,
            &FastStats {
                fast_forward_jumps: 1,
                heap_events_elided: 2,
                stale_events_skipped: 3,
                events_popped: 4,
                timeline_hits: 5,
                timeline_misses: 6,
            },
        );
        let text = r.render();
        assert!(text.contains("occamy_sim_fast_forward_jumps_total 1\n"), "{text}");
        assert!(text.contains("occamy_sim_heap_events_elided_total 2\n"), "{text}");
        assert!(text.contains("occamy_sim_stale_events_skipped_total 3\n"), "{text}");
        assert!(text.contains("occamy_sim_events_popped_total 4\n"), "{text}");
        assert!(text.contains("occamy_sim_timeline_cache_hits_total 5\n"), "{text}");
        assert!(text.contains("occamy_sim_timeline_cache_misses_total 6\n"), "{text}");
    }
}
