//! Interference curves from recorded serve traffic.
//!
//! `occamy trace serve-report` replays nothing: it reads the span
//! stream a serve daemon already emitted (`request` spans with their
//! `queue`/`execute` children, delimited by the daemon's
//! `engine_start` line) and reassembles each run's schedule into the
//! same [`InterferenceOutcome`] the `exp/interference` experiment
//! computes — isolated service from the `execute` span, per-job
//! queueing delay from the `queue` spans in admission order, makespan
//! from the last `request` span's end. Because the serve engine and
//! [`InterferenceRequest::run_on`] drive the *same* occupancy model,
//! a homogeneous recorded run at a fixed arrival gap reproduces the
//! experiment's row bit-identically at the matching (inflight, gap)
//! point — the CI check diffs the two tables byte-for-byte.

use std::collections::BTreeMap;

use crate::campaign::spec::parse_kernel;
use crate::obs::span::SpanRecord;
use crate::offload::RoutineKind;
use crate::runtime::json::Json;
use crate::sweep::{
    InterferenceOutcome, InterferencePoint, InterferenceRequest, InterferenceSample,
    OffloadRequest,
};

/// One request reassembled from its span tree.
#[derive(Debug, Clone)]
struct ReqSpan {
    seq: u64,
    kernel: String,
    clusters: u64,
    routine: String,
    gap: u64,
    start: u64,
    dur: u64,
    queue_dur: Option<u64>,
    execute_dur: Option<u64>,
}

/// One daemon run: everything between two `engine_start` lines.
#[derive(Debug, Default)]
struct Run {
    inflight: u64,
    /// Request span id → reassembled request.
    requests: BTreeMap<u64, ReqSpan>,
}

fn req_of<'a>(run: &'a mut Run, parent: u64, name: &str) -> anyhow::Result<&'a mut ReqSpan> {
    run.requests
        .get_mut(&parent)
        .ok_or_else(|| anyhow::anyhow!("{name} span references unknown request span {parent:016x}"))
}

/// Segment a serve event log into runs and reassemble each request's
/// span tree. Lines that are neither `engine_start` nor serve-side
/// spans (client spans, wall spans, plain events) are skipped.
fn segment(log_text: &str) -> anyhow::Result<Vec<Run>> {
    let mut runs: Vec<Run> = Vec::new();
    for (lineno, line) in log_text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        if let Some(rec) = SpanRecord::parse(line) {
            match rec.name.as_str() {
                "request" => {
                    let run = runs.last_mut().ok_or_else(|| {
                        anyhow::anyhow!(
                            "line {}: request span before any engine_start — not a serve log",
                            lineno + 1
                        )
                    })?;
                    let field = |k: &str| {
                        rec.field_u64(k).ok_or_else(|| {
                            anyhow::anyhow!("line {}: request span missing {k:?}", lineno + 1)
                        })
                    };
                    let text = |k: &str| {
                        rec.field_str(k).map(str::to_string).ok_or_else(|| {
                            anyhow::anyhow!("line {}: request span missing {k:?}", lineno + 1)
                        })
                    };
                    let req = ReqSpan {
                        seq: field("seq")?,
                        kernel: text("kernel")?,
                        clusters: field("clusters")?,
                        routine: text("routine")?,
                        gap: field("gap")?,
                        start: rec.cycle.ok_or_else(|| {
                            anyhow::anyhow!("line {}: wall-domain request span", lineno + 1)
                        })?,
                        dur: rec.dur,
                        queue_dur: None,
                        execute_dur: None,
                    };
                    anyhow::ensure!(
                        run.requests.insert(rec.span, req).is_none(),
                        "line {}: duplicate request span id {:016x}",
                        lineno + 1,
                        rec.span
                    );
                }
                "queue" | "execute" => {
                    let run = runs.last_mut().ok_or_else(|| {
                        anyhow::anyhow!(
                            "line {}: {} span before any engine_start — not a serve log",
                            lineno + 1,
                            rec.name
                        )
                    })?;
                    let parent = rec.parent.ok_or_else(|| {
                        anyhow::anyhow!("line {}: {} span has no parent", lineno + 1, rec.name)
                    })?;
                    let req = req_of(run, parent, &rec.name)?;
                    if rec.name == "queue" {
                        req.queue_dur = Some(rec.dur);
                    } else {
                        req.execute_dur = Some(rec.dur);
                    }
                }
                // Client-side and lifecycle spans carry no schedule.
                _ => {}
            }
            continue;
        }
        let Ok(v) = Json::parse(line) else { continue };
        if v.get("src").and_then(Json::as_str) == Some("serve")
            && v.get("event").and_then(Json::as_str) == Some("engine_start")
        {
            let inflight = v.get("inflight").and_then(Json::as_u64).ok_or_else(|| {
                anyhow::anyhow!("line {}: engine_start missing inflight", lineno + 1)
            })?;
            runs.push(Run {
                inflight,
                requests: BTreeMap::new(),
            });
        }
    }
    Ok(runs)
}

/// Derive interference samples from a recorded serve span log. Each
/// daemon run contributes one sample per (kernel, clusters, routine)
/// group; groups must be internally uniform in arrival gap and service
/// time (they are whenever the recorded traffic came from one loadgen
/// mix entry — heterogeneous mixes still derive, one sample per entry,
/// but only homogeneous fixed-gap runs are bit-comparable to
/// `occamy interfere`).
pub fn derive(log_text: &str) -> anyhow::Result<Vec<InterferenceSample>> {
    let runs = segment(log_text)?;
    let mut samples = Vec::new();
    for run in &runs {
        let mut groups: BTreeMap<(String, u64, String), Vec<&ReqSpan>> = BTreeMap::new();
        for req in run.requests.values() {
            groups
                .entry((req.kernel.clone(), req.clusters, req.routine.clone()))
                .or_default()
                .push(req);
        }
        for ((kernel, clusters, routine), mut group) in groups {
            group.sort_by_key(|r| r.seq);
            let spec = parse_kernel(&kernel)
                .map_err(|e| anyhow::anyhow!("recorded kernel {kernel:?}: {e}"))?;
            let routine = RoutineKind::parse(&routine)
                .ok_or_else(|| anyhow::anyhow!("recorded routine {routine:?} is unknown"))?;
            let gap = group[0].gap;
            let mut queue_delays = Vec::with_capacity(group.len());
            let mut isolated = None;
            let mut makespan = 0u64;
            for req in &group {
                anyhow::ensure!(
                    req.gap == gap,
                    "group {kernel} c{clusters}: mixed arrival gaps ({} vs {gap})",
                    req.gap
                );
                let service = req.execute_dur.ok_or_else(|| {
                    anyhow::anyhow!("request seq {} has no execute span", req.seq)
                })?;
                let queue = req.queue_dur.ok_or_else(|| {
                    anyhow::anyhow!("request seq {} has no queue span", req.seq)
                })?;
                match isolated {
                    None => isolated = Some(service),
                    Some(prev) => anyhow::ensure!(
                        prev == service,
                        "group {kernel} c{clusters}: mixed service times ({service} vs {prev})"
                    ),
                }
                queue_delays.push(queue);
                makespan = makespan.max(req.start + req.dur);
            }
            let ireq = InterferenceRequest::new(
                OffloadRequest::new(spec, clusters as usize, routine),
                run.inflight as usize,
                group.len(),
                gap,
            );
            samples.push(InterferenceSample {
                point: InterferencePoint {
                    label: spec.kind().name(),
                    ireq,
                },
                outcome: InterferenceOutcome {
                    isolated: isolated.expect("non-empty group"),
                    queue_delays,
                    makespan,
                },
            });
        }
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::OccupancyModel;
    use crate::kernels::JobSpec;
    use crate::obs::log::Event;
    use crate::obs::span::{child_span, sim_span, TraceContext};

    /// Render the span stream a serve run over `ireq`'s traffic would
    /// have logged, straight from the occupancy model's schedule.
    fn synthetic_log(cfg: &Config, ireq: &InterferenceRequest, isolated: u64) -> String {
        let mut lines = vec![Event::sim("serve", "engine_start", 0)
            .u64("inflight", ireq.inflight as u64)
            .u64("queue_factor", 4)
            .u64("gap", ireq.arrival_gap)
            .str("profile", "reference")
            .render()];
        let mut model = OccupancyModel::new(ireq.params(cfg));
        let kernel = format!("{}:512", ireq.req.spec.kind().name());
        for seq in 0..ireq.n_jobs as u64 {
            let adm = model.admit(ireq.req.n_clusters, isolated);
            let ctx = TraceContext::root("curves-test").child(&kernel, seq);
            lines.push(
                sim_span("request", ctx, None, adm.arrival, adm.completion - adm.arrival)
                    .u64("id", seq + 1)
                    .str("kernel", &kernel)
                    .u64("clusters", ireq.req.n_clusters as u64)
                    .str("routine", ireq.req.routine.name())
                    .u64("seq", seq)
                    .u64("gap", ireq.arrival_gap)
                    .render(),
            );
            let q = TraceContext { trace: ctx.trace, span: child_span(ctx.span, "queue") };
            let x = TraceContext { trace: ctx.trace, span: child_span(ctx.span, "execute") };
            lines.push(
                sim_span("queue", q, Some(ctx.span), adm.arrival, adm.queue_delay)
                    .u64("id", seq + 1)
                    .render(),
            );
            lines.push(
                sim_span("execute", x, Some(ctx.span), adm.start, isolated)
                    .u64("id", seq + 1)
                    .render(),
            );
        }
        model.finish();
        lines.join("\n")
    }

    #[test]
    fn recorded_schedule_round_trips_through_run_on() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 512 }, 16, RoutineKind::Multicast);
        for inflight in [1usize, 4] {
            let ireq = InterferenceRequest::new(req, inflight, 8, 0);
            let log = synthetic_log(&cfg, &ireq, 1000);
            let samples = derive(&log).unwrap();
            assert_eq!(samples.len(), 1);
            let s = &samples[0];
            assert_eq!(s.point.label, "axpy");
            assert_eq!(s.point.ireq, ireq);
            // The reassembled outcome is the model's own schedule.
            assert_eq!(s.outcome, ireq.run_on(&cfg, 1000));
        }
    }

    #[test]
    fn two_concatenated_runs_become_two_samples_in_log_order() {
        let cfg = Config::default();
        let req = OffloadRequest::new(JobSpec::Axpy { n: 512 }, 16, RoutineKind::Multicast);
        let a = InterferenceRequest::new(req, 1, 4, 0);
        let b = InterferenceRequest::new(req, 4, 4, 0);
        let log = format!(
            "{}\n{}",
            synthetic_log(&cfg, &a, 900),
            synthetic_log(&cfg, &b, 900)
        );
        let samples = derive(&log).unwrap();
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].point.ireq.inflight, 1);
        assert_eq!(samples[1].point.ireq.inflight, 4);
        assert!(samples[0].outcome.total_queue_delay() == 0);
    }

    #[test]
    fn malformed_logs_error_instead_of_misreporting() {
        // Spans before any engine_start are not a serve log.
        let ctx = TraceContext::root("x").child("axpy:64", 0);
        let orphan = sim_span("request", ctx, None, 0, 10)
            .u64("id", 1)
            .str("kernel", "axpy:64")
            .u64("clusters", 2)
            .str("routine", "multicast")
            .u64("seq", 0)
            .u64("gap", 0)
            .render();
        let err = derive(&orphan).unwrap_err().to_string();
        assert!(err.contains("engine_start"), "{err}");
        // A request whose execute child is missing cannot be scored.
        let start = Event::sim("serve", "engine_start", 0).u64("inflight", 1).render();
        let q = TraceContext { trace: ctx.trace, span: child_span(ctx.span, "queue") };
        let queue = sim_span("queue", q, Some(ctx.span), 0, 0).u64("id", 1).render();
        let err = derive(&format!("{start}\n{orphan}\n{queue}"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no execute span"), "{err}");
        // An empty log has no runs and no samples.
        assert!(derive("").unwrap().is_empty());
    }
}
