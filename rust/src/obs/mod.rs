//! # Cycle-accurate observability (`obs`)
//!
//! The paper's contribution is *attribution*: per-phase (A–I) runtimes
//! reconstructed from mcycle-style instrumentation (§5.1, Fig. 11).
//! The simulator records all of that ([`crate::sim::Trace`],
//! [`crate::serve::ServeMetrics`], the store's hit/sim counters, the
//! fleet's lease states) — this module is the layer that gets it *out*,
//! in forms humans and machines already know how to read:
//!
//! * [`perfetto`] — deterministic Chrome trace-event / Perfetto JSON
//!   timelines on the virtual-cycle clock: one lane per cluster with its
//!   A–I [`crate::sim::PhaseSpan`]s, host lanes for the host-side
//!   phases, and coordinator lanes (JCU slots + queueing) for
//!   occupancy-engine batches. `occamy trace export` writes them; open
//!   the file in <https://ui.perfetto.dev> or `chrome://tracing`.
//! * [`report`] — aggregation over a campaign store: re-derive the
//!   paper's overhead decomposition (offload overhead vs. execute) and
//!   Fig. 11-style per-phase min/avg/max bands from arbitrary recorded
//!   traffic, not just the `exp/fig11` grid (`occamy trace report`).
//! * [`log`] — a leveled, ring-buffered JSONL event sink. Off by
//!   default; enabled with `occamy serve --log FILE` or the
//!   `OCCAMY_LOG` environment variable. Sim-domain events are stamped
//!   in virtual cycles (deterministic bytes — golden tests hold),
//!   daemon/fleet events in wall time. Pure observation: enabling it
//!   never changes a simulation result or adds a fresh simulation.
//! * [`metrics`] — a Prometheus-text metrics registry.
//!   [`crate::serve::ServeMetrics`], [`crate::campaign::StoreStats`]
//!   and the fleet's shard states register into it; the serve wire
//!   protocol exposes it through the `metrics` verb (alongside the
//!   JSON `stats` verb), so a standard scraper can watch a long-lived
//!   daemon: `occamy loadgen --connect HOST:PORT --requests 0 --metrics`.
//! * [`span`] — deterministic distributed-tracing spans: every request
//!   carries a trace/span id derived from its key and admission seq (no
//!   wall-clock entropy), with parent/child spans at each layer boundary
//!   and `traceparent` propagation across processes and hosts
//!   (`--trace-parent` / `OCCAMY_TRACE_PARENT`). Spans ride the [`log`]
//!   stream; `occamy trace export --spans` merges them into the
//!   Perfetto timeline and `occamy trace serve-report` derives
//!   interference curves from them.
//! * [`flight`] — an always-on flight recorder: the last N event lines
//!   in a fixed lock-free ring, dumped to `<store>/flight/` on panic,
//!   overload shed, or a worker bailing mid-shard; `occamy trace
//!   flight` renders a dump.
//! * [`curves`] — latency-vs-inflight interference curves reassembled
//!   from recorded serve span streams (`occamy trace serve-report`),
//!   bit-identical to `exp/interference` at matching (inflight, gap)
//!   points.

pub mod curves;
pub mod flight;
pub mod log;
pub mod metrics;
pub mod perfetto;
pub mod report;
pub mod span;

pub use log::{Event, EventLog, Level};
pub use metrics::Registry;
pub use span::{SpanRecord, TraceContext};
